"""End-to-end behaviour tests for the framework.

* config registry: all 10 assigned archs load; analytic parameter counts
  match the published model sizes (the config-fidelity check);
* training integration: a reduced model trains for 12 steps end-to-end
  (data pipeline -> train step -> checkpoint -> resume) and the resumed
  run is bit-identical;
* serving integration: greedy decode agrees across all three KV placements
  (local / bridge_pull / bridge_push) on a model with mixed SWA+full layers.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.config import SHAPES, OptimConfig, RunConfig, ShapeConfig
from repro.data.pipeline import SyntheticLM
from repro.models import transformer
from repro.serve import step as serve_step_mod
from repro.train import step as train_step_mod

# published sizes (B params): total, active
PUBLISHED = {
    "internvl2-2b": (1.9, 1.9),          # LM backbone of the 2B VLM
    "granite-moe-1b-a400m": (1.3, 0.4),
    "phi3_5-moe-42b-a6_6b": (41.9, 6.6),
    "recurrentgemma-9b": (8.5, 8.5),
    "seamless-m4t-medium": (0.6, 0.6),   # decoder+encoder backbone
    "h2o-danube-3-4b": (4.0, 4.0),
    "gemma3-12b": (11.8, 11.8),
    "granite-3-8b": (8.2, 8.2),
    "starcoder2-7b": (7.4, 7.4),
    "xlstm-125m": (0.09, 0.09),
}


def test_registry_has_all_assigned_archs():
    assert len(configs.lm_archs()) == 10
    assert len(SHAPES) == 4


@pytest.mark.parametrize("arch", configs.lm_archs())
def test_param_counts_match_published(arch):
    cfg = configs.get_config(arch)
    total, active = PUBLISHED[arch]
    assert cfg.param_count() / 1e9 == pytest.approx(total, rel=0.15)
    assert cfg.active_param_count() / 1e9 == pytest.approx(active, rel=0.15)


def test_train_checkpoint_resume_bitwise():
    cfg = dataclasses.replace(configs.get_reduced("granite-3-8b"),
                              dtype="float32")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 2, "train"),
                    optim=OptimConfig(lr=1e-3, warmup_steps=2,
                                      total_steps=12))
    step = jax.jit(train_step_mod.build_train_step(run), donate_argnums=(0,))
    data = SyntheticLM(cfg, 2, 32)

    def run_steps(state, lo, hi):
        for i in range(lo, hi):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state, metrics = step(state, batch)
        return state, metrics

    state = train_step_mod.make_train_state(run, jax.random.key(0))
    state, _ = run_steps(state, 0, 6)
    with tempfile.TemporaryDirectory() as d:
        ckpt = CheckpointManager(d)
        ckpt.save(6, state, extra={"step": 6})
        # continue directly
        direct, m_direct = run_steps(state, 6, 12)
        # resume from checkpoint and continue identically
        template = train_step_mod.make_train_state(run, jax.random.key(0))
        resumed, extra = ckpt.restore(template)
        resumed = jax.tree.map(jnp.asarray, resumed)
        resumed, m_resumed = run_steps(resumed, int(extra["step"]), 12)
    assert float(m_direct["loss"]) == pytest.approx(
        float(m_resumed["loss"]), abs=1e-6)
    for a, b in zip(jax.tree.leaves(direct.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["gemma3-12b", "granite-moe-1b-a400m"])
def test_serve_placements_agree(arch):
    """Mixed SWA+global layers (gemma3) and MoE (granite-moe)."""
    cfg = dataclasses.replace(configs.get_reduced(arch), dtype="float32")
    shape = ShapeConfig("s", 32, 2, "decode")
    params = transformer.init_params(cfg, jax.random.key(0))
    outs = {}
    for kv in ("local", "bridge_pull", "bridge_push"):
        run = RunConfig(model=cfg, shape=shape, kv_placement=kv)
        ops_ = serve_step_mod.make_cache_ops(run, mesh=None, max_len=32,
                                             page_tokens=8,
                                             dtype=jnp.float32)
        state = serve_step_mod.init_serve_state(run, 2, ops_)
        step = jax.jit(serve_step_mod.build_serve_step(run, ops_),
                       donate_argnums=(1,))
        tokens = jnp.asarray([3, 5], jnp.int32)
        seq = []
        for _ in range(12):
            tokens, state = step(params, state, tokens)
            seq.append(np.asarray(tokens))
        outs[kv] = np.stack(seq)
    np.testing.assert_array_equal(outs["local"], outs["bridge_pull"])
    np.testing.assert_array_equal(outs["local"], outs["bridge_push"])


def test_long_context_skip_policy():
    """The DESIGN.md §5 applicability matrix is what the code enforces."""
    expect_run = {"recurrentgemma-9b", "h2o-danube-3-4b", "gemma3-12b",
                  "xlstm-125m"}
    for arch in configs.lm_archs():
        cfg = configs.get_config(arch)
        assert cfg.supports_long_context == (arch in expect_run), arch
