"""repro.orchestrator — tenants, admission, QoS scheduling, lifecycle.

Covers the orchestration acceptance contract:

* tenant/lease mechanics: step-denominated expiry, auto-renew, reclamation
  freeing capacity for queued admissions,
* admission rules: quota rejects, capacity/SLO queues, FIFO drain,
* the weighted-fair scheduler: proportional shares, demand caps with
  work-conserving spill (unused interactive budget flows to batch),
  interactive-first composition,
* per-tenant telemetry: the datapath's tenant lane matches the extended
  ref oracle bit-exactly and always reconciles with the untagged PR 2
  counters (property-tested over random ragged fabrics and 1-4 tenants),
* the ControlPlane satellites: logical-id recycling under lease churn and
  the dead-affinity placement guard (fall back to board mates).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from topologies import TELEM_FIELDS, assert_telem_equal, make_pool, \
    random_fabric, striped_table

from repro.core import bridge, ref, steering
from repro.core.control_plane import ControlPlane
from repro.core.memport import FREE, MemPortTable
from repro.core.topology import Topology
from repro.orchestrator import (ADMITTED, QUEUED, REJECTED,
                                AdmissionController, Lease, Orchestrator,
                                Schedule, TenantSpec, WeightedFairScheduler,
                                water_fill)
from repro.telemetry import TelemetryAggregator
from repro.telemetry.counters import DEFAULT_MAX_TENANTS

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # pragma: no cover
    from hypofallback import given, settings, st


# ---------------------------------------------------------------------------
# Tenants + leases
# ---------------------------------------------------------------------------

def test_tenant_spec_validation():
    with pytest.raises(ValueError):
        TenantSpec(0, "bad", qos="realtime")
    with pytest.raises(ValueError):
        TenantSpec(0, "bad", share=0.0)
    with pytest.raises(ValueError):
        TenantSpec(-1, "bad")


def test_lease_expiry_and_auto_renew():
    cp = ControlPlane(4, 8, num_logical=32)
    orc = Orchestrator(cp, budget=8, default_term=3)
    orc.register(TenantSpec(0, "a"))
    _, lease = orc.request_lease(0, 4)
    assert lease.expires_step == 3
    for _ in range(2):
        orc.step()
    assert lease.lease_id in orc.leases
    rep = orc.step()                       # step 3: lapse
    assert lease.lease_id in set(rep["expired"])
    assert orc.held_pages(0) == 0
    assert (cp.occupancy() == 0).all()

    _, lease2 = orc.request_lease(0, 4, auto_renew=True)
    for _ in range(7):
        rep = orc.step()
    assert lease2.lease_id in orc.leases   # renewed, never reclaimed
    assert lease2.renewals >= 2


def test_lease_expiry_drains_admission_queue():
    cp = ControlPlane(2, 4, num_logical=16)
    orc = Orchestrator(cp, budget=4, default_term=2)
    orc.register(TenantSpec(0, "a"))
    orc.register(TenantSpec(1, "b"))
    _, big = orc.request_lease(0, 8)       # fills the pool
    assert big is not None
    dec, none = orc.request_lease(1, 4)    # no capacity: queued
    assert dec.status == QUEUED and none is None
    rep1 = orc.step()
    assert rep1["granted"] == []
    rep2 = orc.step()                      # lease 0 expires -> queue drains
    assert big.lease_id in set(rep2["expired"])
    assert rep2["granted"] == [1]
    assert orc.held_pages(1) == 4


# ---------------------------------------------------------------------------
# Admission rules
# ---------------------------------------------------------------------------

def test_admission_rules():
    ac = AdmissionController(queue_limit=1)
    spec = TenantSpec(0, "t", page_quota=10, slo_round_us=50.0)
    ok = ac.evaluate(spec, 4, free_slots=8, free_logical=8, held_pages=0)
    assert ok.status == ADMITTED
    quota = ac.evaluate(spec, 8, free_slots=8, free_logical=8, held_pages=4)
    assert quota.status == REJECTED and "quota" in quota.reason
    cap = ac.evaluate(spec, 9, free_slots=8, free_logical=20, held_pages=0)
    assert cap.status == QUEUED and "capacity" in cap.reason
    ids = ac.evaluate(spec, 6, free_slots=8, free_logical=4, held_pages=0)
    assert ids.status == QUEUED and "logical" in ids.reason
    slo = ac.evaluate(spec, 4, free_slots=8, free_logical=8, held_pages=0,
                      predicted_us=80.0)
    assert slo.status == QUEUED and "slo" in slo.reason
    # queue limit: second enqueue rejects
    from repro.orchestrator import PendingRequest
    assert ac.enqueue(PendingRequest(0, 4)).status == QUEUED
    assert ac.enqueue(PendingRequest(0, 4)).status == REJECTED


def test_admission_rejects_request_beyond_total_capacity():
    """A request the whole pool cannot hold must REJECT, not queue.

    Regression: such a request used to QUEUE on the free-capacity rule
    and then retry in the FIFO forever — waiting can never heal it.
    """
    ac = AdmissionController()
    spec = TenantSpec(0, "t")
    d = ac.evaluate(spec, 40, free_slots=8, free_logical=50, held_pages=0,
                    total_slots=32, total_logical=64)
    assert d.status == REJECTED and "whole alive pool" in d.reason
    d = ac.evaluate(spec, 40, free_slots=8, free_logical=20, held_pages=0,
                    total_slots=64, total_logical=32)
    assert d.status == REJECTED and "logical id space" in d.reason
    # within totals but over free capacity still queues (can heal)
    d = ac.evaluate(spec, 16, free_slots=8, free_logical=20, held_pages=0,
                    total_slots=32, total_logical=64)
    assert d.status == QUEUED
    # orchestrator path: the impossible request never enters the queue
    cp = ControlPlane(4, 4, num_logical=64)
    orc = Orchestrator(cp, budget=8)
    orc.register(TenantSpec(0, "t"))
    dec, lease = orc.request_lease(0, 17)      # pool holds 4 * 4 = 16
    assert dec.status == REJECTED and lease is None
    assert len(orc.admission.pending) == 0
    for _ in range(4):                         # no livelock, no retries
        orc.step()
        assert len(orc.admission.pending) == 0


def test_admission_queue_eviction_max_attempts_and_ttl():
    from repro.orchestrator import PendingRequest
    ac = AdmissionController(max_attempts=2)
    ac.enqueue(PendingRequest(0, 4))
    for _ in range(2):
        assert ac.drain(lambda req: False) == []
        assert len(ac.pending) == 1
    assert ac.drain(lambda req: False) == []   # third drain evicts
    assert len(ac.pending) == 0
    assert ac.evicted_total == 1 and ac.rejected_total == 1
    assert [r.tenant_id for r in ac.last_evicted] == [0]

    ac = AdmissionController(ttl_steps=3)
    ac.enqueue(PendingRequest(1, 4, queued_step=10))
    assert ac.drain(lambda req: False, step=13) == []
    assert len(ac.pending) == 1                # inside the TTL
    assert ac.drain(lambda req: False, step=14) == []
    assert len(ac.pending) == 0 and ac.evicted_total == 1

    # orchestrator wiring: a capacity-starved request is evicted by TTL
    # instead of livelocking the admission loop forever.
    cp = ControlPlane(2, 4, num_logical=16)
    orc = Orchestrator(cp, budget=8, queue_ttl_steps=2)
    orc.register(TenantSpec(0, "hog"))
    orc.register(TenantSpec(1, "late"))
    _, hold = orc.request_lease(0, 8, term=0)  # pins the whole pool
    assert hold is not None
    dec, _ = orc.request_lease(1, 6)
    assert dec.status == QUEUED
    reports = [orc.step() for _ in range(4)]
    assert any(r["evicted"] == [1] for r in reports)
    assert len(orc.admission.pending) == 0


def test_admission_drain_keeps_fifo_order():
    from repro.orchestrator import PendingRequest
    ac = AdmissionController()
    ac.enqueue(PendingRequest(0, 4))
    ac.enqueue(PendingRequest(1, 2))
    granted = ac.drain(lambda req: req.tenant_id == 1)
    assert [g.tenant_id for g in granted] == [1]
    assert [p.tenant_id for p in ac.pending] == [0]
    assert ac.pending[0].attempts == 1


# ---------------------------------------------------------------------------
# Weighted-fair scheduler
# ---------------------------------------------------------------------------

def test_water_fill_work_conserving():
    # equal shares, one tenant demand-capped: surplus spills to the other
    alloc = water_fill(np.asarray([1.0, 1.0]), np.asarray([2.0, np.inf]), 8)
    assert alloc[0] == pytest.approx(2.0)
    assert alloc[1] == pytest.approx(6.0)
    # weighted 3:1 with unbounded demand: proportional
    alloc = water_fill(np.asarray([3.0, 1.0]),
                       np.asarray([np.inf, np.inf]), 8)
    assert alloc.tolist() == [6.0, 2.0]
    # zero demand gets nothing
    alloc = water_fill(np.asarray([1.0, 1.0]), np.asarray([0.0, 5.0]), 8)
    assert alloc.tolist() == [0.0, 5.0]


def test_water_fill_zero_weight_guard():
    """All-zero effective shares must not divide by zero (NaN windows).

    Regression: ``water_fill`` divided by ``w.sum()`` unguarded; a zero
    share vector produced NaN allocations that propagated into compiled
    windows.  The guard falls back to an even split among hungry tenants.
    """
    alloc = water_fill(np.asarray([0.0, 0.0]),
                       np.asarray([np.inf, np.inf]), 8)
    assert np.isfinite(alloc).all()
    assert alloc.tolist() == [4.0, 4.0]
    # negative shares clip to zero rather than stealing budget
    alloc = water_fill(np.asarray([-1.0, 1.0]), np.asarray([5.0, 5.0]), 8)
    assert np.isfinite(alloc).all() and (alloc >= 0).all()
    assert alloc.sum() <= 8 + 1e-9
    # mixed: one zero-share tenant alongside a positive one still works
    alloc = water_fill(np.asarray([0.0, 2.0]), np.asarray([4.0, 2.0]), 8)
    assert np.isfinite(alloc).all() and alloc.sum() <= 8 + 1e-9


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_water_fill_windows_property(seed):
    """Compiled windows always sum to <= budget with no NaN/negatives."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 7))
    budget = int(rng.integers(1, 65))
    # shares include exact zeros (the division-guard case) and demands mix
    # zero / finite / unbounded tenants
    shares = np.where(rng.random(n) < 0.3, 0.0, rng.uniform(0.0, 8.0, n))
    dem = np.where(rng.random(n) < 0.3, np.inf,
                   rng.uniform(0.0, 128.0, n))
    alloc = water_fill(shares, dem, budget)
    assert np.isfinite(alloc).all()
    assert (alloc >= 0).all()
    assert alloc.sum() <= budget + 1e-6
    # end to end through the scheduler: integer windows obey the same
    # invariants (TenantSpec enforces share > 0, so jitter shares up)
    specs = [TenantSpec(i, f"t{i}", share=float(shares[i]) + 1e-3,
                        qos=str(rng.choice(["interactive", "batch",
                                            "best_effort"])))
             for i in range(n)]
    sched = WeightedFairScheduler(budget)
    s = sched.compile(specs, demand={i: (None if np.isinf(dem[i])
                                         else float(dem[i]))
                                     for i in range(n)})
    assert s.total_window <= budget
    assert all(w >= 0 for w in s.windows.values())


def test_scheduler_interactive_first_and_spill():
    sched = WeightedFairScheduler(budget=8)
    specs = [TenantSpec(0, "batchy", qos="batch", share=1.0),
             TenantSpec(1, "chat", qos="interactive", share=1.0)]
    s = sched.compile(specs, demand={0: 100.0, 1: 2.0})
    # interactive composes first despite the higher tenant id
    assert s.order == (1, 0)
    # interactive capped at its demand, surplus spills to batch
    assert s.windows[1] == 2
    assert s.windows[0] == 6
    assert s.total_window == 8


def test_scheduler_windows_never_exceed_budget():
    sched = WeightedFairScheduler(budget=5)
    specs = [TenantSpec(i, f"t{i}", share=float(i + 1)) for i in range(3)]
    s = sched.compile(specs)
    assert s.total_window <= 5
    assert all(w >= 0 for w in s.windows.values())


def test_schedule_compose_requests():
    s = Schedule(windows={0: 2, 1: 3}, order=(1, 0), budget=8)
    backlogs = {0: [[10, 11, 12], [20]], 1: [[30], [40, 41, 42, 43]]}
    want, lane, taken = s.compose_requests(backlogs, num_nodes=2)
    assert want.shape == (2, 5) and lane.shape == (2, 5)
    # tenant 1's window (3 lanes) first, then tenant 0's (2 lanes)
    assert want[0].tolist() == [30, FREE, FREE, 10, 11]
    assert want[1].tolist() == [40, 41, 42, 20, FREE]
    # only the filled prefix carries the tenant tag: FREE filler lanes
    # keep tenant lane 0 (regression — they used to be tagged with the
    # window's tenant id, contradicting the docstring contract)
    assert lane[0].tolist() == [1, 0, 0, 0, 0]
    assert lane[1].tolist() == [1, 1, 1, 0, 0]
    assert (lane[want == FREE] == 0).all()
    assert taken == {1: 3, 0: 2}


def test_compose_requests_free_lanes_reconcile_with_telemetry():
    """Composed lanes must reconcile bit-exactly with per-tenant telemetry.

    Regression for the FREE-filler tagging bug: a tenant whose backlog is
    shorter than its window left FREE lanes tagged with its id.  The
    oracle never *counts* FREE requests, so the bug was latent — but any
    consumer reading the lane directly (or a future datapath change)
    would attribute phantom traffic.  This pins the contract both ways: the
    lane is 0 wherever want is FREE, and the oracle's per-tenant sums
    equal the per-tenant non-FREE lane counts.
    """
    n, budget = 4, 4
    table = striped_table(32, n, 8)
    sched = WeightedFairScheduler(budget)
    specs = [TenantSpec(1, "chat", qos="interactive"),
             TenantSpec(2, "crawl", qos="batch")]
    s = sched.compile(specs, demand={1: 3.0, 2: 3.0})
    # short backlogs: every node has fewer queued pages than its window
    backlogs = {1: [[0], [1], [], [2]], 2: [[3, 4], [5], [6], []]}
    want, lane, _ = s.compose_requests(backlogs, num_nodes=n)
    assert (lane[want == FREE] == 0).all()
    program = steering.bidirectional_program(n)
    telem = ref.expected_transfer_telemetry(
        want, table, program, num_nodes=n, budget=want.shape[1],
        tenant_ids=lane, max_tenants=DEFAULT_MAX_TENANTS)
    per_tenant = (np.asarray(telem.tenant_served)
                  + np.asarray(telem.tenant_spilled)
                  + np.asarray(telem.tenant_pruned)).sum(0)
    for spec in specs:
        composed = int(((lane == spec.tenant_id)
                        & (want != FREE)).sum())
        assert per_tenant[spec.tenant_id] == composed
    # nothing was attributed to the FREE filler tenant 0
    assert per_tenant[0] == 0


def test_scheduler_refit_unclips_spilled_tenant():
    sched = WeightedFairScheduler(budget=8)
    specs = [TenantSpec(0, "a", qos="interactive"),
             TenantSpec(1, "b", qos="batch")]
    agg = TelemetryAggregator(2, max_tenants=DEFAULT_MAX_TENANTS)
    agg.last_tenant_served = np.asarray([4.0, 8.0, 0, 0])
    agg.last_tenant_spilled = np.asarray([0.0, 6.0, 0, 0])
    s = sched.refit(specs, agg, num_nodes=2)
    # tenant 0 served 2/node with no spill -> capped at 2; tenant 1
    # spilled -> treated as unbounded, takes the rest of the budget.
    assert s.windows[0] == 2
    assert s.windows[1] == 6


# ---------------------------------------------------------------------------
# ControlPlane satellites
# ---------------------------------------------------------------------------

def test_logical_id_recycling_survives_churn():
    """Allocate/release churn beyond num_logical must not exhaust ids."""
    cp = ControlPlane(4, 4, num_logical=12)
    total = 0
    for i in range(10):                    # 60 pages >> 12 logical ids
        region = cp.allocate(6, name=f"r{i}")
        total += len(region.page_ids)
        assert (np.asarray(region.page_ids) < 12).all()
        cp.release(region)
    assert total == 60
    # ids really recycle: a full-space allocation still fits
    region = cp.allocate(12)
    assert sorted(np.asarray(region.page_ids).tolist()) == list(range(12))


def test_double_release_does_not_alias_logical_ids():
    """Releasing a region twice must not duplicate free-list ids.

    A duplicate would hand the same logical id to two later allocations,
    silently aliasing two tenants' pages.
    """
    cp = ControlPlane(2, 4, num_logical=8)
    region = cp.allocate(4)
    cp.release(region)
    cp.release(region)                     # stale handle: must be a no-op
    a = cp.allocate(4)
    b = cp.allocate(4)
    ids = np.concatenate([a.page_ids, b.page_ids])
    assert len(set(ids.tolist())) == 8     # no id handed out twice
    assert sorted(set(np.asarray(cp.table().home)[ids].tolist())) == [0, 1]


def test_stale_release_after_id_recycling_is_noop():
    """A stale handle whose ids were recycled must not free the new owner.

    allocate -> release -> allocate (reuses the ids) -> release the STALE
    handle: pre-fix this freed the live region's slots and re-queued its
    ids, aliasing the next two allocations.
    """
    cp = ControlPlane(2, 4, num_logical=8)
    a = cp.allocate(2)
    cp.release(a)
    b = cp.allocate(2)                     # recycles a's ids
    assert set(b.page_ids.tolist()) == set(a.page_ids.tolist())
    cp.release(a)                          # stale: must not touch b
    home_col = np.asarray(cp.table().home)
    assert (home_col[b.page_ids] >= 0).all()   # b still placed
    c = cp.allocate(2)
    assert not set(c.page_ids.tolist()) & set(b.page_ids.tolist())


def test_queued_request_that_becomes_rejected_is_dropped():
    """A queued request pushed over quota by a later grant must drop.

    Re-queueing it forever would poison the admission queue ('waiting
    cannot heal a quota violation').
    """
    cp = ControlPlane(2, 16, num_logical=48)
    orc = Orchestrator(cp, budget=4)
    orc.register(TenantSpec(0, "a", page_quota=10))
    orc.register(TenantSpec(1, "b"))
    _, filler = orc.request_lease(1, 28)           # leaves 4 free slots
    dec, _ = orc.request_lease(0, 8)               # no capacity: queued
    assert dec.status == QUEUED
    _, small = orc.request_lease(0, 4)             # fits; tenant 0 at 4/10
    assert small is not None
    # capacity frees up, but the queued 8 now violates the quota (4+8>10)
    orc.release_lease(filler)
    rejected_before = orc.admission.rejected_total
    rep = orc.step()
    assert rep["granted"] == []
    assert len(orc.admission.pending) == 0         # dropped, not re-queued
    assert orc.admission.rejected_total == rejected_before + 1


def test_allocate_rolls_back_on_pool_exhaustion():
    cp = ControlPlane(2, 2, num_logical=16)
    cp.allocate(4)
    with pytest.raises(RuntimeError, match="out of slots"):
        cp.allocate(4)
    # the failed allocation left no leaked ids or half-placed pages
    r = cp.allocate(0)  # no-op region still works
    cp2_free = sum(cp.free_slots(n) for n in range(2))
    assert cp2_free == 0
    assert int((np.asarray(cp.table().home) >= 0).sum()) == 4


def test_affinity_allocation_avoids_dead_node():
    topo = Topology.boards(2, 2)
    cp = ControlPlane(4, 4, num_logical=16, topology=topo)
    cp.fail_node(1)
    region = cp.allocate(4, policy="affinity", affinity=1)
    home_col = np.asarray(cp.table().home)
    homes = {int(home_col[p]) for p in region.page_ids}
    assert 1 not in homes
    assert homes == {0}                    # node 1's board mate preferred
    # quarantined-without-remap node: alive=False but free list intact
    cp.nodes[2].alive = False
    region2 = cp.allocate(2, policy="affinity", affinity=2)
    home_col = np.asarray(cp.table().home)
    homes2 = {int(home_col[p]) for p in region2.page_ids}
    assert 2 not in homes2 and homes2 <= {3}
    # pull round-trip through the re-homed placement
    table = cp.table()
    pool = make_pool(16, 4)
    want = jnp.asarray(np.asarray(region.page_ids, np.int32)[None, :])
    got = bridge.pull_pages(pool, want, table, mesh=None, budget=4,
                            table_nodes=4)
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    assert np.abs(np.asarray(got)).sum() > 0


# ---------------------------------------------------------------------------
# Per-tenant telemetry: oracle match + reconciliation (property-tested)
# ---------------------------------------------------------------------------

def test_tenant_lane_matches_oracle_loopback():
    tn, ppn, budget = 4, 8, 3
    pool = make_pool(tn * ppn, 4)
    table = striped_table(20, tn, ppn)
    rng = np.random.default_rng(5)
    want = jnp.asarray(rng.integers(-1, 20, size=(tn, 9)), jnp.int32)
    lane = jnp.asarray(rng.integers(0, 3, size=(tn, 9)), jnp.int32)
    for prog in (steering.bidirectional_program(tn),
                 steering.pruned_program(steering.bidirectional_program(tn),
                                         [1, 3])):
        _, telem = bridge.pull_pages(
            pool, want, table, mesh=None, budget=budget, table_nodes=tn,
            program=prog, active_budget=jnp.int32(2),
            collect_telemetry=True, tenant_ids=lane)
        exp = ref.expected_transfer_telemetry(
            want, table, prog, num_nodes=tn, budget=budget, active_budget=2,
            tenant_ids=lane)
        assert_telem_equal(telem, exp)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_tenant_reconciliation_property(seed):
    """Random ragged fabrics, 1-4 tenants: tenant sums == untagged counters.

    The oracle AND the loopback datapath must attribute every outcome to
    exactly one tenant: summed over tenants, the per-tenant histograms
    reproduce the untagged served/spilled/pruned counters bit-for-bit.
    """
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n = topo.num_nodes
    ppn = int(rng.integers(2, 6))
    num_logical = int(rng.integers(1, n * ppn + 1))
    table = striped_table(num_logical, n, ppn)
    budget = int(rng.integers(1, 5))
    r = int(rng.integers(1, 12))
    num_tenants = int(rng.integers(1, 5))
    want = jnp.asarray(
        rng.integers(-1, num_logical, size=(n, r)), jnp.int32)
    lane = jnp.asarray(rng.integers(0, num_tenants, size=(n, r)),
                       jnp.int32)
    ab = int(rng.integers(0, budget + 1))
    prog = steering.hierarchical_program(topo) if n > 1 else None
    exp = ref.expected_transfer_telemetry(
        want, table, prog, num_nodes=n, budget=budget,
        active_budget=ab, topology=topo, tenant_ids=lane)
    # reconciliation with the untagged counters (the PR 2 plane)
    np.testing.assert_array_equal(
        np.asarray(exp.tenant_served).sum(-1),
        np.asarray(exp.served_total()))
    np.testing.assert_array_equal(
        np.asarray(exp.tenant_spilled).sum(-1), np.asarray(exp.spilled))
    np.testing.assert_array_equal(
        np.asarray(exp.tenant_pruned).sum(-1), np.asarray(exp.pruned))
    # and the loopback datapath agrees with the oracle bit-exactly
    pool = make_pool(n * ppn, 2, seed=int(rng.integers(1 << 16)))
    _, telem = bridge.pull_pages(
        pool, want, table, mesh=None, budget=budget, table_nodes=n,
        active_budget=jnp.int32(ab), program=prog, topology=topo,
        collect_telemetry=True, tenant_ids=lane)
    assert_telem_equal(telem, exp)


def test_tenant_lane_is_observational():
    """Attribution never changes what is served."""
    tn, ppn = 4, 8
    pool = make_pool(tn * ppn, 4)
    table = striped_table(16, tn, ppn)
    rng = np.random.default_rng(9)
    want = jnp.asarray(rng.integers(-1, 16, size=(tn, 6)), jnp.int32)
    plain = bridge.pull_pages(pool, want, table, mesh=None, budget=3,
                              table_nodes=tn)
    lane = jnp.asarray(rng.integers(0, 4, size=(tn, 6)), jnp.int32)
    tagged, _ = bridge.pull_pages(pool, want, table, mesh=None, budget=3,
                                  table_nodes=tn, collect_telemetry=True,
                                  tenant_ids=lane)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(tagged))


def test_tenant_lane_shape_mismatch_raises():
    tn, ppn = 2, 4
    pool = make_pool(tn * ppn, 2)
    table = striped_table(4, tn, ppn)
    want = jnp.zeros((tn, 3), jnp.int32)
    with pytest.raises(ValueError, match="tenant_ids"):
        bridge.pull_pages(pool, want, table, mesh=None, budget=2,
                          table_nodes=tn, collect_telemetry=True,
                          tenant_ids=jnp.zeros((tn, 4), jnp.int32))


# ---------------------------------------------------------------------------
# Aggregator tenant views
# ---------------------------------------------------------------------------

def test_aggregator_tenant_views():
    n = 4
    table = striped_table(16, n, 8)
    rng = np.random.default_rng(3)
    want = rng.integers(0, 16, size=(n, 6)).astype(np.int32)
    lane = (np.arange(6)[None, :] % 2 * np.ones((n, 1))).astype(np.int32)
    telem = ref.expected_transfer_telemetry(
        want, table, None, num_nodes=n, budget=2, active_budget=1,
        tenant_ids=lane)
    agg = TelemetryAggregator(n, page_bytes=128)
    agg.update(telem)
    served = np.asarray(telem.tenant_served).sum(0)
    spilled = np.asarray(telem.tenant_spilled).sum(0)
    np.testing.assert_allclose(agg.tenant_pages(), served)
    np.testing.assert_allclose(agg.tenant_bytes(), served * 128)
    np.testing.assert_allclose(agg.tenant_demand(), served + spilled)
    rate = agg.tenant_spill_rate()
    assert (rate >= 0).all() and (rate <= 1).all()
    assert "telemetry" in agg.describe()


def test_aggregator_rejects_tenant_width_mismatch():
    agg = TelemetryAggregator(2, max_tenants=2)
    telem = ref.expected_transfer_telemetry(
        np.zeros((2, 2), np.int32), striped_table(4, 2, 2), None,
        num_nodes=2, budget=2)          # default 4-wide histograms
    with pytest.raises(ValueError, match="tenants"):
        agg.update(telem)


# ---------------------------------------------------------------------------
# Orchestrator lifecycle (closed loop, host side)
# ---------------------------------------------------------------------------

def test_orchestrator_closed_loop_refit():
    """Measured per-tenant demand re-partitions the windows."""
    cp = ControlPlane(4, 16, num_logical=64)
    orc = Orchestrator(cp, budget=8, control_period=1, migrate=False)
    orc.register(TenantSpec(0, "chat", qos="interactive", share=1.0))
    orc.register(TenantSpec(1, "crawl", qos="batch", share=1.0))
    _, l0 = orc.request_lease(0, 8)
    _, l1 = orc.request_lease(1, 32)
    assert l0 is not None and l1 is not None
    # chat offers 1 page/node, crawl floods (spills under any window)
    backlogs = {0: [[int(l0.region.page_ids[i])] for i in range(4)],
                1: [np.asarray(l1.region.page_ids[i * 8:(i + 1) * 8],
                               np.int64).tolist() for i in range(4)]}
    want, lane, _ = orc.compose_requests(backlogs)
    telem = ref.expected_transfer_telemetry(
        want, orc.table(), orc.route_program(), num_nodes=4, budget=8,
        active_budget=int(orc.active_budget()[0]), tenant_ids=lane)
    rep = orc.step(telem)
    assert rep["refit"] is True
    # chat demand-capped at ~1/node; crawl work-conservingly takes the rest
    assert orc.schedule.windows[0] >= 1
    assert orc.schedule.windows[1] > orc.schedule.windows[0]
    assert orc.schedule.total_window <= 8
    assert "orchestrator" in orc.describe()


def test_refit_survives_idle_period():
    """An all-idle control period must not pin every window to zero.

    Measured zero demand as a hard cap would livelock: a zero window
    serves nothing, so the next measurement is zero again and the window
    never reopens.  The re-fit floors each tenant's bid at one lane.
    """
    cp = ControlPlane(4, 16, num_logical=64)
    orc = Orchestrator(cp, budget=8, control_period=1, migrate=False)
    orc.register(TenantSpec(0, "a", qos="interactive"))
    orc.register(TenantSpec(1, "b", qos="batch"))
    orc.request_lease(0, 8)
    idle = ref.expected_transfer_telemetry(
        np.full((4, 2), FREE, np.int32), orc.table(), None, num_nodes=4,
        budget=8)
    orc.step(idle)
    assert all(w >= 1 for w in orc.schedule.windows.values())
    assert orc.schedule.active_budget(4).min() >= 1
    # ...and a saturated window (fully consumed) re-bids as unbounded
    _, lease = orc.request_lease(1, 32)
    backlogs = {0: [[] for _ in range(4)],
                1: [np.asarray(lease.region.page_ids[i * 8:(i + 1) * 8],
                               np.int64).tolist() for i in range(4)]}
    want, lane, taken = orc.compose_requests(backlogs)
    assert taken[1] == orc.schedule.windows[1]    # clipped by its window
    telem = ref.expected_transfer_telemetry(
        want, orc.table(), orc.route_program(), num_nodes=4, budget=8,
        active_budget=int(orc.active_budget()[0]), tenant_ids=lane)
    orc.step(telem)
    assert orc.schedule.windows[1] > 1            # grew past the idle floor


def test_request_lease_queue_false_rejects():
    cp = ControlPlane(2, 2, num_logical=8)
    orc = Orchestrator(cp, budget=4)
    orc.register(TenantSpec(0, "a"))
    dec, lease = orc.request_lease(0, 100, queue=False)
    assert dec.status == REJECTED and lease is None
    assert len(orc.admission.pending) == 0


def test_orchestrator_board_affinity_placement():
    topo = Topology.boards(2, 4)
    cp = ControlPlane(8, 8, num_logical=64, topology=topo)
    orc = Orchestrator(cp, budget=8)
    orc.register(TenantSpec(0, "a"))
    orc.register(TenantSpec(1, "b"))
    _, la = orc.request_lease(0, 12)
    _, lb = orc.request_lease(1, 12)
    group = np.asarray(topo.group)
    home_col = np.asarray(cp.table().home)
    homes_a = {int(group[int(home_col[p])]) for p in la.region.page_ids}
    homes_b = {int(group[int(home_col[p])]) for p in lb.region.page_ids}
    assert homes_a == {0}                  # tenant 0 anchored to board 0
    assert homes_b == {1}                  # tenant 1 to board 1
