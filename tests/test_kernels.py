"""Per-kernel allclose tests against the pure-jnp oracles (interpret mode).

Shape/dtype sweeps per the assignment: every Pallas kernel is validated over
a grid of shapes and dtypes, plus hypothesis property tests on the paged
kernel's page-table indirection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def rand(shape, dtype):
    x = RNG.normal(size=shape)
    return jnp.asarray(x, dtype)


# -- STREAM -------------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 1024, 128 * 256, 128 * 1000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_kernels(n, dtype):
    tol = dict(atol=1e-6) if dtype == jnp.float32 else dict(atol=5e-2)
    a, b, c = (rand((n,), dtype) for _ in range(3))
    np.testing.assert_allclose(
        np.asarray(ops.stream_copy(c), np.float32),
        np.asarray(ref.stream_copy_ref(c), np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(ops.stream_scale(c, 3.0), np.float32),
        np.asarray(ref.stream_scale_ref(c, 3.0), np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(ops.stream_add(a, b), np.float32),
        np.asarray(ref.stream_add_ref(a, b), np.float32), **tol)
    np.testing.assert_allclose(
        np.asarray(ops.stream_triad(b, c, 3.0), np.float32),
        np.asarray(ref.stream_triad_ref(b, c, 3.0), np.float32), **tol)


def test_stream_block_rows_sweep():
    c = rand((128 * 64,), jnp.float32)
    for rows in (8, 16, 64):
        np.testing.assert_allclose(
            np.asarray(ops.stream_copy(c, block_rows=rows)), np.asarray(c))


# -- flash attention -------------------------------------------------------------

@pytest.mark.parametrize("b,sq,sk,h,kv,hd", [
    (1, 128, 128, 4, 4, 64),       # MHA square
    (2, 128, 256, 8, 2, 64),       # GQA, longer K
    (1, 256, 128, 4, 1, 128),      # MQA, q longer than k
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_shapes(b, sq, sk, h, kv, hd, dtype):
    q = rand((b, sq, h, hd), dtype)
    k = rand((b, sk, kv, hd), dtype)
    v = rand((b, sk, kv, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=True, bq=128, bk=128)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


@pytest.mark.parametrize("window", [0, 64, 100])
def test_flash_kernel_sliding_window(window):
    q = rand((1, 256, 4, 64), jnp.float32)
    k = rand((1, 256, 2, 64), jnp.float32)
    v = rand((1, 256, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              bq=128, bk=128)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)


def test_flash_kernel_q_offset_decode_chunk():
    """Prefill continuation: q block at absolute offset into the KV."""
    q = rand((1, 128, 4, 64), jnp.float32)
    k = rand((1, 384, 4, 64), jnp.float32)
    v = rand((1, 384, 4, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, q_offset=256,
                              bq=128, bk=128)
    exp = ref.flash_attention_ref(q, k, v, causal=True, q_offset=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)


def test_flash_kernel_unaligned_seq():
    """Sk not a multiple of bk exercises the padding/masking path."""
    q = rand((1, 100, 4, 64), jnp.float32)
    k = rand((1, 200, 4, 64), jnp.float32)
    v = rand((1, 200, 4, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, bq=64, bk=128)
    exp = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)


# -- paged decode attention -------------------------------------------------------

def make_paged(b, max_pages, t, kv, hd, lengths, seed=0):
    rng = np.random.default_rng(seed)
    slots = b * max_pages + 3
    k_pool = rng.normal(size=(slots, t, kv, hd)).astype(np.float32)
    v_pool = rng.normal(size=(slots, t, kv, hd)).astype(np.float32)
    # random permutation placement: logical (b, p) -> random distinct slot
    perm = rng.permutation(slots)[: b * max_pages].reshape(b, max_pages)
    return (jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(perm, jnp.int32), jnp.asarray(lengths, jnp.int32))


@pytest.mark.parametrize("h,kv,hd", [(8, 8, 64), (8, 2, 64), (4, 1, 128)])
def test_paged_kernel_gqa(h, kv, hd):
    b, mp, t = 3, 4, 16
    lengths = np.array([64, 33, 16])
    k_pool, v_pool, table, ln = make_paged(b, mp, t, kv, hd, lengths)
    q = rand((b, h, hd), jnp.float32)
    got = ops.paged_attention(q, k_pool, v_pool, table, ln, max_pages=mp)
    exp = ref.paged_attention_ref(q, k_pool, v_pool, table, ln, max_pages=mp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000),
       lengths=st.lists(st.integers(0, 64), min_size=2, max_size=2))
def test_paged_kernel_property(seed, lengths):
    """Random placements and ragged lengths always match the oracle."""
    b, mp, t, h, kv, hd = 2, 4, 16, 4, 2, 64
    k_pool, v_pool, table, ln = make_paged(b, mp, t, kv, hd,
                                           np.array(lengths), seed)
    rng = np.random.default_rng(seed + 1)
    q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
    got = ops.paged_attention(q, k_pool, v_pool, table, ln, max_pages=mp)
    exp = ref.paged_attention_ref(q, k_pool, v_pool, table, ln, max_pages=mp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)


def test_paged_kernel_bf16_pool():
    b, mp, t, h, kv, hd = 2, 3, 8, 4, 4, 64
    k_pool, v_pool, table, ln = make_paged(b, mp, t, kv, hd, [24, 17])
    k_pool = k_pool.astype(jnp.bfloat16)
    v_pool = v_pool.astype(jnp.bfloat16)
    q = rand((b, h, hd), jnp.bfloat16)
    got = ops.paged_attention(q, k_pool, v_pool, table, ln, max_pages=mp)
    exp = ref.paged_attention_ref(q, k_pool, v_pool, table, ln, max_pages=mp)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)
