"""The measurement plane: in-band counters + aggregation + closed loop.

Covers the telemetry acceptance contract:

* counters exactly match the oracle's per-request walk for arbitrary
  programs, budgets and placements (single-process loopback here; the 8-way
  ring re-checks in tests/distributed/run_bridge_8dev.py),
* collection is a zero-retrace runtime output: swapping programs / tables /
  budgets with collection on hits the same jit cache entry,
* counters are deterministic under jit and scan and bit-identical between
  ``edge_buffer`` modes,
* the closed loop works: measured skew -> load-balanced program with lower
  predicted round latency than static bidirectional; observed spills ->
  adapted rate limits -> zero spills.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from topologies import (TELEM_FIELDS, assert_telem_equal, fake_telem,
                        make_pool)

from repro.core import bridge, perfmodel, ref, steering
from repro.core.control_plane import ControlPlane
from repro.core.memport import FREE, MemPortTable
from repro.telemetry import (BridgeTelemetry, TelemetryAggregator,
                             counters as tcounters)

_fake_telem = fake_telem  # shared fixture (tests/topologies.py)


# ---------------------------------------------------------------------------
# Counter correctness vs the oracle
# ---------------------------------------------------------------------------

def test_pull_telemetry_matches_oracle_loopback():
    tn, ppn, budget = 4, 8, 4
    pool = make_pool(tn * ppn, 4)
    table = MemPortTable.striped(12, tn, ppn)
    want = jnp.asarray(np.arange(12, dtype=np.int32)[None, :])
    for prog in (steering.bidirectional_program(tn),
                 steering.unidirectional_program(tn),
                 steering.pruned_program(steering.bidirectional_program(tn),
                                         [1, 3])):
        out, telem = bridge.pull_pages(pool, want, table, mesh=None,
                                       budget=budget, table_nodes=tn,
                                       program=prog, collect_telemetry=True)
        exp = ref.expected_transfer_telemetry(want, table, prog, num_nodes=tn,
                                             budget=budget)
        assert_telem_equal(telem, exp)
        # ... and collection never changes the data path's result
        plain = bridge.pull_pages(pool, want, table, mesh=None, budget=budget,
                                  table_nodes=tn, program=prog)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))


def test_push_telemetry_matches_oracle_loopback():
    tn, ppn, budget = 4, 8, 4
    pool = make_pool(tn * ppn, 4)
    table = MemPortTable.striped(12, tn, ppn)
    dest = jnp.asarray(np.arange(12, dtype=np.int32)[None, :])
    payload = jnp.ones((1, 12, 4), jnp.float32)
    prog = steering.pruned_program(steering.bidirectional_program(tn), [2])
    _, telem = bridge.push_pages(pool, dest, payload, table, mesh=None,
                                 budget=budget, table_nodes=tn, program=prog,
                                 collect_telemetry=True)
    exp = ref.expected_transfer_telemetry(dest, table, prog, num_nodes=tn,
                                         budget=budget)
    assert_telem_equal(telem, exp)


def test_telemetry_counts_spills_and_unmapped():
    """FREE holes and unmapped pages are not live; throttled tails spill."""
    tn, ppn, budget = 2, 16, 4
    pool = make_pool(tn * ppn, 4)
    table = MemPortTable.empty(20).program(
        np.arange(10), np.zeros(10, np.int64), np.arange(10))
    # 12 requests: 2 FREE holes, 2 unmapped (ids 15, 16), 8 live
    want = jnp.asarray([[0, 1, FREE, 2, 15, 3, 4, FREE, 16, 5, 6, 7]],
                       jnp.int32)
    ab = jnp.int32(2)
    out, telem = bridge.pull_pages(pool, want, table, mesh=None,
                                   budget=budget, table_nodes=tn,
                                   active_budget=ab, collect_telemetry=True)
    exp = ref.expected_transfer_telemetry(want, table, None, num_nodes=tn,
                                         budget=budget, active_budget=2)
    assert_telem_equal(telem, exp)
    # rounds = 3, window = 6: live requests at idx >= 6 spill (4 of them)
    assert int(telem.spilled[0]) == 4
    assert int(telem.served_total()[0]) == 4  # idx<6 minus holes/unmapped
    # conservation: every live request is served, spilled or pruned
    live = 8
    assert (int(telem.served_total()[0]) + int(telem.spilled[0])
            + int(telem.pruned[0])) == live


def test_telemetry_identical_across_edge_buffer_modes():
    tn, ppn, budget = 4, 8, 3
    pool = make_pool(tn * ppn, 4)
    table = MemPortTable.striped(16, tn, ppn)
    want = jnp.asarray(
        np.random.default_rng(3).integers(-1, 16, size=(1, 10)), jnp.int32)
    prog = steering.bidirectional_program(tn)
    telems = []
    for eb in (True, False):
        _, t = bridge.pull_pages(pool, want, table, mesh=None, budget=budget,
                                 table_nodes=tn, program=prog,
                                 edge_buffer=eb, collect_telemetry=True)
        telems.append(t)
    assert_telem_equal(telems[0], telems[1], msg="edge_buffer ")


# ---------------------------------------------------------------------------
# Zero-retrace + determinism
# ---------------------------------------------------------------------------

def test_telemetry_collection_never_retraces_on_program_swap():
    from repro.core.topology import Topology
    tn, ppn, budget = 4, 8, 4
    topo = Topology.boards(2, 2)
    pool = make_pool(tn * ppn, 4)
    table = MemPortTable.striped(12, tn, ppn)
    want = jnp.asarray(np.arange(12, dtype=np.int32)[None, :])
    pull = jax.jit(functools.partial(
        bridge.pull_pages, mesh=None, budget=budget, table_nodes=tn,
        collect_telemetry=True, topology=topo))
    progs = [steering.bidirectional_program(tn),
             steering.unidirectional_program(tn),
             steering.pruned_program(steering.bidirectional_program(tn), [2]),
             steering.link_avoiding_program(tn, +1),
             steering.hierarchical_program(topo)]
    for prog in progs:
        for ab in (4, 2):
            out, telem = pull(pool, want, table, program=prog,
                              active_budget=jnp.int32(ab))
            exp = ref.expected_transfer_telemetry(
                want, table, prog, num_nodes=tn, budget=budget,
                active_budget=ab, topology=topo)
            assert_telem_equal(telem, exp, msg=f"ab={ab} ")
    # swapping programs (flat AND hierarchical) / budgets / tables with
    # collection on: one trace
    t2 = MemPortTable.striped(12, tn, ppn).program(
        np.array([0]), np.array([2]), np.array([7]))
    pull(pool, want, t2, program=progs[0], active_budget=jnp.int32(3))
    assert pull._cache_size() == 1, pull._cache_size()


def test_telemetry_deterministic_under_jit_and_scan():
    tn, ppn, budget = 4, 8, 4
    pool = make_pool(tn * ppn, 4)
    table = MemPortTable.striped(12, tn, ppn)
    want = jnp.asarray(np.arange(12, dtype=np.int32)[None, :])
    prog = steering.bidirectional_program(tn)

    def one(carry, _):
        _out, t = bridge.pull_pages(pool, want, table, mesh=None,
                                    budget=budget, table_nodes=tn,
                                    program=prog, collect_telemetry=True)
        return carry, t

    @jax.jit
    def steps(n_unused):
        _, ts = jax.lax.scan(one, 0, None, length=3)
        return ts

    ts = steps(0)
    _, single = bridge.pull_pages(pool, want, table, mesh=None, budget=budget,
                                  table_nodes=tn, program=prog,
                                  collect_telemetry=True)
    for name in TELEM_FIELDS:
        stacked = np.asarray(getattr(ts, name))
        expect = np.asarray(getattr(single, name))
        for i in range(3):  # every scan iteration bit-identical
            np.testing.assert_array_equal(stacked[i], expect, err_msg=name)


# ---------------------------------------------------------------------------
# Aggregator
# ---------------------------------------------------------------------------

def test_aggregator_ewma_and_views():
    n = 4
    agg = TelemetryAggregator(n, page_bytes=64, alpha=0.5)
    t1 = _fake_telem(n, np.asarray([[2, 4, 0, 0]] * n))
    agg.update(t1)
    np.testing.assert_allclose(agg.traffic_matrix(), [[2, 4, 0, 0]] * n)
    # distance histogram: every requester sends 4 pages at distance 1 mod
    # placement; just check totals & bytes scaling
    assert agg.distance_pages().sum() > 0
    np.testing.assert_allclose(agg.distance_bytes(),
                               agg.distance_pages() * 64)
    t2 = _fake_telem(n, np.asarray([[4, 0, 0, 0]] * n))
    agg.update(t2)
    # EWMA with alpha=0.5: halfway between the two steps
    np.testing.assert_allclose(agg.traffic_matrix(), [[3, 2, 0, 0]] * n)
    assert agg.steps == 2
    util = agg.link_utilization()
    assert 0 <= util["cw"] <= 1 and 0 <= util["ccw"] <= 1
    r, share = agg.dominant_requester(1)
    assert r != 1 and 0 <= share <= 1


def test_aggregator_spill_rate_and_rejects():
    n = 2
    agg = TelemetryAggregator(n)
    with pytest.raises(ValueError):
        TelemetryAggregator(n, alpha=0.0)
    agg.update(_fake_telem(n, np.asarray([[3, 1], [0, 4]]),
                           spilled=[4, 0]))
    rate = agg.spill_rate()
    assert rate[0] == pytest.approx(0.5)
    assert rate[1] == 0.0
    with pytest.raises(ValueError):
        agg.update(_fake_telem(4, np.zeros((4, 4))))


def test_aggregator_accepts_loopback_rows():
    """Loopback telemetry (1 row) folds into row 0 of an N-node aggregate."""
    tn, ppn = 4, 8
    pool = make_pool(tn * ppn, 4)
    table = MemPortTable.striped(12, tn, ppn)
    want = jnp.asarray(np.arange(12, dtype=np.int32)[None, :])
    _, telem = bridge.pull_pages(pool, want, table, mesh=None, budget=4,
                                 table_nodes=tn, collect_telemetry=True)
    agg = TelemetryAggregator(tn)
    agg.update(telem)
    assert agg.traffic_matrix()[0].sum() == 12
    assert agg.traffic_matrix()[1:].sum() == 0
    assert agg.live_distances() == [1, 2, 3]


# ---------------------------------------------------------------------------
# The closed loop
# ---------------------------------------------------------------------------

def test_load_balanced_program_beats_static_under_skew():
    """Acceptance: measured skew -> load-balanced direction assignment with
    strictly lower predicted round latency than static bidirectional."""
    n, budget, page_bytes = 8, 8, 1 << 18
    w = np.array([6.0, 3.0, 2.0, 0, 0, 0, 0])   # skew: near distances only
    lb = steering.load_balanced_program(n, w)
    lb.validate()
    bi = steering.bidirectional_program(n)
    lat_lb = perfmodel.predict_round_latency_us(lb, page_bytes, budget,
                                                slot_pages=w)
    lat_bi = perfmodel.predict_round_latency_us(bi, page_bytes, budget,
                                                slot_pages=w)
    assert lat_lb < lat_bi
    # the balanced split's bottleneck direction moves fewer bytes
    def direction_loads(p):
        off, live = np.asarray(p.offsets), np.asarray(p.live)
        return (w[live & (off > 0)].sum(), w[live & (off < 0)].sum())
    assert max(direction_loads(lb)) < max(direction_loads(bi))


def test_route_program_from_measured_telemetry():
    """Pruning follows measurement, not placement reachability."""
    n, ppn = 4, 8
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=32)
    cp.allocate(16, policy="striped")      # placement reaches distances 1-3
    agg = TelemetryAggregator(n)
    # ... but traffic only ever crossed distance 2
    traffic = np.zeros((n, n), np.int32)
    for i in range(n):
        traffic[i, (i + 2) % n] = 5
    agg.update(_fake_telem(n, traffic))
    prog = cp.route_program(telemetry=agg)
    assert list(prog.live_distances()) == [2]
    # placement-based compile still sees 1-3
    assert list(cp.route_program().live_distances()) == [1, 2, 3]
    # an empty measurement falls back to placement
    assert list(cp.route_program(
        telemetry=TelemetryAggregator(n)).live_distances()) == [1, 2, 3]


def test_censored_measurement_does_not_prune():
    """A measurement taken while requests were dropped (spilled or pruned)
    is blind to the demand it dropped: no distance may be pruned from it.
    After a clean (drop-free) measurement, pruning resumes."""
    n = 4
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=32)
    cp.allocate(16, policy="striped")
    traffic = np.zeros((n, n), np.int32)
    for i in range(n):
        traffic[i, (i + 1) % n] = 5      # only d=1 got *served*...
    agg = TelemetryAggregator(n)
    agg.update(_fake_telem(n, traffic, spilled=[3, 0, 0, 0]))
    prog = cp.route_program(telemetry=agg)
    # ...but the spills hide real demand: everything stays wired
    assert list(prog.live_distances()) == [1, 2, 3]
    prog.validate()
    # clean measurement -> measured pruning resumes
    agg2 = TelemetryAggregator(n)
    agg2.update(_fake_telem(n, traffic))
    assert list(cp.route_program(telemetry=agg2).live_distances()) == [1]


def test_route_program_telemetry_respects_link_failure():
    n = 4
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=32)
    cp.allocate(16, policy="striped")
    agg = TelemetryAggregator(n)
    traffic = np.zeros((n, n), np.int32)
    for i in range(n):
        traffic[i, (i + 1) % n] = 5
        traffic[i, (i + 3) % n] = 5
    agg.update(_fake_telem(n, traffic))
    cp.report_link_failure(+1)
    prog = cp.route_program(telemetry=agg)
    off, live = np.asarray(prog.offsets), np.asarray(prog.live)
    assert (off[live] < 0).all()                    # cw fully avoided
    assert list(prog.live_distances()) == [1, 3]    # measured prune kept


def test_measure_recompile_drives_spills_to_zero():
    """Acceptance: one measure -> recompile iteration zeroes the spills."""
    tn, ppn, budget = 1, 32, 8
    pool = make_pool(tn * ppn, 4)
    cp = ControlPlane(num_nodes=tn, pages_per_node=ppn, num_logical=24)
    cp.allocate(24, policy="striped")
    table = cp.table()
    want = jnp.asarray(np.arange(24, dtype=np.int32)[None, :])
    # a straggler report throttled node 0 to budget 4 -> spills
    cp.nodes[0].step_times = [2.5] * 4
    limits = cp.rate_limits(budget)
    assert limits[0] == budget  # single node: it IS the median; pin manually
    throttled = jnp.int32(4)
    _, telem = bridge.pull_pages(pool, want, table, mesh=None, budget=budget,
                                 table_nodes=tn, active_budget=throttled,
                                 collect_telemetry=True)
    assert int(telem.spilled.sum()) > 0
    agg = TelemetryAggregator(tn)
    agg.update(telem)
    assert agg.spill_rate()[0] > 0
    # recompile: spill feedback restores the budget
    new_limits = cp.rate_limits(budget, telemetry=agg)
    _, telem2 = bridge.pull_pages(
        pool, want, table, mesh=None, budget=budget, table_nodes=tn,
        active_budget=jnp.int32(int(new_limits[0])), collect_telemetry=True)
    assert int(telem2.spilled.sum()) == 0


def test_affinity_migration_rehomes_hot_pages():
    n = 4
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=32)
    cp.allocate(8, policy="affinity", affinity=2)
    agg = TelemetryAggregator(n)
    traffic = np.zeros((n, n), np.int32)
    traffic[0, 2] = 12   # node 0 hammers pages homed on node 2
    traffic[2, 2] = 2
    agg.update(_fake_telem(n, traffic))
    plan = cp.affinity_migration(agg)
    assert plan and all(s.old_home == 2 and s.new_home == 0 for s in plan)
    # plan applied: pages now homed at the dominant requester
    homes = np.asarray(cp.table().home)
    assert (homes[[s.page_id for s in plan]] == 0).all()
    # the plan round-trips through the table like fail_node's plans
    assert len(plan) <= 8
    # below-threshold traffic migrates nothing
    agg2 = TelemetryAggregator(n)
    t2 = np.zeros((n, n), np.int32)
    t2[0, 1], t2[1, 1], t2[2, 1] = 2, 5, 2
    agg2.update(_fake_telem(n, t2))
    assert cp.affinity_migration(agg2) == []


def test_affinity_migration_skips_dead_homes():
    """A monitor-marked-dead node is no migration source: its data is gone
    and its vacated slots must stay quarantined (symmetric to release)."""
    n = 4
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=32)
    cp.allocate(8, policy="affinity", affinity=2)
    cp.nodes[2].alive = False
    cp._free[2] = []
    traffic = np.zeros((n, n), np.int32)
    traffic[0, 2] = 12
    agg = TelemetryAggregator(n)
    agg.update(_fake_telem(n, traffic))
    assert cp.affinity_migration(agg) == []
    assert cp.free_slots(2) == 0


def test_rate_limits_accepts_bare_bridge_telemetry():
    """One step's counters work directly, like route_program(telemetry=)."""
    tn, ppn, budget = 1, 32, 8
    pool = make_pool(tn * ppn, 4)
    cp = ControlPlane(num_nodes=tn, pages_per_node=ppn, num_logical=24)
    cp.allocate(24, policy="striped")
    want = jnp.asarray(np.arange(24, dtype=np.int32)[None, :])
    _, telem = bridge.pull_pages(pool, want, cp.table(), mesh=None,
                                 budget=budget, table_nodes=tn,
                                 active_budget=jnp.int32(4),
                                 collect_telemetry=True)
    assert int(telem.spilled.sum()) > 0
    np.testing.assert_array_equal(cp.rate_limits(budget, telemetry=telem),
                                  [budget])


def test_route_program_telemetry_honours_unidirectional():
    """bidirectional=False pins one direction even under measured steering;
    measured pruning still applies."""
    n = 4
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=32)
    cp.allocate(16, policy="striped")
    agg = TelemetryAggregator(n)
    traffic = np.zeros((n, n), np.int32)
    for i in range(n):
        traffic[i, (i + 3) % n] = 5
    agg.update(_fake_telem(n, traffic))
    prog = cp.route_program(telemetry=agg, bidirectional=False)
    off, live = np.asarray(prog.offsets), np.asarray(prog.live)
    assert (off[live] > 0).all()                 # one direction only
    assert list(prog.live_distances()) == [3]    # measured prune kept


def test_node_budget_manual_override():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=8)
    base = cp.rate_limits(8)
    np.testing.assert_array_equal(base, [8, 8, 8, 8])
    cp.nodes[1].budget = 3
    np.testing.assert_array_equal(cp.rate_limits(8), [8, 3, 8, 8])
    # the override wins over spill feedback
    agg = TelemetryAggregator(4)
    agg.update(_fake_telem(4, np.zeros((4, 4), np.int32),
                           spilled=[0, 5, 0, 0]))
    np.testing.assert_array_equal(cp.rate_limits(8, telemetry=agg),
                                  [8, 3, 8, 8])
    cp.nodes[1].budget = 0  # back to unlimited: spill feedback applies
    np.testing.assert_array_equal(cp.rate_limits(8, telemetry=agg),
                                  [8, 8, 8, 8])


# ---------------------------------------------------------------------------
# kvbridge / zero_bridge threading
# ---------------------------------------------------------------------------

def test_kvbridge_decode_pull_telemetry():
    from repro.core import kvbridge
    b, h, kv, hd, pt, mp = 2, 4, 2, 8, 4, 2
    cache = kvbridge.init_cache(1, b, pt * mp, pt, kv, hd, mesh=None,
                                dtype=jnp.float32)
    layer = jax.tree.map(lambda x: x[0], cache.layers)
    lengths = jnp.asarray([5, 4], jnp.int32)
    q = jnp.asarray(np.random.default_rng(0).normal(size=(b, h, hd)),
                    jnp.float32)
    out, telem = kvbridge.decode_attention_pull(
        q, layer, cache.table, lengths, page_tokens=pt, max_pages=mp,
        mesh=None, budget=4, collect_telemetry=True)
    plain = kvbridge.decode_attention_pull(
        q, layer, cache.table, lengths, page_tokens=pt, max_pages=mp,
        mesh=None, budget=4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))
    # both sequences have one flushed page; k and v pulls both counted
    assert int(telem.served_total().sum()) == 2 * 2


def test_kvbridge_append_telemetry():
    from repro.core import kvbridge
    b, kv, hd, pt, mp = 2, 2, 8, 4, 2
    cache = kvbridge.init_cache(1, b, pt * mp, pt, kv, hd, mesh=None,
                                dtype=jnp.float32)
    layer = jax.tree.map(lambda x: x[0], cache.layers)
    lengths = jnp.asarray([pt - 1, 1], jnp.int32)  # seq 0 at a page boundary
    k_new = jnp.ones((b, kv, hd), jnp.float32)
    new_layer, telem = kvbridge.append(
        layer, cache.table, lengths, k_new, k_new, page_tokens=pt,
        max_pages=mp, mesh=None, collect_telemetry=True)
    # one page flush (k + v) crossed the bridge
    assert int(telem.served_total().sum()) == 2
    plain = kvbridge.append(layer, cache.table, lengths, k_new, k_new,
                            page_tokens=pt, max_pages=mp, mesh=None)
    np.testing.assert_array_equal(np.asarray(new_layer.k_pool),
                                  np.asarray(plain.k_pool))


def test_zero_bridge_telemetry_roundtrip():
    from repro.core import zero_bridge
    tree = {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
            "b": jnp.ones((3,), jnp.float32)}
    store = zero_bridge.create_store(tree, mesh=None, page_elems=8)
    pulled, telem = zero_bridge.pull_tree(store, mesh=None,
                                          collect_telemetry=True)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), tree, pulled)
    assert int(telem.served_total().sum()) == store.packer.num_pages
    store2, telem_w = zero_bridge.push_tree(store, tree, mesh=None,
                                            collect_telemetry=True)
    assert int(telem_w.served_total().sum()) == store.packer.num_pages
    assert zero_bridge.with_program(store2, None).program is None


def test_serve_state_telemetry_accumulates():
    from repro.serve.cache_ops import BridgeCacheOps
    from repro.serve.step import collect_state_telemetry
    from repro.config import ModelConfig

    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=32,
                      head_dim=8)
    ops = BridgeCacheOps(mode="pull", max_len=8, page_tokens=2, mesh=None,
                         budget=4, collect_telemetry=True)
    shared = ops.init_shared(cfg, batch=2)
    st = ops.init_layer(cfg, batch=2)
    assert "telem" in st
    lengths = jnp.zeros((2,), jnp.int32)
    q = jnp.ones((2, 2, 8), jnp.float32)
    kv_new = jnp.ones((2, 2, 8), jnp.float32)
    for i in range(3):
        _, st = ops.append_and_attend(cfg, st, shared, lengths + i, q,
                                      kv_new, kv_new)
    total = collect_state_telemetry(st)
    assert total is not None
    # after 3 appends from length 0, seq tails crossed one page boundary
    # (page_tokens=2): k+v flush = 2 served pages in the cumulative counter
    assert int(total.served_total().sum()) >= 2
