"""Paper-validation tests: the analytical model must reproduce the published
prototype numbers (DESIGN.md §6.1).  These pins ARE the faithfulness check.
"""
import numpy as np
import pytest

from repro.core import perfmodel as pm


def test_rtt_matches_paper():
    assert abs(pm.PAPER_HW.rtt_ns - 800.0) < 1.0          # 134 cyc == 800 ns


def test_link_ceiling_matches_paper():
    assert pm.PAPER_HW.link_payload_mibps == pytest.approx(1280.0)


def test_copy_one_core_remote_matches_paper():
    bw = pm.stream_bandwidth_mibps("copy", 1, remote=True)
    assert bw == pytest.approx(562.0, rel=0.02)           # paper: 562 MiB/s


def test_copy_one_core_penalty_matches_paper():
    assert pm.penalty("copy", 1) == pytest.approx(0.47, abs=0.01)


def test_scale_penalty_matches_paper():
    assert pm.penalty("scale", 1) == pytest.approx(0.25, abs=0.01)


def test_link_saturates_beyond_two_cores():
    """Paper: 'beyond 2 CPUs the transceiver becomes the bottleneck'."""
    bw2 = pm.mem_bandwidth_mibps(pm.PAPER_HW, 2, remote=True)
    bw3 = pm.mem_bandwidth_mibps(pm.PAPER_HW, 3, remote=True)
    bw4 = pm.mem_bandwidth_mibps(pm.PAPER_HW, 4, remote=True)
    assert bw2 < pm.PAPER_HW.link_payload_mibps * 0.99
    assert bw3 == pytest.approx(pm.PAPER_HW.link_payload_mibps)
    assert bw4 == pytest.approx(pm.PAPER_HW.link_payload_mibps)


def test_flop_kernels_have_lower_penalty_than_copy():
    """The paper's balance argument: more FLOPs/byte -> lower penalty."""
    for kernel in ("scale", "add", "triad"):
        assert pm.penalty(kernel, 1) < pm.penalty("copy", 1)


def test_rtt_pipeline_sums_to_134():
    assert sum(pm.RTT_PIPELINE_CYCLES.values()) == 134


def test_stream_table_shape():
    t = pm.stream_table()
    assert set(t) == {"copy", "scale", "add", "triad"}
    for sides in t.values():
        assert len(sides["local"]) == 4 and len(sides["remote"]) == 4
        # local >= remote always
        assert all(l >= r for l, r in zip(sides["local"], sides["remote"]))


def test_tpu_projection_monotone_in_page_size():
    """Bigger pages amortize the hop latency -> more bandwidth."""
    small = pm.tpu_remote_page_bandwidth_gbps(1 << 14)
    big = pm.tpu_remote_page_bandwidth_gbps(1 << 20)
    assert big > small
    assert big <= pm.TPU_HW.ici_link_gbps
