"""Paper-validation tests: the analytical model must reproduce the published
prototype numbers (DESIGN.md §6.1).  These pins ARE the faithfulness check.

Plus the bridge-side accounting invariants: predicted bytes-per-round must
equal the ref oracle's summed bytes for every program variant (flat
uni/bi/pruned/load-balanced + hierarchical), and the tier-aware latency
model must degenerate to the classic flat model on a single board.
"""
import numpy as np
import pytest

from repro.core import perfmodel as pm
from repro.core import ref, steering
from repro.core.memport import MemPortTable
from repro.core.topology import Topology


def test_rtt_matches_paper():
    assert abs(pm.PAPER_HW.rtt_ns - 800.0) < 1.0          # 134 cyc == 800 ns


def test_link_ceiling_matches_paper():
    assert pm.PAPER_HW.link_payload_mibps == pytest.approx(1280.0)


def test_copy_one_core_remote_matches_paper():
    bw = pm.stream_bandwidth_mibps("copy", 1, remote=True)
    assert bw == pytest.approx(562.0, rel=0.02)           # paper: 562 MiB/s


def test_copy_one_core_penalty_matches_paper():
    assert pm.penalty("copy", 1) == pytest.approx(0.47, abs=0.01)


def test_scale_penalty_matches_paper():
    assert pm.penalty("scale", 1) == pytest.approx(0.25, abs=0.01)


def test_link_saturates_beyond_two_cores():
    """Paper: 'beyond 2 CPUs the transceiver becomes the bottleneck'."""
    bw2 = pm.mem_bandwidth_mibps(pm.PAPER_HW, 2, remote=True)
    bw3 = pm.mem_bandwidth_mibps(pm.PAPER_HW, 3, remote=True)
    bw4 = pm.mem_bandwidth_mibps(pm.PAPER_HW, 4, remote=True)
    assert bw2 < pm.PAPER_HW.link_payload_mibps * 0.99
    assert bw3 == pytest.approx(pm.PAPER_HW.link_payload_mibps)
    assert bw4 == pytest.approx(pm.PAPER_HW.link_payload_mibps)


def test_flop_kernels_have_lower_penalty_than_copy():
    """The paper's balance argument: more FLOPs/byte -> lower penalty."""
    for kernel in ("scale", "add", "triad"):
        assert pm.penalty(kernel, 1) < pm.penalty("copy", 1)


def test_rtt_pipeline_sums_to_134():
    assert sum(pm.RTT_PIPELINE_CYCLES.values()) == 134


def test_stream_table_shape():
    t = pm.stream_table()
    assert set(t) == {"copy", "scale", "add", "triad"}
    for sides in t.values():
        assert len(sides["local"]) == 4 and len(sides["remote"]) == 4
        # local >= remote always
        assert all(l >= r for l, r in zip(sides["local"], sides["remote"]))


def test_tpu_projection_monotone_in_page_size():
    """Bigger pages amortize the hop latency -> more bandwidth."""
    small = pm.tpu_remote_page_bandwidth_gbps(1 << 14)
    big = pm.tpu_remote_page_bandwidth_gbps(1 << 20)
    assert big > small
    assert big <= pm.TPU_HW.ici_link_gbps


# ---------------------------------------------------------------------------
# Bridge accounting invariants (byte conservation + tier model)
# ---------------------------------------------------------------------------

def _full_coverage_load(n, ppn):
    """Every requester asks one page at every ring distance 1..n-1.

    Striped placement (home = id % n) with a distinct page per (requester,
    distance): requester i's page for distance d is (i + d) % n + n * d.
    Offered load per distance is therefore exactly n pages.
    """
    table = MemPortTable.striped(n * ppn, n, ppn)
    want = np.stack([[(i + d) % n + n * d for d in range(1, n)]
                     for i in range(n)]).astype(np.int32)
    return table, want


def test_byte_conservation_all_program_variants():
    """Regression: ``perfmodel.predict_round_bytes`` == the ref oracle's
    summed wire bytes for every program variant.  The perfmodel counts from
    program liveness x offered load; the oracle walks each request — they
    must agree or the bench's bytes-per-round trajectory lies."""
    n, ppn, budget, page_bytes = 8, 16, 8, 1 << 18
    topo = Topology.boards(2, 4)
    table, want = _full_coverage_load(n, ppn)
    bi = steering.bidirectional_program(n)
    w = np.array([6.0, 3.0, 2.0, 0, 0, 0, 0])
    variants = {
        "uni": steering.unidirectional_program(n),
        "bi": bi,
        "pruned": steering.pruned_program(bi, [1, 2, 6]),
        "load_balanced": steering.load_balanced_program(n, w, prune=True),
        "hierarchical": steering.hierarchical_program(topo),
        "hier_pruned": steering.hierarchical_program(
            topo, live_distances=[1, 3, 5]),
        "hier_masked": steering.masked_ranks_program(
            steering.hierarchical_program(topo),
            np.broadcast_to(np.arange(n)[None, :] % 2 == 0, (n - 1, n))),
    }
    for name, prog in variants.items():
        # offered pages per slot = requesters the program actually serves
        # there (each offers exactly one page per distance)
        offered = prog.rank_served().sum(1).astype(float)
        telem = ref.expected_transfer_telemetry(
            want, table, prog, num_nodes=n, budget=budget, topology=topo)
        oracle_bytes = float(np.asarray(
            telem.slot_bytes(page_bytes)).sum())
        predicted = pm.predict_round_bytes(prog, page_bytes, budget,
                                           slot_pages=offered)
        assert predicted == oracle_bytes, (
            f"{name}: predicted {predicted} != oracle {oracle_bytes}")
    # worst-case accounting (no measured loads): live_slots x budget pages
    stats = pm.route_epoch_stats(bi)
    assert pm.predict_round_bytes(bi, page_bytes, budget) == (
        stats["live_slots"] * budget * page_bytes)


def test_flat_topology_matches_classic_model():
    """A single-board Topology with ICI constants reproduces the flat
    latency model bit-for-bit (same formula, same numbers)."""
    flat = Topology.flat(8, board_hop_us=pm.TPU_HW.ici_hop_latency_us,
                         board_link_gbps=pm.TPU_HW.ici_link_gbps)
    for prog in (steering.bidirectional_program(8),
                 steering.unidirectional_program(8)):
        for eb in (True, False):
            classic = pm.predict_round_latency_us(prog, 1 << 18, 8,
                                                  edge_buffer=eb)
            tiered = pm.predict_round_latency_us(prog, 1 << 18, 8,
                                                 edge_buffer=eb,
                                                 topology=flat)
            assert tiered == pytest.approx(classic)


def test_hierarchical_beats_flat_bi_under_intra_heavy_traffic():
    """Acceptance: on 2 boards x 4, the hierarchical program's modeled
    round latency beats flat bidirectional under intra-board-heavy
    traffic (topology-blind directions pay extra board hops; the
    hierarchical schedule drives every pair the short local way)."""
    topo = Topology.boards(2, 4)
    n = topo.num_nodes
    # intra-only load: every requester pulls one page from each board mate
    w = np.zeros((n - 1,))
    intra_frac = np.zeros((n - 1,))
    for k in range(n - 1):
        r = np.arange(n)
        intra = topo.pair_intra(r, (r + k + 1) % n)
        w[k] = intra.sum()
        intra_frac[k] = 1.0 if intra.any() else 0.0
    live = (np.nonzero(w > 0)[0] + 1).tolist()
    hier = steering.hierarchical_program(topo, live_distances=live)
    flat = steering.pruned_program(steering.bidirectional_program(n), live)
    kw = dict(slot_pages=w, topology=topo, slot_intra_pages=w)
    lat_hier = pm.predict_round_latency_us(hier, 1 << 18, 8, **kw)
    lat_flat = pm.predict_round_latency_us(flat, 1 << 18, 8, **kw)
    assert lat_hier < lat_flat
    # the hierarchical stats expose why: fewer board hops end to end for
    # the same coverage (the rack side is identical — both serve the same
    # board-crossing pairings, just at different epochs)
    sh = pm.hierarchical_route_stats(hier, topo)
    sf = pm.hierarchical_route_stats(flat, topo)
    assert sh["board_hops"] < sf["board_hops"]
    assert sh["rack_hops"] == sf["rack_hops"]


def test_rack_tier_asymmetry_penalizes_inter_board_pages():
    """Board-crossing pages ride the slow rack links: the same load costs
    more when it crosses boards than when it stays on-board."""
    topo = Topology.boards(2, 4)
    n = topo.num_nodes
    hier = steering.hierarchical_program(topo)
    w = np.full((n - 1,), 4.0)
    all_intra = pm.predict_round_latency_us(
        hier, 1 << 18, 8, slot_pages=w, topology=topo, slot_intra_pages=w)
    all_inter = pm.predict_round_latency_us(
        hier, 1 << 18, 8, slot_pages=w, topology=topo,
        slot_intra_pages=np.zeros_like(w))
    assert all_inter > all_intra


def test_pipelined_channels_degenerates_and_overlaps():
    """channels=1 must reproduce the classic serial model bit-for-bit;
    deeper pipelines monotonically shrink the round latency toward the
    fully-overlapped max(wire, RTT) floor — never below it."""
    page_bytes, budget = 1 << 18, 8
    for prog in (steering.bidirectional_program(8),
                 steering.unidirectional_program(8)):
        serial = pm.predict_round_latency_us(prog, page_bytes, budget)
        assert pm.predict_round_latency_us(prog, page_bytes, budget,
                                           channels=1) == serial
        prev = serial
        for c in (2, 4, 8, 64):
            cur = pm.predict_round_latency_us(prog, page_bytes, budget,
                                              channels=c)
            assert cur < prev
            prev = cur
        # the fully-overlapped floor: one term completely hidden
        assert prev > serial / 2
    # bufferless bridges cannot overlap: channels is ignored there
    bi = steering.bidirectional_program(8)
    nobuf = pm.predict_round_latency_us(bi, page_bytes, budget,
                                        edge_buffer=False)
    assert pm.predict_round_latency_us(bi, page_bytes, budget,
                                       edge_buffer=False,
                                       channels=4) == nobuf


def test_pipelined_channels_hierarchical_degenerates_and_overlaps():
    """The overlap term applies to the two-tier model identically:
    channels=1 is bit-for-bit the serial hierarchical model."""
    topo = Topology.boards(2, 4)
    hier = steering.hierarchical_program(topo)
    page_bytes, budget = 1 << 18, 8
    serial = pm.predict_round_latency_us(hier, page_bytes, budget,
                                         topology=topo)
    assert pm.predict_round_latency_us(hier, page_bytes, budget,
                                       topology=topo, channels=1) == serial
    prev = serial
    for c in (2, 4, 8):
        cur = pm.predict_round_latency_us(hier, page_bytes, budget,
                                          topology=topo, channels=c)
        assert cur < prev
        prev = cur
