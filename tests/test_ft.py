"""Fault-tolerance: elastic trainer recovery, heartbeats, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.control_plane import ControlPlane
from repro.ft.elastic import ElasticTrainer
from repro.ft.heartbeat import HeartbeatMonitor


def counting_step(state, batch):
    return {"x": state["x"] + batch["inc"]}, {"loss": 1.0 / (state["x"] + 1)}


def batches():
    while True:
        yield {"inc": jnp.asarray(1.0)}


def test_elastic_recovery_resumes_from_checkpoint(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=16)
    cp.allocate(8)
    trainer = ElasticTrainer(step_fn=counting_step, ckpt=ckpt, cp=cp,
                             ckpt_every=10)
    state = {"x": jnp.asarray(0.0)}
    state, hist = trainer.run(state, batches(), num_steps=30,
                              failure_schedule={17: 1})
    # failed at 17 -> restored to step 10 -> ran to 30: total = 30
    assert float(state["x"]) == 30.0
    kinds = [e.kind for e in trainer.events]
    assert kinds == ["node_lost", "restored"]
    # dead node's pages were re-homed
    assert not np.any(np.asarray(cp.table().home) == 1)
    assert not cp.nodes[1].alive


def test_failure_without_checkpoint_raises(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    trainer = ElasticTrainer(step_fn=counting_step, ckpt=ckpt, ckpt_every=100)
    state = {"x": jnp.asarray(0.0)}
    try:
        trainer.run(state, batches(), num_steps=10, failure_schedule={3: 0})
        raise AssertionError("expected RuntimeError")
    except RuntimeError as e:
        assert "no checkpoint" in str(e)


def test_heartbeat_detects_dead_node():
    mon = HeartbeatMonitor(num_nodes=3, timeout=10.0)
    for t in range(0, 30, 5):
        mon.beat(0, float(t))
        mon.beat(1, float(t))
        if t < 10:
            mon.beat(2, float(t))
    dead = mon.tick(30.0)
    assert dead == [2]
    assert mon.tick(31.0) == []  # reported once


def test_straggler_rate_limit_integration(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=8)
    trainer = ElasticTrainer(step_fn=counting_step, ckpt=ckpt, cp=cp,
                             ckpt_every=50)
    # synthetic telemetry: node 3 is 3x slower
    for _ in range(8):
        for n in range(4):
            cp.record_step_time(n, 0.1 if n != 3 else 0.3)
    budgets = trainer.rate_limits(static_budget=8)
    assert list(budgets) == [8, 8, 8, 4]


def test_elastic_scaling_revive_node():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=16)
    cp.allocate(12)
    cp.fail_node(2)
    assert 2 not in cp.alive_nodes
    cp.revive_node(2)
    assert 2 in cp.alive_nodes
    # new allocations can land on the revived node again
    region = cp.allocate(4, policy="affinity", affinity=2)
    homes = np.asarray(cp.table().home)[region.page_ids]
    assert np.all(homes == 2)
