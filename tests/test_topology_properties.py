"""Oracle-driven topology conformance suite (randomized).

Random board + rack fabrics (1-4 boards of 2-8 endpoints, ragged sizes,
random dead slots / group-masked pairings) must satisfy the hierarchical
scheduling contract:

* every live (requester, home) pair is served **exactly once** — by the
  slot of its ring distance, at exactly one epoch;
* no two slots target one gateway in the same epoch (board-crossing
  circuits get exclusive epochs), and board-ring links host at most one
  circuit per direction per epoch;
* the datapath's ``collect_telemetry`` counters — including the per-tier
  occupancy — match :func:`repro.core.ref.expected_transfer_telemetry`
  bit-exactly, and every live request is conserved (served + spilled +
  pruned).

Real hypothesis when installed, the seeded fallback otherwise (same
convention as test_bridge_properties.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from topologies import TELEM_FIELDS, make_pool, random_fabric

from repro.core import bridge, ref, steering
from repro.core.memport import MemPortTable
from repro.core.topology import Topology

pytestmark = pytest.mark.property


def _random_hier_program(rng, topo):
    """A hierarchical program with random dead slots / masked pairings."""
    n = topo.num_nodes
    full = steering.hierarchical_program(topo)
    roll = rng.random()
    if roll < 0.4:
        return full
    if roll < 0.7:  # random dead distances
        keep = [d for d in range(1, n) if rng.random() < 0.7]
        if not keep:
            keep = [1]
        return steering.hierarchical_program(topo, live_distances=keep)
    # random group-mask: kill random (slot, rank) pairings
    mask = rng.random((n - 1, n)) < 0.8
    return steering.masked_ranks_program(full, mask)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_hierarchical_schedule_conformance(seed):
    """Exactly-once coverage + gateway exclusivity on random fabrics."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n = topo.num_nodes
    prog = steering.hierarchical_program(topo)
    prog.validate()
    steering.validate_hierarchical(prog, topo)

    # Full program: every remote (requester, home) pair is served exactly
    # once — its distance's slot wires it at exactly one epoch.
    served = prog.rank_served()
    assert served.all(), "full hierarchical program must cover every pair"
    re = np.asarray(prog.rank_epoch)
    assert (re[served] >= 0).all()
    # ... and never beyond the static epoch-bin bound (the telemetry
    # histograms must never clip).
    from repro.telemetry.counters import num_epoch_bins
    assert prog.num_epochs() <= num_epoch_bins(n)

    # Gateway exclusivity, asserted directly (not only via the validator):
    # in any epoch the set of slots carrying board-crossing pairs is <= 1.
    r = np.arange(n)
    for e in np.unique(re[re >= 0]):
        inter_slots = set()
        for k in range(n - 1):
            ranks = np.nonzero(served[k] & (re[k] == e))[0]
            if ranks.size == 0:
                continue
            if (~topo.pair_intra(ranks, (ranks + k + 1) % n)).any():
                inter_slots.add(k)
        assert len(inter_slots) <= 1, (e, inter_slots)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pruned_hierarchical_conformance(seed):
    """Random dead slots / masked pairings stay sound and cover exactly
    what they keep."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    prog = _random_hier_program(rng, topo)
    prog.validate()
    steering.validate_hierarchical(prog, topo)
    served = prog.rank_served()
    live = np.asarray(prog.live)
    # a dead slot serves nobody; a live slot serves someone
    assert not served[~live].any()
    assert served[live].any(axis=1).all()


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    budget=st.integers(1, 6),
    active_budget=st.integers(1, 6),
)
def test_hierarchical_telemetry_matches_oracle(seed, budget, active_budget):
    """Datapath counters == oracle walk, bit-exactly, on random fabrics,
    programs, throttles and request lists (dups, FREE holes, unmapped)."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    tn, ppn = topo.num_nodes, 8
    pool = make_pool(tn * ppn, 4, seed)
    num_logical = int(rng.integers(1, tn * ppn + 1))
    table = MemPortTable.striped(num_logical, tn, ppn)
    r = int(rng.integers(1, 16))
    want = rng.integers(-1, num_logical, size=(1, r)).astype(np.int32)
    prog = _random_hier_program(rng, topo)
    got, telem = bridge.pull_pages(
        pool, jnp.asarray(want), table, mesh=None, budget=budget,
        active_budget=jnp.int32(active_budget), table_nodes=tn,
        program=prog, topology=topo, collect_telemetry=True)
    exp = ref.expected_transfer_telemetry(
        want, table, prog, num_nodes=tn, budget=budget,
        active_budget=active_budget, topology=topo)
    for name in TELEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(telem, name)), np.asarray(getattr(exp, name)),
            err_msg=name)
    # conservation: every live request is served, spilled or pruned
    home = np.asarray(table.home)
    live = int(((want >= 0) & (home[np.clip(want, 0, None)] >= 0)).sum())
    total = (int(np.asarray(telem.served_total()).sum())
             + int(np.asarray(telem.spilled).sum())
             + int(np.asarray(telem.pruned).sum()))
    assert total == live
    # the gathered pages match the program-aware pull oracle too
    served = ref.rate_limit_mask(r, budget, active_budget)
    masked = jnp.asarray(np.where(served[None, :], want, -1))
    expp = ref.pull_pages_ref(pool, masked, table, pages_per_node=ppn,
                              program=prog)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expp))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_tier_hop_bounds(seed):
    """Realized hop counts respect the fabric: board hops < its board's
    size, rack hops < the board count, flat fabrics never touch the rack."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n = topo.num_nodes
    req = rng.integers(0, n, size=(64,))
    home = rng.integers(0, n, size=(64,))
    sign = np.where(rng.random(64) < 0.5, 1, -1)
    bh, rh = topo.pair_hops(req, home, sign)
    sizes = topo.group_sizes
    assert (bh >= 0).all() and (rh >= 0).all()
    # every board's gateway is its local rank 0
    for gid in range(topo.num_groups):
        gw = topo.gateway_rank(gid)
        assert topo.group[gw] == gid and topo.local_rank[gw] == 0
    assert (rh < max(topo.num_groups, 1)).all()
    intra = topo.pair_intra(req, home)
    assert (bh[intra] < sizes[topo.group[req[intra]]]).all()
    assert (rh[intra] == 0).all()
    # inter legs: at most half of each board ring per leg
    legs = sizes[topo.group[req]] // 2 + sizes[topo.group[home]] // 2
    assert (bh[~intra] <= legs[~intra]).all()
    # loopback pairs cost nothing
    bh0, rh0 = topo.pair_hops(req, req, sign)
    assert (bh0 == 0).all() and (rh0 == 0).all()


def test_pruned_program_preserves_hierarchical_group_mask():
    """Regression: the PR-1 pruning entry point on a hierarchical base must
    keep the per-rank schedule (re-packing one-circuit-per-direction would
    put two board-crossing circuits on one gateway epoch)."""
    topo = Topology.boards(2, 4)
    base = steering.hierarchical_program(topo)
    p = steering.pruned_program(base, [1, 2, 4, 6])
    p.validate()
    steering.validate_hierarchical(p, topo)   # gateway exclusivity survives
    assert list(p.live_distances()) == [1, 2, 4, 6]
    re_base = np.asarray(base.rank_epoch)
    re_p = np.asarray(p.rank_epoch)
    for d in (1, 2, 4, 6):                    # surviving masks untouched
        np.testing.assert_array_equal(re_p[d - 1], re_base[d - 1])
    # flat bases keep the historic compaction behavior
    flat = steering.pruned_program(steering.bidirectional_program(8), [2, 5, 7])
    assert flat.num_epochs() == 2


def test_flat_fabric_degenerates_to_bidirectional():
    """One board: the hierarchical compile IS the flat bidirectional one."""
    for n in (2, 3, 5, 8):
        h = steering.hierarchical_program(Topology.flat(n))
        bi = steering.bidirectional_program(n)
        np.testing.assert_array_equal(np.asarray(h.offsets),
                                      np.asarray(bi.offsets))
        np.testing.assert_array_equal(np.asarray(h.epoch),
                                      np.asarray(bi.epoch))
        np.testing.assert_array_equal(np.asarray(h.rank_epoch),
                                      np.asarray(bi.rank_epoch))


def test_control_plane_compiles_hierarchical_programs():
    """A topology-aware control plane's route_program is a valid two-tier
    schedule; measured steering prunes by measurement and weighs the
    direction vote by the measured tier split; the censorship guard holds."""
    from topologies import fake_telem
    from repro.core.control_plane import ControlPlane
    from repro.telemetry import TelemetryAggregator

    topo = Topology.boards(2, 2)
    n = topo.num_nodes
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=32,
                      topology=topo)
    cp.allocate(16, policy="striped")
    prog = cp.route_program()
    steering.validate_hierarchical(prog, topo)
    assert list(prog.live_distances()) == [1, 2, 3]
    # measured: only distance 2 carried traffic -> pruned to it
    agg = TelemetryAggregator(n)
    traffic = np.zeros((n, n), np.int32)
    for i in range(n):
        traffic[i, (i + 2) % n] = 5
    agg.update(fake_telem(n, traffic))
    measured = cp.route_program(telemetry=agg)
    steering.validate_hierarchical(measured, topo)
    assert list(measured.live_distances()) == [2]
    # censored measurement (spills): nothing may be pruned
    agg2 = TelemetryAggregator(n)
    agg2.update(fake_telem(n, traffic, spilled=[3, 0, 0, 0]))
    censored = cp.route_program(telemetry=agg2)
    assert list(censored.live_distances()) == [1, 2, 3]
    # a failed ring link still falls back to the flat link-avoiding compile
    cp.report_link_failure(+1)
    avoid = cp.route_program()
    off = np.asarray(avoid.offsets)
    assert (off[np.asarray(avoid.live)] < 0).all()


def test_affinity_migration_prefers_intra_board_homes():
    """Once the dominant requester is full, cross-board pages keep moving
    into its board mates (rack traffic becomes board traffic)."""
    from topologies import fake_telem
    from repro.core.control_plane import ControlPlane
    from repro.telemetry import TelemetryAggregator

    topo = Topology.boards(2, 2)   # board 0 = {0, 1}, board 1 = {2, 3}
    n, ppn = topo.num_nodes, 4
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=32,
                      topology=topo)
    cp.allocate(ppn, policy="affinity", affinity=0)   # node 0 full
    hot = cp.allocate(ppn, policy="affinity", affinity=2)
    agg = TelemetryAggregator(n)
    traffic = np.zeros((n, n), np.int32)
    traffic[0, 2] = 12                                 # node 0 hammers node 2
    agg.update(fake_telem(n, traffic))
    plan = cp.affinity_migration(agg)
    assert plan, "hot pages must migrate"
    # node 0 has no free slots: pages land on its board mate, node 1
    assert all(s.old_home == 2 and s.new_home == 1 for s in plan)
    homes = np.asarray(cp.table().home)[hot.page_ids]
    assert set(homes.tolist()) <= {1}
    # same-board domination migrates only into the requester itself: node 3
    # dominating node-2 pages must NOT shuffle them to other board-1 slots
    cp2 = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=32,
                       topology=topo)
    cp2.allocate(ppn, policy="affinity", affinity=3)  # node 3 full
    cp2.allocate(ppn, policy="affinity", affinity=2)
    t2 = np.zeros((n, n), np.int32)
    t2[3, 2] = 12
    agg2 = TelemetryAggregator(n)
    agg2.update(fake_telem(n, t2))
    assert cp2.affinity_migration(agg2) == []


def test_allocate_spills_onto_the_affinity_nodes_board():
    """A full affinity home overflows onto its own board before the rack."""
    from repro.core.control_plane import ControlPlane

    topo = Topology.boards(2, 2)
    cp = ControlPlane(num_nodes=4, pages_per_node=4, num_logical=32,
                      topology=topo)
    cp.allocate(4, policy="affinity", affinity=3)     # node 3 full
    spilled = cp.allocate(2, policy="affinity", affinity=3)
    homes = np.asarray(cp.table().home)[spilled.page_ids]
    assert set(homes.tolist()) == {2}                 # board mate, not 0/1


def test_zero_bridge_store_threads_topology():
    """create_store on a hierarchical control plane: two-tier program +
    topology ride in the store and the round trip stays exact."""
    from repro.core import zero_bridge
    from repro.core.control_plane import ControlPlane

    topo = Topology.boards(2, 2)
    tree = {"w": jnp.arange(24, dtype=jnp.float32).reshape(4, 6),
            "b": jnp.ones((3,), jnp.float32)}
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=64,
                      topology=topo)
    store = zero_bridge.create_store(tree, mesh=None, page_elems=8, cp=cp)
    assert store.topology is topo
    steering.validate_hierarchical(store.program, topo)
    pulled, telem = zero_bridge.pull_tree(store, mesh=None,
                                          collect_telemetry=True)
    import jax
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), tree, pulled)
    assert int(np.asarray(telem.served_total()).sum()) == store.packer.num_pages


def test_topology_validation_rejects_bad_fabrics():
    import pytest
    with pytest.raises(ValueError):
        Topology.from_sizes([])
    with pytest.raises(ValueError):
        Topology.from_sizes([2, 0])
    with pytest.raises(ValueError):
        Topology(group=np.array([0, 0]), local_rank=np.array([0, 0]),
                 group_sizes=np.array([2]))  # duplicate local rank
    with pytest.raises(ValueError):
        bridge._resolve_topology(Topology.boards(2, 2), 8)  # wrong size
