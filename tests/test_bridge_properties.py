"""Randomized property tests for the bridge.

Split out of test_bridge.py so the deterministic suite is isolated from the
property-testing machinery: real hypothesis when installed (pinned in
requirements-dev.txt), the seeded fallback in hypofallback.py otherwise.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from topologies import TELEM_FIELDS, make_pool

from repro.core import bridge, ref, steering
from repro.core.memport import FREE, MemPortTable
from repro.core.control_plane import ControlPlane
from repro.telemetry import counters as tcounters  # noqa: F401 (structure)

pytestmark = pytest.mark.property

make_pool_np = make_pool  # shared fixture (tests/topologies.py)


@settings(max_examples=25, deadline=None)
@given(
    num_logical=st.integers(1, 24),
    budget=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
def test_pull_property_random_requests(num_logical, budget, seed):
    """Any request list (dups, FREE holes, unmapped pages) matches the oracle."""
    rng = np.random.default_rng(seed)
    pool = make_pool_np(32, 4, seed)
    table = MemPortTable.striped(num_logical, 1, 32)
    r = int(rng.integers(1, 16))
    want = rng.integers(-1, num_logical, size=(1, r)).astype(np.int32)
    got = bridge.pull_pages(pool, jnp.asarray(want), table,
                            mesh=None, budget=budget)
    exp = ref.pull_pages_ref(pool, jnp.asarray(want), table, pages_per_node=32)
    np.testing.assert_allclose(got, exp)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), nodes=st.integers(1, 6))
def test_control_plane_invariants(seed, nodes):
    """No slot double-booked; every mapped page has a live home."""
    rng = np.random.default_rng(seed)
    cp = ControlPlane(num_nodes=nodes, pages_per_node=8, num_logical=64)
    regions = []
    # Keep total allocation at <= half capacity so a failed node's pages
    # always fit on survivors.
    remaining = nodes * 8 // 2
    for _ in range(int(rng.integers(1, 4))):
        n = int(rng.integers(1, 8))
        if n > remaining:
            break
        remaining -= n
        regions.append(cp.allocate(n, policy=str(rng.choice(
            ["striped", "hashed"]))))
    if nodes > 1 and rng.random() < 0.5:
        cp.fail_node(int(rng.integers(0, nodes)))
    home, slot = np.asarray(cp._home), np.asarray(cp._slot)
    mapped = home != FREE
    pairs = set(zip(home[mapped].tolist(), slot[mapped].tolist()))
    assert len(pairs) == mapped.sum(), "slot double-booked"
    for h in home[mapped]:
        assert cp.nodes[h].alive, "page homed on dead node"


@settings(max_examples=20, deadline=None)
@given(
    num_nodes=st.integers(1, 6),
    budget=st.integers(1, 6),
    active_budget=st.integers(1, 6),
    overprovision=st.integers(1, 2),
    seed=st.integers(0, 10_000),
)
def test_pull_telemetry_matches_oracle_property(num_nodes, budget,
                                                active_budget, overprovision,
                                                seed):
    """Counters == the oracle's per-request walk for arbitrary programs,
    budgets, throttles and request lists (dups, FREE holes, unmapped)."""
    rng = np.random.default_rng(seed)
    tn, ppn = num_nodes, 8
    pool = make_pool_np(tn * ppn, 4, seed)
    num_logical = int(rng.integers(1, tn * ppn + 1))
    table = MemPortTable.striped(num_logical, tn, ppn)
    r = int(rng.integers(1, 16))
    # ids beyond num_logical-1 are invalid; stay in-range but allow FREE
    want = rng.integers(-1, num_logical, size=(1, r)).astype(np.int32)
    if tn > 1 and rng.random() < 0.7:
        keep = [d for d in range(1, tn) if rng.random() < 0.7]
        base = (steering.bidirectional_program(tn) if rng.random() < 0.5
                else steering.unidirectional_program(tn))
        program = steering.pruned_program(base, keep)
    else:
        program = None
    got, telem = bridge.pull_pages(
        pool, jnp.asarray(want), table, mesh=None, budget=budget,
        overprovision=overprovision, active_budget=jnp.int32(active_budget),
        table_nodes=tn, program=program, collect_telemetry=True)
    exp = ref.expected_transfer_telemetry(
        want, table, program, num_nodes=tn, budget=budget,
        active_budget=active_budget, overprovision=overprovision)
    for name in TELEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(telem, name)), np.asarray(getattr(exp, name)),
            err_msg=name)
    # conservation: live requests all end up served, spilled or pruned
    home = np.asarray(table.home)
    live = int(((want >= 0) & (home[np.clip(want, 0, None)] >= 0)).sum())
    total = (int(np.asarray(telem.served_total()).sum())
             + int(np.asarray(telem.spilled).sum())
             + int(np.asarray(telem.pruned).sum()))
    assert total == live
    # pushes count with identical semantics
    payload = rng.normal(size=(1, r, 4)).astype(np.float32)
    _, ptelem = bridge.push_pages(
        pool, jnp.asarray(want), jnp.asarray(payload), table, mesh=None,
        budget=budget, overprovision=overprovision,
        active_budget=jnp.int32(active_budget), table_nodes=tn,
        program=program, collect_telemetry=True)
    for name in ("slot_served", "spilled", "pruned", "traffic"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ptelem, name)),
            np.asarray(getattr(exp, name)), err_msg=f"push {name}")


@settings(max_examples=20, deadline=None)
@given(num_nodes=st.integers(2, 12), seed=st.integers(0, 10_000))
def test_load_balanced_program_properties(num_nodes, seed):
    """Random measured loads: congruent offsets, live == measured (when
    pruning), and the bottleneck direction is never worse than the static
    shortest-way split under the same loads."""
    rng = np.random.default_rng(seed)
    n = num_nodes
    w = np.where(rng.random(n - 1) < 0.6, rng.integers(0, 50, n - 1), 0)
    p = steering.load_balanced_program(n, w)
    p.validate()
    assert list(p.live_distances()) == (np.nonzero(w > 0)[0] + 1).tolist()
    off, live = np.asarray(p.offsets), np.asarray(p.live)
    ep = np.asarray(p.epoch)
    assert (ep[~live] == -1).all() and (off[~live] == 0).all()
    for e in set(ep[live].tolist()):
        at_e = live & (ep == e)
        assert (off[at_e] > 0).sum() <= 1 and (off[at_e] < 0).sum() <= 1

    def bottleneck(prog):
        o, lv = np.asarray(prog.offsets), np.asarray(prog.live)
        return max(w[lv & (o > 0)].sum(), w[lv & (o < 0)].sum())

    bi = steering.pruned_program(steering.bidirectional_program(n),
                                 (np.nonzero(w > 0)[0] + 1).tolist())
    assert bottleneck(p) <= bottleneck(bi)
    # unpruned keeps every distance wired (zero-weight ones ride along)
    p_full = steering.load_balanced_program(n, w, prune=False)
    assert list(p_full.live_distances()) == list(range(1, n))


@settings(max_examples=20, deadline=None)
@given(num_nodes=st.integers(2, 12), seed=st.integers(0, 10_000))
def test_route_program_properties(num_nodes, seed):
    """Random prunings stay congruent, cover exactly what they keep, and
    never use more epochs than the base program."""
    rng = np.random.default_rng(seed)
    base = (steering.bidirectional_program(num_nodes)
            if rng.random() < 0.5 else
            steering.unidirectional_program(num_nodes,
                                            direction=1 if rng.random() < 0.5
                                            else -1))
    keep = [d for d in range(1, num_nodes) if rng.random() < 0.6]
    p = steering.pruned_program(base, keep)
    p.validate()
    assert list(p.live_distances()) == sorted(keep)
    assert p.num_epochs() <= base.num_epochs()
    live = np.asarray(p.live)
    ep = np.asarray(p.epoch)
    off = np.asarray(p.offsets)
    # dead slots fully cleared
    assert (ep[~live] == -1).all() and (off[~live] == 0).all()
    # at most one circuit per direction per epoch
    for e in set(ep[live].tolist()):
        at_e = live & (ep == e)
        assert (off[at_e] > 0).sum() <= 1
        assert (off[at_e] < 0).sum() <= 1


@settings(max_examples=15, deadline=None)
@given(
    budget=st.integers(1, 8),
    active_budget=st.integers(1, 8),
    overprovision=st.integers(1, 2),
    seed=st.integers(0, 10_000),
)
def test_pipelined_channels_bit_exact_property(budget, active_budget,
                                               overprovision, seed):
    """Pipelined channels ∈ {1, 2, 4} serve bit-exactly what the serial
    engine serves — results and telemetry — over random ragged board+rack
    fabrics, hierarchical/masked/pruned programs, throttles and request
    lists (the pipeline reorders wire traffic, never what is served)."""
    from topologies import random_fabric
    from repro.core import steering as _steering

    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n, ppn = topo.num_nodes, 8
    pool = make_pool_np(n * ppn, 4, seed)
    num_logical = int(rng.integers(1, n * ppn + 1))
    table = MemPortTable.striped(num_logical, n, ppn)
    r = int(rng.integers(1, 16))
    want = rng.integers(-1, num_logical, size=(n, r)).astype(np.int32)

    choice = rng.random()
    if n == 1:
        program = None
    elif choice < 0.4:
        program = _steering.hierarchical_program(topo)
    elif choice < 0.7:
        base = _steering.hierarchical_program(topo)
        rank_live = rng.random(np.asarray(base.rank_epoch).shape) < 0.8
        program = _steering.masked_ranks_program(base, rank_live)
    else:
        keep = [d for d in range(1, n) if rng.random() < 0.7]
        program = _steering.pruned_program(
            _steering.bidirectional_program(n), keep)

    serial = ref.pull_pages_pipelined_ref(
        pool, jnp.asarray(want), table, ppn, program, budget=budget,
        channels=1, active_budget=active_budget, overprovision=overprovision)
    # the serial oracle must agree with the classic ref under the limiter
    mask = ref.rate_limit_mask(r, budget, active_budget, overprovision)
    masked = jnp.asarray(np.where(mask[None, :], want, FREE))
    np.testing.assert_array_equal(
        np.asarray(serial),
        np.asarray(ref.pull_pages_ref(pool, masked, table, ppn,
                                      program=program)))
    for channels in (2, 4):
        piped = ref.pull_pages_pipelined_ref(
            pool, jnp.asarray(want), table, ppn, program, budget=budget,
            channels=channels, active_budget=active_budget,
            overprovision=overprovision)
        np.testing.assert_array_equal(np.asarray(piped), np.asarray(serial))
        # the chunk schedule is a duplicate-free cover of the served window
        flat_sched = np.concatenate(
            ref.pipeline_schedule(r, budget, channels, active_budget,
                                  overprovision) or [np.zeros(0, int)])
        in_range = flat_sched[flat_sched < r]
        assert len(set(in_range.tolist())) == len(in_range)
        np.testing.assert_array_equal(np.sort(in_range), np.nonzero(mask)[0])
    # push: commits retire in chunk order; single-writer image identical
    dest_ids = rng.permutation(num_logical)[: min(r, num_logical)]
    dest = np.full((n, r), FREE, np.int32)
    dest[0, : len(dest_ids)] = dest_ids
    payload = rng.normal(size=(n, r, 4)).astype(np.float32)
    pser = ref.push_pages_pipelined_ref(
        pool, jnp.asarray(dest), jnp.asarray(payload), table, ppn, program,
        budget=budget, channels=1, active_budget=active_budget,
        overprovision=overprovision)
    for channels in (2, 4):
        ppiped = ref.push_pages_pipelined_ref(
            pool, jnp.asarray(dest), jnp.asarray(payload), table, ppn,
            program, budget=budget, channels=channels,
            active_budget=active_budget, overprovision=overprovision)
        np.testing.assert_array_equal(np.asarray(ppiped), np.asarray(pser))
    # telemetry is channels-blind by construction: the datapath counters are
    # computed from the request list + program alone, so one oracle serves
    # every depth (asserted against the live datapath in the 8-device suite)
    telem = ref.expected_transfer_telemetry(
        want, table, program, num_nodes=n, budget=budget,
        active_budget=active_budget, overprovision=overprovision,
        topology=topo)
    live = int(((want >= 0)
                & (np.asarray(table.home)[np.clip(want, 0, None)] >= 0)).sum())
    total = (int(np.asarray(telem.served_total()).sum())
             + int(np.asarray(telem.spilled).sum())
             + int(np.asarray(telem.pruned).sum()))
    assert total == live
