"""Randomized property tests for the bridge.

Split out of test_bridge.py so the deterministic suite is isolated from the
property-testing machinery: real hypothesis when installed (pinned in
requirements-dev.txt), the seeded fallback in hypofallback.py otherwise.
"""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from repro.core import bridge, ref, steering
from repro.core.memport import FREE, MemPortTable
from repro.core.control_plane import ControlPlane


def make_pool_np(num_slots, page, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(num_slots, page)).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    num_logical=st.integers(1, 24),
    budget=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
def test_pull_property_random_requests(num_logical, budget, seed):
    """Any request list (dups, FREE holes, unmapped pages) matches the oracle."""
    rng = np.random.default_rng(seed)
    pool = make_pool_np(32, 4, seed)
    table = MemPortTable.striped(num_logical, 1, 32)
    r = int(rng.integers(1, 16))
    want = rng.integers(-1, num_logical, size=(1, r)).astype(np.int32)
    got = bridge.pull_pages(pool, jnp.asarray(want), table,
                            mesh=None, budget=budget)
    exp = ref.pull_pages_ref(pool, jnp.asarray(want), table, pages_per_node=32)
    np.testing.assert_allclose(got, exp)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), nodes=st.integers(1, 6))
def test_control_plane_invariants(seed, nodes):
    """No slot double-booked; every mapped page has a live home."""
    rng = np.random.default_rng(seed)
    cp = ControlPlane(num_nodes=nodes, pages_per_node=8, num_logical=64)
    regions = []
    # Keep total allocation at <= half capacity so a failed node's pages
    # always fit on survivors.
    remaining = nodes * 8 // 2
    for _ in range(int(rng.integers(1, 4))):
        n = int(rng.integers(1, 8))
        if n > remaining:
            break
        remaining -= n
        regions.append(cp.allocate(n, policy=str(rng.choice(
            ["striped", "hashed"]))))
    if nodes > 1 and rng.random() < 0.5:
        cp.fail_node(int(rng.integers(0, nodes)))
    home, slot = np.asarray(cp._home), np.asarray(cp._slot)
    mapped = home != FREE
    pairs = set(zip(home[mapped].tolist(), slot[mapped].tolist()))
    assert len(pairs) == mapped.sum(), "slot double-booked"
    for h in home[mapped]:
        assert cp.nodes[h].alive, "page homed on dead node"


@settings(max_examples=20, deadline=None)
@given(num_nodes=st.integers(2, 12), seed=st.integers(0, 10_000))
def test_route_program_properties(num_nodes, seed):
    """Random prunings stay congruent, cover exactly what they keep, and
    never use more epochs than the base program."""
    rng = np.random.default_rng(seed)
    base = (steering.bidirectional_program(num_nodes)
            if rng.random() < 0.5 else
            steering.unidirectional_program(num_nodes,
                                            direction=1 if rng.random() < 0.5
                                            else -1))
    keep = [d for d in range(1, num_nodes) if rng.random() < 0.6]
    p = steering.pruned_program(base, keep)
    p.validate()
    assert list(p.live_distances()) == sorted(keep)
    assert p.num_epochs() <= base.num_epochs()
    live = np.asarray(p.live)
    ep = np.asarray(p.epoch)
    off = np.asarray(p.offsets)
    # dead slots fully cleared
    assert (ep[~live] == -1).all() and (off[~live] == 0).all()
    # at most one circuit per direction per epoch
    for e in set(ep[live].tolist()):
        at_e = live & (ep == e)
        assert (off[at_e] > 0).sum() <= 1
        assert (off[at_e] < 0).sum() <= 1
