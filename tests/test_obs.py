"""Observability plane: tracing, metrics, SLOs, and the calibrated model.

Covers the contracts the rest of the repo leans on:

* deterministic tracing — with a ``ManualClock`` the same sequence of
  spans serializes to byte-identical Chrome-trace JSON across runs, and
  the span tree (parents/children, categories, args) round-trips through
  the export schema Perfetto expects,
* span <-> counter reconciliation — ``annotate_telemetry`` on a span and
  ``observe_telemetry`` into a registry must agree bit-exactly with the
  telemetry oracle's counts (same telemetry, three independent readers),
* log-bucketed histograms — bounded relative quantile error by
  construction, exact count/sum,
* the online-calibrated perfmodel — an *unfitted* calibrator reproduces
  the static analytic model exactly (the prior is the datasheet), RLS
  converges to known constants from synthetic latencies, and the fitted
  model beats the static prior on data the static constants cannot
  explain,
* the orchestrator's measure->fit->steer loop end to end in-process.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import perfmodel, ref, steering
from repro.core.memport import MemPortTable
from repro.obs import (Counter, Gauge, Histogram, ManualClock,
                       MetricsRegistry, MonotonicClock, SLOMonitor,
                       TraceRecorder, phase_op_counts)

# ---------------------------------------------------------------- tracing


def _record_sample_trace(rec: TraceRecorder) -> None:
    with rec.span("transfer:demo", scenario="demo", pages=16) as t:
        for r in range(2):
            with rec.span(f"round:{r}", "round", index=r):
                with rec.span("phase:gather", "phase"):
                    pass
        rec.annotate(t, rounds=2)


def test_manual_clock_trace_is_byte_reproducible():
    blobs = []
    for _ in range(2):
        rec = TraceRecorder(ManualClock(start_us=100.0, tick_us=2.5),
                            process_name="determinism")
        _record_sample_trace(rec)
        blobs.append(rec.to_json(indent=1))
    assert blobs[0] == blobs[1]
    # and the timestamps are the deterministic tick sequence, not wall time
    assert '"ts": 100.0' in blobs[0]


def test_monotonic_clock_advances():
    c = MonotonicClock()
    a, b = c.now_us(), c.now_us()
    assert b >= a >= 0.0


def test_span_tree_nesting_and_queries():
    rec = TraceRecorder(ManualClock())
    _record_sample_trace(rec)
    t = rec.find("transfer:demo")
    assert t is not None and t.parent_id is None
    rounds = rec.find_all(cat="round")
    assert [s.name for s in rounds] == ["round:0", "round:1"]
    assert all(s.parent_id == t.span_id for s in rounds)
    assert [s.name for s in rec.children(rounds[0])] == ["phase:gather"]
    assert t.args["rounds"] == 2 and t.args["pages"] == 16
    assert all(s.duration_us >= 0 for s in rec.spans)


def test_chrome_trace_schema():
    rec = TraceRecorder(ManualClock(), process_name="schema")
    _record_sample_trace(rec)
    trace = rec.to_chrome_trace()
    events = trace["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    assert meta and meta[0]["args"]["name"] == "schema"
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == len(rec.spans)
    for e in xs:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= e.keys()
        assert e["dur"] >= 0
    # open spans auto-close in the export (marked, duration to "now")
    # without being mutated: a crash mid-span still yields a full trace.
    clk = ManualClock(tick_us=0.0)
    rec2 = TraceRecorder(clk)
    with rec2.span("open") as sp:
        clk.advance(25.0)
        xs2 = [e for e in rec2.to_chrome_trace()["traceEvents"]
               if e["ph"] == "X"]
        assert len(xs2) == 1
        assert xs2[0]["args"]["unclosed"] is True
        assert xs2[0]["dur"] == 25.0
        assert sp.end_us is None       # the span itself stays open
    # once closed, the marker disappears
    xs3 = [e for e in rec2.to_chrome_trace()["traceEvents"]
           if e["ph"] == "X"]
    assert "unclosed" not in xs3[0]["args"]


def test_phase_op_counts_parses_both_scope_spellings():
    hlo = '\n'.join([
        'p0 = f32[8] parameter(0), metadata={op_name="jit(f)/obs:wire_req/x"}',
        'p1 = f32[8] add(p0, p0), metadata={op_name="jit(f)/obs:gather/add"}',
        'p2 = f32[8] add(p1, p1), metadata={op_name="jit(f)/obs_gather/add"}',
        'p3 = f32[8] copy(p2), metadata={op_name="no_scope_here"}',
    ])
    assert phase_op_counts(hlo) == {"wire_req": 1, "gather": 2}


# ------------------------------------------------ span <-> counter parity


def _oracle_telemetry():
    n, budget = 8, 3
    rng = np.random.default_rng(7)
    table = MemPortTable.striped(48, n, 8)
    want = rng.integers(-1, 48, size=(n, 7)).astype(np.int32)
    lane = rng.integers(0, 4, size=(n, 7)).astype(np.int32)
    prog = steering.bidirectional_program(n)
    return ref.expected_transfer_telemetry(
        want, table, prog, num_nodes=n, budget=budget, tenant_ids=lane)


def test_span_and_registry_reconcile_with_oracle():
    telem = _oracle_telemetry()
    page_bytes = 64

    rec = TraceRecorder(ManualClock())
    with rec.span("transfer:oracle") as sp:
        pass
    rec.annotate_telemetry(sp, telem, page_bytes=page_bytes)

    reg = MetricsRegistry()
    reg.observe_telemetry(telem, page_bytes=page_bytes)
    counters = reg.snapshot()["counters"]

    served = int(np.asarray(telem.served_total()).sum())
    cw, ccw = telem.wire_pages()
    cw, ccw = int(np.asarray(cw).sum()), int(np.asarray(ccw).sum())
    assert served > 0 and cw + ccw > 0

    # all three readers of the same telemetry agree bit-exactly
    assert sp.args["pages_served"] == served
    assert counters["bridge_pages_served_total"] == served
    assert sp.args["wire_pages_cw"] == cw
    assert counters['bridge_wire_pages_total{direction="cw"}'] == cw
    assert sp.args["wire_pages_ccw"] == ccw
    assert counters['bridge_wire_pages_total{direction="ccw"}'] == ccw
    assert sp.args["pages_spilled"] == int(np.asarray(telem.spilled).sum())
    assert counters["bridge_pages_spilled_total"] == sp.args["pages_spilled"]
    assert sp.args["bytes_served"] == served * page_bytes
    assert counters["bridge_bytes_served_total"] == served * page_bytes
    assert sp.args["wire_bytes"] == (cw + ccw) * page_bytes

    # per-tenant lanes reconcile too (and carry names when given)
    tser = np.asarray(telem.tenant_served).sum(0)
    for t, pages in enumerate(tser.tolist()):
        if pages:
            assert sp.args["tenant_pages"][str(t)] == int(pages)
            key = f'bridge_tenant_pages_total{{qos="unknown",tenant="{t}"}}'
            assert counters[key] == int(pages)
    total_tenant = sum(sp.args["tenant_pages"].values())
    assert total_tenant == int(tser.sum())


# ---------------------------------------------------------------- metrics


def test_counter_gauge_basics():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge()
    g.set(2.5)
    assert g.value == 2.5


def test_registry_rejects_kind_conflicts():
    reg = MetricsRegistry()
    reg.counter("x", a="1")
    with pytest.raises(TypeError):
        reg.gauge("x", a="1")
    # different labels are a different family member, no conflict
    reg.gauge("x", a="2")


def test_histogram_counts_and_quantiles():
    h = Histogram(lo=1.0, growth=1.1, num_buckets=128)
    vals = np.linspace(10.0, 1000.0, 500)
    for v in vals:
        h.record(float(v))
    assert h.count == 500
    assert h.total == pytest.approx(float(vals.sum()))
    # log-bucketed quantiles carry at most one bucket of relative error
    for q in (0.5, 0.9, 0.99):
        exact = float(np.quantile(vals, q))
        assert h.quantile(q) == pytest.approx(exact, rel=0.12)
    assert h.p50() <= h.p99()
    # underflow bin: values below lo quantile-interpolate inside [0, lo)
    h2 = Histogram(lo=10.0)
    h2.record(0.5)
    assert 0.0 <= h2.p50() <= 10.0


def test_histogram_quantile_edge_cases():
    # empty histogram: quantile is NaN (unknown), never a fake 0.0
    h = Histogram(lo=1.0)
    assert np.isnan(h.quantile(0.5)) and np.isnan(h.p99())
    assert h.count == 0
    # q >= 1 clamps to the top occupied bucket edge, not past the table
    h.record(10.0)
    h.record(500.0)
    top = h.quantile(1.0)
    assert np.isfinite(top) and top >= 500.0
    assert h.quantile(2.0) == top


def test_slo_burn_rate_zero_sample_guard():
    mon = SLOMonitor(window=10, budget_fraction=0.1)
    # unknown tenant and empty window both read 0.0, not a divide error
    assert mon.burn_rate(42) == 0.0
    mon0 = SLOMonitor(window=10, budget_fraction=0.0)
    mon0.record(0, latency_us=150.0, slo_us=100.0)
    assert mon0.burn_rate(0) == 0.0


def test_registry_text_exposition_is_deterministic():
    reg = MetricsRegistry()
    reg.counter("bridge_pages_served_total").inc(3)
    reg.gauge("bridge_link_utilization", direction="cw").set(0.75)
    reg.histogram("obs_span_latency_us", cat="round",
                  name="pull").record(12.0)
    text = reg.to_text()
    assert "bridge_pages_served_total 3" in text
    assert 'bridge_link_utilization{direction="cw"} 0.75' in text
    assert ('obs_span_latency_us_count{cat="round",name="pull"} 1'
            in text)
    assert text == reg.to_text()


def test_text_exposition_escapes_hostile_label_values():
    reg = MetricsRegistry()
    hostile = 'evil"name\nwith\\slashes'
    reg.counter("serve_requests_total", tenant=hostile).inc(7)
    text = reg.to_text()
    # escaped per the Prometheus exposition format: \\ then \" then \n
    assert ('serve_requests_total{tenant='
            '"evil\\"name\\nwith\\\\slashes"} 7') in text
    # one line per sample survives: the newline never splits the entry
    lines = [ln for ln in text.splitlines()
             if ln.startswith("serve_requests_total")]
    assert len(lines) == 1 and lines[0].endswith(" 7")


def test_slo_monitor_burn_rates():
    reg = MetricsRegistry()
    mon = SLOMonitor(window=10, budget_fraction=0.1, registry=reg)
    for _ in range(8):
        mon.record(0, latency_us=50.0, slo_us=100.0)
    for _ in range(2):
        mon.record(0, latency_us=150.0, slo_us=100.0)
    assert mon.violation_fraction(0) == pytest.approx(0.2)
    assert mon.burn_rate(0) == pytest.approx(2.0)
    assert reg.snapshot()["gauges"]['slo_burn_rate{tenant="0"}'] == \
        pytest.approx(2.0)
    d = mon.describe()["0"]
    assert d["violations"] == 2 and d["samples"] == 10
    # slo_us == 0 disables violation accounting entirely
    mon.record(1, latency_us=1e9, slo_us=0.0)
    assert mon.burn_rate(1) == 0.0


# ---------------------------------------------------- calibrated perfmodel


def test_unfitted_calibrator_is_the_static_model():
    """The RLS prior *is* the datasheet: before any observation the
    linearized calibrator reproduces the serial analytic model exactly."""
    cal = perfmodel.Calibrator()
    assert not cal.fitted
    for prog in (steering.bidirectional_program(8),
                 steering.unidirectional_program(8)):
        for page_bytes in (1 << 12, 1 << 18):
            want = perfmodel.predict_round_latency_us(prog, page_bytes, 8)
            got = cal.predict_round_latency_us(prog, page_bytes, 8)
            assert got == pytest.approx(want, rel=1e-12), (
                prog, page_bytes)
            feats = perfmodel.route_features(prog, page_bytes, 8)
            assert cal.static_predict_us(feats) == pytest.approx(
                want, rel=1e-12)


def test_route_features_shape_and_scaling():
    bi = steering.bidirectional_program(8)
    f1 = np.asarray(perfmodel.route_features(bi, 1 << 18, 8))
    assert f1.shape == (len(perfmodel.FEATURE_NAMES),)
    assert f1[4] == 1.0                      # one transfer
    assert f1[3] == 1.0                      # rounds * channels
    assert f1[1] == 0.0                      # flat fabric: no rack tier
    f3 = np.asarray(perfmodel.route_features(bi, 1 << 18, 8, rounds=3,
                                             channels=2))
    # hop RTTs, wire and chunk terms all scale linearly with rounds
    assert f3[0] == pytest.approx(3 * f1[0])
    assert f3[2] == pytest.approx(3 * f1[2])
    assert f3[3] == 6.0
    assert f3[4] == 1.0


def test_calibrator_converges_on_synthetic_latencies():
    rng = np.random.default_rng(5)
    theta_true = np.array([3.0, 7.0, 40.0, 250.0, 1200.0])
    cal = perfmodel.Calibrator()
    for _ in range(200):
        x = rng.uniform(0.5, 8.0, size=5)
        x[4] = 1.0
        y = float(x @ theta_true) + rng.normal(0, 0.5)
        cal.observe(x, y)
    assert cal.fitted and cal.samples == 200
    np.testing.assert_allclose(cal.theta, theta_true, atol=0.5)
    assert cal.chunk_overhead_us == pytest.approx(250.0, abs=0.5)
    assert cal.base_overhead_us == pytest.approx(1200.0, abs=2.0)
    # the repackaged TpuHW carries the fitted hop latency
    assert cal.hw().ici_hop_latency_us == pytest.approx(3.0, abs=0.1)
    consts = cal.constants()
    assert set(perfmodel.FEATURE_NAMES) <= consts.keys()


def test_fitted_beats_static_on_software_dominated_latencies():
    """Synthetic fabric whose cost is dispatch, not wire: the static
    datasheet prior cannot explain it, the fitted constants must."""
    rng = np.random.default_rng(9)
    bi = steering.bidirectional_program(8)
    cal = perfmodel.Calibrator()
    samples = []
    for _ in range(60):
        rounds = int(rng.integers(1, 4))
        channels = int(rng.choice([1, 2, 4]))
        feats = perfmodel.route_features(bi, 256, 8, rounds=rounds,
                                         channels=channels)
        measured = 500.0 + 90.0 * rounds * channels + rng.normal(0, 5.0)
        samples.append((feats, measured))
        cal.observe(feats, measured)
    static_err = np.mean([abs(cal.static_predict_us(f) - m) / m
                          for f, m in samples])
    fitted_err = np.mean([abs(cal.predict_us(f) - m) / m
                          for f, m in samples])
    assert fitted_err < static_err
    assert fitted_err < 0.05 < static_err


def test_calibrator_rejects_bad_feature_length():
    cal = perfmodel.Calibrator()
    with pytest.raises(ValueError):
        cal.observe([1.0, 2.0], 10.0)


def test_select_channels_with_calibrated_chunk_overhead():
    """A large fitted per-chunk overhead must keep the pick serial where
    the static model would pipeline deep."""
    from repro.core.control_plane import ControlPlane
    from repro.telemetry import TelemetryAggregator

    n = 8
    cp = ControlPlane(num_nodes=n, pages_per_node=16, num_logical=n * 16)
    agg = TelemetryAggregator(n, page_bytes=1 << 12)
    telem = _oracle_telemetry()
    agg.update(telem)
    static_pick = cp.select_channels(8, 4096, telemetry=agg)

    cal = perfmodel.Calibrator(min_samples=1)
    bi = steering.bidirectional_program(n)
    # dispatch-dominated backend: latency grows with rounds*channels
    for channels in (1, 2, 4, 8):
        for rounds in (1, 2):
            feats = perfmodel.route_features(bi, 4096, 8, rounds=rounds,
                                             channels=channels)
            cal.observe(feats, 800.0 * rounds * channels + 400.0)
    assert cal.chunk_overhead_us > 0
    cal_pick = cp.select_channels(8, 4096, telemetry=agg, calibrator=cal)
    assert cal_pick <= static_pick
    assert cal_pick == 1

    # an unfitted calibrator must leave the static pick untouched
    assert cp.select_channels(
        8, 4096, telemetry=agg,
        calibrator=perfmodel.Calibrator()) == static_pick


# ------------------------------------------- orchestrator integration loop


def test_orchestrator_measure_fit_steer_loop():
    from repro.core.control_plane import ControlPlane
    from repro.orchestrator import Orchestrator, TenantSpec

    n = 8
    cp = ControlPlane(num_nodes=n, pages_per_node=16, num_logical=n * 16)
    orc = Orchestrator(cp, budget=8, page_bytes=4096, control_period=1)
    orc.register(TenantSpec(0, "svc", qos="interactive", share=2.0,
                            slo_round_us=50.0))
    _, lease = orc.request_lease(0, 32)
    assert lease is not None

    telem = _oracle_telemetry()
    # measured spans: a dispatch-heavy fabric violating the 50us SLO
    for _ in range(6):
        orc.step(telemetry=telem, measured_round_us=900.0, rounds=1)
    assert orc.calibrator.samples == 6

    snap = orc.metrics.snapshot()
    assert snap["counters"]["bridge_pages_served_total"] > 0
    assert snap["gauges"]['slo_burn_rate{tenant="0"}'] > 1.0
    lat = snap["histograms"]["obs_round_latency_us"]
    # log-bucketed (growth=2): the quantile is exact to within one bucket
    assert lat["count"] == 6 and 450.0 <= lat["p50"] <= 1800.0
    assert lat["mean"] == pytest.approx(900.0)
    desc = orc.describe()
    assert "calibrator:" in desc and "metrics:" in desc
    assert "slo tenant 0:" in desc

    # once fitted, window pricing runs on the fitted constants: the
    # predicted window latency must reflect the measured ~900us rounds,
    # not the static microsecond-scale wire model.
    pred = orc.predicted_window_us(0)
    assert pred is not None and pred > 100.0
