"""Bridge transfer-engine correctness: bridge == pure-jnp oracle.

Single-device (N=1 loopback) cases run here; multi-node ring tests run in a
subprocess with 8 virtual devices (see test_distributed.py).  Randomized
property tests live in test_bridge_properties.py (optional: hypothesis).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from topologies import fake_telem, make_pool

from repro.core import bridge, perfmodel, ref, steering
from repro.core.memport import FREE, MemPortTable
from repro.core.control_plane import ControlPlane

make_pool_np = make_pool  # shared fixture (tests/topologies.py)


def test_pull_single_node_matches_ref():
    pool = make_pool_np(16, 8)
    table = MemPortTable.striped(12, 1, 16)
    want = jnp.asarray([[3, 0, 7, FREE, 11, 2]], jnp.int32)
    got = bridge.pull_pages(pool, want, table, mesh=None, budget=4)
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=16)
    np.testing.assert_allclose(got, exp)


def test_push_single_node_matches_ref():
    pool = make_pool_np(16, 8)
    table = MemPortTable.striped(12, 1, 16)
    dest = jnp.asarray([[5, 1, FREE, 9]], jnp.int32)
    payload = jnp.ones((1, 4, 8), jnp.float32) * jnp.arange(4)[None, :, None]
    got = bridge.push_pages(pool, dest, payload, table, mesh=None, budget=2)
    exp = ref.push_pages_ref(pool, dest, payload, table, pages_per_node=16)
    np.testing.assert_allclose(got, exp)


def test_memport_translate_free_passthrough():
    t = MemPortTable.striped(8, 2, 4)
    home, slot = t.translate(jnp.asarray([0, FREE, 7], jnp.int32))
    assert home[1] == FREE and slot[1] == FREE
    assert home[0] == 0 and slot[0] == 0
    assert home[7 % 3 if False else 2] >= 0


def test_memport_runtime_reprogram():
    t = MemPortTable.striped(8, 2, 4)
    t2 = t.program(np.array([3]), np.array([1]), np.array([2]))
    assert int(t2.home[3]) == 1 and int(t2.slot[3]) == 2
    # untouched rows preserved
    assert int(t2.home[0]) == int(t.home[0])


def test_control_plane_alloc_and_fail():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=64)
    region = cp.allocate(16, "kv", policy="striped")
    occ = cp.occupancy()
    assert occ.sum() == 16 and occ.max() == 4
    plan = cp.fail_node(2)
    assert len(plan) == 4  # node 2 held 4 pages
    assert all(s.new_home != 2 for s in plan)
    occ = cp.occupancy()
    assert occ[2] == 0 and occ.sum() == 16
    # table stays consistent
    t = cp.table()
    assert not np.any(np.asarray(t.home) == 2)
    region2 = cp.allocate(8, policy="hashed")
    t2 = cp.table()
    homes = np.asarray(t2.home)[region2.page_ids]
    assert not np.any(homes == 2)


def test_control_plane_straggler_rate_limits():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=8)
    for step in range(8):
        for n in range(4):
            cp.record_step_time(n, 1.0 if n != 3 else 2.5)
    budgets = cp.rate_limits(static_budget=8)
    assert list(budgets[:3]) == [8, 8, 8]
    assert budgets[3] == 4


def test_rate_limited_pull_matches_ref():
    """Throttled budget (overprovisioned rounds) still returns every page."""
    pool = make_pool_np(32, 4)
    table = MemPortTable.striped(24, 1, 32)
    want = jnp.arange(24, dtype=jnp.int32)[None, :]
    got = bridge.pull_pages(pool, want, table, mesh=None, budget=8,
                            overprovision=2, active_budget=jnp.int32(5))
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=32)
    np.testing.assert_allclose(got, exp)


def test_rate_limited_pull_single_node_drops_tail():
    """Regression: the n == 1 fast path must honour ``active_budget``.

    With budget=8, overprovision=1 and active_budget=5, 3 rounds serve only
    the first 15 of 24 requests — on a 1-device mesh exactly like on an
    N-device mesh (the rest spill off the final round and return zeros).
    """
    pool = make_pool_np(32, 4)
    table = MemPortTable.striped(24, 1, 32)
    want = jnp.arange(24, dtype=jnp.int32)[None, :]
    got = np.asarray(bridge.pull_pages(
        pool, want, table, mesh=None, budget=8, overprovision=1,
        active_budget=jnp.int32(5)))
    exp = np.asarray(ref.pull_pages_ref(pool, want, table, pages_per_node=32))
    np.testing.assert_allclose(got[0, :15], exp[0, :15])
    np.testing.assert_array_equal(got[0, 15:], np.zeros_like(exp[0, 15:]))


def test_loopback_pull_pads_multidim_pages():
    """Regression: the n == 1 path must trim round padding on the request
    dim, not the second-to-last *page* dim (multi-dim pages + pad > 0)."""
    rng = np.random.default_rng(5)
    pool = jnp.asarray(rng.normal(size=(8, 4, 2, 3)).astype(np.float32))
    table = MemPortTable.striped(8, 1, 8)
    want = jnp.asarray([[0, 3, 5, FREE, 7, 2]], jnp.int32)  # 6 reqs, budget 4
    got = bridge.pull_pages(pool, want, table, mesh=None, budget=4)
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=8)
    assert got.shape == (1, 6, 4, 2, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp))


def test_rate_limits_spill_restore_ends_with_clean_measurement():
    """Regression: the spill-feedback restore must key on the *last*
    measurement, not the EWMA (which never decays to zero), or a straggler
    could never be throttled again after a single historic spill."""
    from repro.telemetry import TelemetryAggregator
    n = 4
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=8)
    for _ in range(8):
        for node in range(n):
            cp.record_step_time(node, 2.5 if node == 3 else 1.0)
    agg = TelemetryAggregator(n)

    def telem(spilled):
        return fake_telem(n, 4 * np.eye(n, dtype=np.int32), spilled=spilled)

    agg.update(telem([0, 0, 0, 6]))          # throttled step spilled
    assert cp.rate_limits(8, telemetry=agg)[3] == 8   # restore
    agg.update(telem([0, 0, 0, 0]))          # clean step measured
    assert agg.spilled[3] > 0                # EWMA still remembers...
    assert cp.rate_limits(8, telemetry=agg)[3] == 4   # ...throttle resumes


def test_rate_limited_push_single_node_drops_tail():
    """Regression: the write path must honour ``active_budget`` too.

    Pull throttled while push didn't — now both share the spill semantics:
    with budget=8, overprovision=1 and active_budget=5, 3 rounds write only
    the first 15 of 24 pages; the rest spill and their slots stay untouched.
    """
    pool = make_pool_np(32, 4)
    table = MemPortTable.striped(24, 1, 32)
    dest = jnp.arange(24, dtype=jnp.int32)[None, :]
    payload = (jnp.ones((1, 24, 4), jnp.float32)
               * jnp.arange(1, 25)[None, :, None])
    got = np.asarray(bridge.push_pages(
        pool, dest, payload, table, mesh=None, budget=8,
        active_budget=jnp.int32(5)))
    served = ref.rate_limit_mask(24, 8, 5)
    assert served.sum() == 15
    masked = jnp.where(jnp.asarray(served)[None, :], dest, FREE)
    exp = np.asarray(ref.push_pages_ref(pool, masked, payload, table,
                                        pages_per_node=32))
    np.testing.assert_allclose(got, exp)
    # the spilled pages' slots hold their original contents
    flat = np.asarray(ref.flat_index(table, jnp.arange(15, 24), 32))
    np.testing.assert_allclose(got[flat], np.asarray(pool)[flat])
    # overprovisioned rounds absorb the throttle: every page lands
    got_all = np.asarray(bridge.push_pages(
        pool, dest, payload, table, mesh=None, budget=8, overprovision=2,
        active_budget=jnp.int32(5)))
    exp_all = np.asarray(ref.push_pages_ref(pool, dest, payload, table,
                                            pages_per_node=32))
    np.testing.assert_allclose(got_all, exp_all)


# ---------------------------------------------------------------------------
# Route programs (runtime circuit schedules)
# ---------------------------------------------------------------------------

def test_route_program_epoch_counts():
    for n in (2, 3, 4, 5, 8, 16):
        uni = steering.unidirectional_program(n)
        bi = steering.bidirectional_program(n)
        uni.validate()
        bi.validate()
        assert uni.num_epochs() == n - 1
        assert bi.num_epochs() == n // 2
        assert list(uni.live_distances()) == list(range(1, n))
        assert list(bi.live_distances()) == list(range(1, n))


def test_route_program_is_runtime_pytree():
    """Programs are registered pytrees whose leaves are all arrays, so they
    can flow through jit without becoming static (no retrace on swap)."""
    p = steering.bidirectional_program(8)
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 4  # offsets, epoch, live, rank_epoch (group mask)
    assert all(hasattr(l, "dtype") for l in leaves)
    # identical treedef AND shapes across every program variant -> same jit
    # cache entry (flat and hierarchical programs swap without retracing)
    from repro.core.topology import Topology
    t2 = jax.tree.structure(p)
    for q in (steering.unidirectional_program(8),
              steering.hierarchical_program(Topology.boards(2, 4))):
        assert jax.tree.structure(q) == t2
        assert all(a.shape == b.shape for a, b in
                   zip(jax.tree.leaves(q), leaves))


def test_bidirectional_offsets_shortest_way():
    p = steering.bidirectional_program(8)
    off = np.asarray(p.offsets)
    np.testing.assert_array_equal(off, [1, 2, 3, 4, -3, -2, -1])
    assert p.hops().max() == 4


def test_pruned_program_compacts_epochs():
    base = steering.bidirectional_program(8)
    p = steering.pruned_program(base, [2, 5, 7])
    p.validate()
    assert list(p.live_distances()) == [2, 5, 7]
    # cw: {+2}; ccw: {-3 (d=5), -1 (d=7)} -> 2 epochs, shortest first
    assert p.num_epochs() == 2
    ep = np.asarray(p.epoch)
    assert ep[6] == 0 and ep[4] == 1 and ep[1] == 0  # d=7, d=5, d=2
    with pytest.raises(ValueError):
        steering.pruned_program(base, [8])


def test_link_avoiding_program_directions():
    for bad in (+1, -1):
        p = steering.link_avoiding_program(8, bad)
        p.validate()
        off = np.asarray(p.offsets)
        assert (np.sign(off) == -bad).all()
    with pytest.raises(ValueError):
        steering.link_avoiding_program(8, 0)


def test_route_program_validate_rejects_incongruent():
    p = steering.unidirectional_program(4)
    bad = dataclasses.replace(p, offsets=jnp.asarray([1, 3, 3], jnp.int32))
    with pytest.raises(ValueError):
        bad.validate()
    # an inconsistent group mask (dead slot still serving ranks) is caught
    ghost = dataclasses.replace(
        p, live=jnp.asarray([True, False, True]))
    with pytest.raises(ValueError):
        ghost.validate()


def test_bridge_rejects_wrong_sized_program():
    with pytest.raises(ValueError):
        bridge._resolve_program(steering.unidirectional_program(4), 8)


def test_ref_oracle_honours_programs():
    """Requests whose ring distance has no wired circuit come back zeroed."""
    n, ppn = 4, 8
    pool = make_pool_np(n * ppn, 4)
    table = MemPortTable.striped(12, n, ppn)
    want = jnp.asarray(np.tile(np.arange(12, dtype=np.int32), (n, 1)))
    full = np.asarray(ref.pull_pages_ref(pool, want, table,
                                         pages_per_node=ppn))
    pruned = steering.pruned_program(steering.bidirectional_program(n), [1, 3])
    got = np.asarray(ref.pull_pages_ref(pool, want, table,
                                        pages_per_node=ppn, program=pruned))
    home = np.asarray(table.home)
    for node in range(n):
        for r in range(12):
            d = (home[r] - node) % n
            if d in (0, 1, 3):
                np.testing.assert_allclose(got[node, r], full[node, r])
            else:
                np.testing.assert_array_equal(got[node, r], 0.0)


def test_loopback_honours_program():
    """The n == 1 fast path applies the same program semantics (and oracle)
    as the N-device path: unwired logical distances drop their pages."""
    tn, ppn = 4, 8
    pool = make_pool_np(tn * ppn, 4)
    table = MemPortTable.striped(12, tn, ppn)
    want = jnp.asarray(np.arange(12, dtype=np.int32)[None, :])
    prog = steering.pruned_program(steering.bidirectional_program(tn), [1, 3])
    got = bridge.pull_pages(pool, want, table, mesh=None, budget=4,
                            table_nodes=tn, program=prog)
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=ppn,
                             program=prog)
    np.testing.assert_allclose(got, exp)
    full = np.asarray(ref.pull_pages_ref(pool, want, table,
                                         pages_per_node=ppn))
    assert not np.array_equal(np.asarray(got), full)  # distance 2 dropped
    # push path: unwired writes are dropped too
    payload = jnp.ones((1, 12, 4), jnp.float32)
    got_p = bridge.push_pages(pool, want, payload, table, mesh=None,
                              budget=4, table_nodes=tn, program=prog)
    exp_p = ref.push_pages_ref(pool, want, payload, table,
                               pages_per_node=ppn, program=prog)
    np.testing.assert_allclose(got_p, exp_p)
    # wrong-sized programs are rejected on the loopback path as well
    with pytest.raises(ValueError):
        bridge.pull_pages(pool, want, table, mesh=None, budget=4,
                          table_nodes=tn,
                          program=steering.bidirectional_program(8))


def test_control_plane_route_program():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=64)
    cp.allocate(8, policy="affinity", affinity=2)
    # node-0 requesters only reach distance 2
    p = cp.route_program(requesters=[0])
    assert list(p.live_distances()) == [2]
    # all requesters: distances {2-j mod 4} = {1, 2, 3}
    assert list(cp.route_program().live_distances()) == [1, 2, 3]
    # link failure reroutes everything the other way round
    cp.report_link_failure(+1)
    p = cp.route_program()
    off = np.asarray(p.offsets)
    assert (off[np.asarray(p.live)] < 0).all()
    cp.clear_link_failure()
    p = cp.route_program(prune=False)
    assert p.num_epochs() == 2  # bidirectional again: ceil(4/2)


def test_perfmodel_route_costs():
    uni = steering.unidirectional_program(8)
    bi = steering.bidirectional_program(8)
    s_uni = perfmodel.route_epoch_stats(uni)
    s_bi = perfmodel.route_epoch_stats(bi)
    assert s_uni["num_epochs"] == 7 and s_bi["num_epochs"] == 4
    assert s_bi["total_hops"] < s_uni["total_hops"]
    for eb in (True, False):
        assert (perfmodel.predict_round_latency_us(bi, 1 << 18, 8,
                                                   edge_buffer=eb)
                < perfmodel.predict_round_latency_us(uni, 1 << 18, 8,
                                                     edge_buffer=eb))
    pruned = steering.pruned_program(bi, [2])
    assert perfmodel.route_epoch_stats(pruned)["live_slots"] == 1


# ---------------------------------------------------------------------------
# ControlPlane fail_node / revive_node interplay
# ---------------------------------------------------------------------------

def test_fail_node_quarantines_slots():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=64)
    cp.allocate(16, policy="striped")
    cp.fail_node(1)
    assert cp.free_slots(1) == 0  # quarantined, not reusable
    # new allocations can never land on the dead node
    region = cp.allocate(8, policy="hashed")
    homes = np.asarray(cp.table().home)[region.page_ids]
    assert not np.any(homes == 1)


def test_revive_then_second_failure_rehomes_correctly():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=64)
    cp.allocate(12, policy="striped")
    cp.fail_node(1)
    cp.revive_node(1)
    # revived node's free list excludes nothing (its pages all moved away)
    assert cp.free_slots(1) == 8
    cp.allocate(4, policy="affinity", affinity=1)
    plan = cp.fail_node(1)
    assert len(plan) == 4
    assert all(s.old_home == 1 and s.new_home != 1 for s in plan)
    home, slot = np.asarray(cp._home), np.asarray(cp._slot)
    mapped = home != FREE
    # no slot double-booked after the fail -> revive -> fail cycle
    pairs = set(zip(home[mapped].tolist(), slot[mapped].tolist()))
    assert len(pairs) == mapped.sum()
    assert not np.any(home == 1)


def test_revive_preserves_occupied_slots():
    """Slots that still appear in the table are not handed back as free."""
    cp = ControlPlane(num_nodes=2, pages_per_node=6, num_logical=8)
    cp.allocate(2, policy="affinity", affinity=1)
    cp.fail_node(1)          # pages rehomed to node 0
    cp.revive_node(1)
    assert cp.free_slots(1) == 6
    cp.allocate(3, policy="affinity", affinity=1)
    cp.fail_node(0)          # node 0's pages (incl. migrated) move to node 1
    home = np.asarray(cp.table().home)
    mapped = home != FREE
    assert (home[mapped] == 1).all()


def test_route_program_keeps_failed_ranks_distances():
    """Regression: a failed node's *rank* still issues bridge requests (the
    mesh never shrinks), so pruning must not drop the distances it needs.

    2-node repro: fail node 1 -> all pages homed on node 0; rank 1 reaches
    them at ring distance 1, which an alive-nodes-only prune would cut —
    silently zeroing every page rank 1 pulls (e.g. zero_bridge restore)."""
    cp = ControlPlane(num_nodes=2, pages_per_node=8, num_logical=8)
    cp.allocate(4, policy="striped")
    cp.fail_node(1)
    prog = cp.route_program()
    assert list(prog.live_distances()) == [1]
    # pulled through the oracle: rank 1's requests survive the program
    pool = make_pool_np(16, 4)
    want = jnp.asarray(np.tile(np.arange(4, dtype=np.int32), (2, 1)))
    got = ref.pull_pages_ref(pool, want, cp.table(), pages_per_node=8,
                             program=prog)
    full = ref.pull_pages_ref(pool, want, cp.table(), pages_per_node=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(full))


def test_release_respects_slot_quarantine():
    """fail -> release -> revive: releasing a region must not hand slots
    back to a dead node's free list (a heartbeat monitor may mark a node
    dead before any remap ran); revive reclaims them from the table."""
    cp = ControlPlane(num_nodes=2, pages_per_node=8, num_logical=16)
    region = cp.allocate(6, policy="affinity", affinity=1)
    # monitor-style death: marked dead, pages not (yet) remapped
    cp.nodes[1].alive = False
    cp._free[1] = []
    cp.release(region)
    assert cp.free_slots(1) == 0          # quarantine respected
    assert np.all(np.asarray(cp._home) == FREE)
    cp.revive_node(1)
    # revive rebuilds from the table: the released slots come back
    assert cp.free_slots(1) == 8
    region2 = cp.allocate(4, policy="affinity", affinity=1)
    assert np.all(np.asarray(cp.table().home)[region2.page_ids] == 1)
    # the full fail_node path stays consistent with release
    cp2 = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=16)
    r = cp2.allocate(8, policy="striped")
    cp2.fail_node(2)
    cp2.release(r)                         # all pages re-homed to survivors
    assert cp2.free_slots(2) == 0
    assert sum(cp2.free_slots(i) for i in (0, 1, 3)) == 24


def test_migration_plan_roundtrips_through_table():
    """Applying the emitted MigrationSteps to the *old* table reproduces the
    control plane's new table exactly (the plan is a complete delta)."""
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=64)
    cp.allocate(16, policy="striped")
    old_table = cp.table()
    plan = cp.fail_node(2)
    ids = np.asarray([s.page_id for s in plan])
    homes = np.asarray([s.new_home for s in plan])
    slots = np.asarray([s.new_slot for s in plan])
    rebuilt = old_table.program(ids, homes, slots)
    new_table = cp.table()
    np.testing.assert_array_equal(np.asarray(rebuilt.home),
                                  np.asarray(new_table.home))
    np.testing.assert_array_equal(np.asarray(rebuilt.slot),
                                  np.asarray(new_table.slot))
    # and the old coordinates in the plan match the old table
    for s in plan:
        assert int(old_table.home[s.page_id]) == s.old_home
        assert int(old_table.slot[s.page_id]) == s.old_slot


# ---------------------------------------------------------------------------
# Pipelined multi-channel round engine + push/pull parity bugfixes
# ---------------------------------------------------------------------------

def _one_node_mesh():
    return jax.make_mesh((1,), ("data",))


def _run_pull_local(pool, want_row, active_budget, *, budget, rounds,
                    channels=1):
    """Drive bridge._pull_local directly (1-node mem axis) — the only way
    to hand the scan body inputs the public wrapper pre-sanitizes."""
    import functools
    from jax.sharding import PartitionSpec as P
    mesh = _one_node_mesh()
    table = MemPortTable.striped(pool.shape[0], 1, pool.shape[0])
    prog = steering.bidirectional_program(1)
    body = functools.partial(bridge._pull_local, axis="data", num_nodes=1,
                             budget=budget, rounds=rounds, edge_buffer=True,
                             channels=channels)

    def mapped(pool_l, want_l, ab):
        return body(pool_l, want_l[0], table, ab[0], prog)[None]

    with bridge.use_mesh(mesh):
        return np.asarray(bridge.shard_map(
            mapped, mesh,
            in_specs=(P("data", None), P("data", None), P("data")),
            out_specs=P("data", None, None), mem_axis="data",
        )(pool, jnp.asarray(want_row)[None],
          jnp.asarray([active_budget], jnp.int32))[0])


def _run_push_local(pool, dest_row, payload_rows, active_budget, *, budget,
                    rounds, channels=1):
    import functools
    from jax.sharding import PartitionSpec as P
    mesh = _one_node_mesh()
    table = MemPortTable.striped(pool.shape[0], 1, pool.shape[0])
    prog = steering.bidirectional_program(1)
    body = functools.partial(bridge._push_local, axis="data", num_nodes=1,
                             budget=budget, rounds=rounds, channels=channels)

    def mapped(pool_l, dest_l, pay_l, ab):
        return body(pool_l, dest_l[0], pay_l[0], table, ab[0], prog)

    with bridge.use_mesh(mesh):
        return np.asarray(bridge.shard_map(
            mapped, mesh,
            in_specs=(P("data", None), P("data", None),
                      P("data", None, None), P("data")),
            out_specs=P("data", None), mem_axis="data",
        )(pool, jnp.asarray(dest_row)[None],
          jnp.asarray(payload_rows)[None],
          jnp.asarray([active_budget], jnp.int32)))


def test_pull_push_signature_parity():
    """Regression: push_pages historically lacked pull's edge_buffer knob.
    Every shared bridge knob must exist on both paths with one default."""
    import inspect
    pull = inspect.signature(bridge.pull_pages).parameters
    push = inspect.signature(bridge.push_pages).parameters
    shared = ("mesh", "mem_axis", "budget", "edge_buffer", "channels",
              "overprovision", "active_budget", "program", "table_nodes",
              "collect_telemetry", "topology")
    for name in shared:
        assert name in pull, f"pull_pages lost {name!r}"
        assert name in push, f"push_pages missing {name!r}"
        assert pull[name].default == push[name].default, name
    locals_ = (inspect.signature(bridge._pull_local).parameters,
               inspect.signature(bridge._push_local).parameters)
    for name in ("edge_buffer", "channels"):
        assert all(name in p for p in locals_), name


def test_pull_local_rounds_zero_returns_request_shaped_zeros():
    """Regression: rounds == 0 with a non-empty ``want`` must return the
    [want.shape[0], *page] all-dropped zeros the docstring promises, not a
    zero-row array (the caller indexes it by request position)."""
    pool = make_pool_np(16, 4)
    want = np.asarray([3, 0, FREE, 7, 11], np.int32)
    got = _run_pull_local(pool, want, 8, budget=8, rounds=0)
    assert got.shape == (5, 4)
    np.testing.assert_array_equal(got, np.zeros((5, 4), np.float32))
    # telemetry counts every live request as a rate-limiter drop
    from repro.telemetry.counters import transfer_telemetry
    from repro.core.topology import Topology
    topo = Topology.flat(1)
    telem = transfer_telemetry(
        jnp.asarray(want), MemPortTable.striped(16, 1, 16),
        steering.bidirectional_program(1), jnp.int32(8), my=0, num_nodes=1,
        budget=8, rounds=0, topo=topo.tables(), num_groups=1)
    assert int(telem.spilled) == 4  # the FREE hole is not a live request
    assert int(telem.served_total()) == 0


@pytest.mark.parametrize("channels", [1, 2])
def test_pull_local_overdriven_budget_clamps(channels):
    """Regression: an ``active_budget`` above ``budget`` used to walk the
    round pointer past the final window, so ``dynamic_slice`` silently
    clamped and re-served tail requests into the wrong output rows."""
    pool = make_pool_np(16, 4)
    table = MemPortTable.striped(16, 1, 16)
    want = np.arange(16, dtype=np.int32)
    got = _run_pull_local(pool, want, 12, budget=8, rounds=2,
                          channels=channels)
    exp = np.asarray(ref.pull_pages_ref(pool, jnp.asarray(want)[None],
                                        table, pages_per_node=16))[0]
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("channels", [1, 2])
def test_push_local_overdriven_budget_clamps(channels):
    """Write-path twin of the clamp regression, plus spill accounting: the
    telemetry oracle (which clips) must agree with what actually landed."""
    pool = make_pool_np(16, 4)
    table = MemPortTable.striped(16, 1, 16)
    dest = np.arange(12, dtype=np.int32)
    padded = steering.pad_requests(dest, 2, 8)
    payload = np.zeros((16, 4), np.float32)
    payload[:12] = np.arange(1, 13, dtype=np.float32)[:, None]
    got = _run_push_local(pool, padded, payload, 9, budget=8, rounds=2,
                          channels=channels)
    exp = np.asarray(ref.push_pages_ref(
        pool, jnp.asarray(dest)[None], jnp.asarray(payload[None, :12]),
        table, pages_per_node=16))
    np.testing.assert_array_equal(got, exp)
    telem = ref.expected_transfer_telemetry(
        padded[None], table, None, num_nodes=1, budget=8, active_budget=9,
        overprovision=2)
    assert int(np.asarray(telem.spilled).sum()) == 0  # window covers all 12


def test_channels_loopback_and_serial_paths_identical():
    """channels is a no-op on the loopback path and must be accepted
    everywhere the serial engine runs (edge_buffer=False, n == 1)."""
    pool = make_pool_np(16, 8)
    table = MemPortTable.striped(12, 1, 16)
    want = jnp.asarray([[3, 0, 7, FREE, 11, 2]], jnp.int32)
    base = np.asarray(bridge.pull_pages(pool, want, table, mesh=None,
                                        budget=4))
    for ch in (2, 4):
        got = np.asarray(bridge.pull_pages(pool, want, table, mesh=None,
                                           budget=4, channels=ch))
        np.testing.assert_array_equal(got, base)
    with pytest.raises(ValueError):
        bridge.pull_pages(pool, want, table, mesh=None, budget=4, channels=0)
    with pytest.raises(ValueError):
        bridge.push_pages(pool, want, jnp.ones((1, 6, 8)), table, mesh=None,
                          budget=4, channels=-1)


def test_control_plane_select_channels():
    """Pipeline depth from measured wire occupancy: serial when idle or
    wire-bound (nothing worth hiding), deep when the RTT is a comparable
    share of the round (latency-bound: overlap wins)."""
    from repro.telemetry import TelemetryAggregator
    n = 8
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=8)
    assert cp.select_channels(8, 1 << 18) == 1            # no measurement
    agg = TelemetryAggregator(n, page_bytes=4096)
    assert cp.select_channels(8, 4096, telemetry=agg) == 1  # idle wire
    tm = np.zeros((n, n), np.int32)
    for i in range(n):
        tm[i, (i + 1) % n] = 16
        tm[i, (i + 3) % n] = 8
    agg.update(fake_telem(n, tm))
    deep = cp.select_channels(8, 4096, telemetry=agg)      # latency-bound
    assert deep > 1
    assert deep <= 8
    assert cp.select_channels(8, 1 << 20, telemetry=agg) == 1  # wire-bound
    assert cp.select_channels(1, 4096, telemetry=agg) == 1     # budget floor
    # one step's raw BridgeTelemetry works like the aggregator
    assert cp.select_channels(8, 4096, telemetry=fake_telem(n, tm)) == deep
    # program-aware RTT: a schedule routing traffic the long way round pays
    # its real hop depth — the shortest-way fallback (min(d, N-d) = 1 hop
    # for distance 7) would call this wire-bound and stay serial
    tm_far = np.zeros((n, n), np.int32)
    for i in range(n):
        tm_far[i, (i + 7) % n] = 24
    agg_far = TelemetryAggregator(n, page_bytes=1 << 15)
    agg_far.update(fake_telem(n, tm_far))
    uni = steering.unidirectional_program(n)          # d=7 driven as +7 hops
    assert cp.select_channels(8, 1 << 15, telemetry=agg_far) == 1
    assert cp.select_channels(8, 1 << 15, telemetry=agg_far,
                              program=uni) > 1
