"""Bridge transfer-engine correctness: bridge == pure-jnp oracle.

Single-device (N=1 loopback) cases run here; multi-node ring tests run in a
subprocess with 8 virtual devices (see test_distributed.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bridge, ref
from repro.core.memport import FREE, MemPortTable
from repro.core.control_plane import ControlPlane


def make_pool_np(num_slots, page, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(num_slots, page)).astype(np.float32))


def test_pull_single_node_matches_ref():
    pool = make_pool_np(16, 8)
    table = MemPortTable.striped(12, 1, 16)
    want = jnp.asarray([[3, 0, 7, FREE, 11, 2]], jnp.int32)
    got = bridge.pull_pages(pool, want, table, mesh=None, budget=4)
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=16)
    np.testing.assert_allclose(got, exp)


def test_push_single_node_matches_ref():
    pool = make_pool_np(16, 8)
    table = MemPortTable.striped(12, 1, 16)
    dest = jnp.asarray([[5, 1, FREE, 9]], jnp.int32)
    payload = jnp.ones((1, 4, 8), jnp.float32) * jnp.arange(4)[None, :, None]
    got = bridge.push_pages(pool, dest, payload, table, mesh=None, budget=2)
    exp = ref.push_pages_ref(pool, dest, payload, table, pages_per_node=16)
    np.testing.assert_allclose(got, exp)


@settings(max_examples=25, deadline=None)
@given(
    num_logical=st.integers(1, 24),
    budget=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
def test_pull_property_random_requests(num_logical, budget, seed):
    """Any request list (dups, FREE holes, unmapped pages) matches the oracle."""
    rng = np.random.default_rng(seed)
    pool = make_pool_np(32, 4, seed)
    table = MemPortTable.striped(num_logical, 1, 32)
    r = int(rng.integers(1, 16))
    want = rng.integers(-1, num_logical, size=(1, r)).astype(np.int32)
    got = bridge.pull_pages(pool, jnp.asarray(want), table,
                            mesh=None, budget=budget)
    exp = ref.pull_pages_ref(pool, jnp.asarray(want), table, pages_per_node=32)
    np.testing.assert_allclose(got, exp)


def test_memport_translate_free_passthrough():
    t = MemPortTable.striped(8, 2, 4)
    home, slot = t.translate(jnp.asarray([0, FREE, 7], jnp.int32))
    assert home[1] == FREE and slot[1] == FREE
    assert home[0] == 0 and slot[0] == 0
    assert home[7 % 3 if False else 2] >= 0


def test_memport_runtime_reprogram():
    t = MemPortTable.striped(8, 2, 4)
    t2 = t.program(np.array([3]), np.array([1]), np.array([2]))
    assert int(t2.home[3]) == 1 and int(t2.slot[3]) == 2
    # untouched rows preserved
    assert int(t2.home[0]) == int(t.home[0])


def test_control_plane_alloc_and_fail():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=64)
    region = cp.allocate(16, "kv", policy="striped")
    occ = cp.occupancy()
    assert occ.sum() == 16 and occ.max() == 4
    plan = cp.fail_node(2)
    assert len(plan) == 4  # node 2 held 4 pages
    assert all(s.new_home != 2 for s in plan)
    occ = cp.occupancy()
    assert occ[2] == 0 and occ.sum() == 16
    # table stays consistent
    t = cp.table()
    assert not np.any(np.asarray(t.home) == 2)
    region2 = cp.allocate(8, policy="hashed")
    t2 = cp.table()
    homes = np.asarray(t2.home)[region2.page_ids]
    assert not np.any(homes == 2)


def test_control_plane_straggler_rate_limits():
    cp = ControlPlane(num_nodes=4, pages_per_node=8, num_logical=8)
    for step in range(8):
        for n in range(4):
            cp.record_step_time(n, 1.0 if n != 3 else 2.5)
    budgets = cp.rate_limits(static_budget=8)
    assert list(budgets[:3]) == [8, 8, 8]
    assert budgets[3] == 4


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), nodes=st.integers(1, 6))
def test_control_plane_invariants(seed, nodes):
    """No slot double-booked; every mapped page has a live home."""
    rng = np.random.default_rng(seed)
    cp = ControlPlane(num_nodes=nodes, pages_per_node=8, num_logical=64)
    regions = []
    # Keep total allocation at <= half capacity so a failed node's pages
    # always fit on survivors.
    remaining = nodes * 8 // 2
    for _ in range(int(rng.integers(1, 4))):
        n = int(rng.integers(1, 8))
        if n > remaining:
            break
        remaining -= n
        regions.append(cp.allocate(n, policy=str(rng.choice(
            ["striped", "hashed"]))))
    if nodes > 1 and rng.random() < 0.5:
        cp.fail_node(int(rng.integers(0, nodes)))
    home, slot = np.asarray(cp._home), np.asarray(cp._slot)
    mapped = home != FREE
    pairs = set(zip(home[mapped].tolist(), slot[mapped].tolist()))
    assert len(pairs) == mapped.sum(), "slot double-booked"
    for h in home[mapped]:
        assert cp.nodes[h].alive, "page homed on dead node"


def test_rate_limited_pull_matches_ref():
    """Throttled budget (overprovisioned rounds) still returns every page."""
    pool = make_pool_np(32, 4)
    table = MemPortTable.striped(24, 1, 32)
    want = jnp.arange(24, dtype=jnp.int32)[None, :]
    got = bridge.pull_pages(pool, want, table, mesh=None, budget=8,
                            overprovision=2, active_budget=jnp.int32(5))
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=32)
    np.testing.assert_allclose(got, exp)
