"""Multi-device (8 virtual CPU) validation, run in subprocesses.

Device count must be fixed before jax initializes, so these scripts cannot
import jax in the pytest process — each runs as ``python tests/distributed/
run_*.py`` with XLA_FLAGS set inside the script itself.
"""
import pathlib
import subprocess
import sys

import pytest

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent


def run_script(name: str, timeout: int = 900) -> str:
    proc = subprocess.run(
        [sys.executable, str(HERE / "distributed" / name)],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{name} failed\n--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.mark.slow
def test_bridge_8dev():
    out = run_script("run_bridge_8dev.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_zero_bridge_8dev():
    out = run_script("run_zero_8dev.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_compressed_dp_8dev():
    out = run_script("run_compress_8dev.py")
    assert "ALL OK" in out


@pytest.mark.slow
def test_pipeline_8dev():
    out = run_script("run_pipeline_8dev.py")
    assert "ALL OK" in out
