"""Multi-device (8 virtual CPU) validation, run in subprocesses.

Device count must be fixed before jax initializes, so these suites cannot
import jax in the pytest process — the ``run_8dev`` fixture executes each
``tests/distributed/run_*_8dev.py`` as ``python <script>`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` pinned in the
environment (the scripts also self-pin, so they stay runnable by hand).

Every ``run_*_8dev.py`` under tests/distributed/ is **auto-collected** via
the parametrized test below: dropping a new 8-device suite in that
directory makes CI run (and fail on) it with no further wiring, and a
regression in any suite fails tier-1 rather than passing silently.
"""
import pathlib
import sys

import pytest

HERE = pathlib.Path(__file__).parent
REPO = HERE.parent

SCRIPTS = sorted(p.name for p in (HERE / "distributed").glob("run_*_8dev.py"))
assert SCRIPTS, "no tests/distributed/run_*_8dev.py scripts found"


@pytest.fixture
def run_8dev(request):
    """Subprocess runner for the 8-virtual-device suites.

    Returns a callable ``run(name, timeout=900) -> stdout`` that raises an
    AssertionError carrying the script's tail output on non-zero exit.
    """
    import subprocess

    def run(name: str, timeout: int = 900) -> str:
        proc = subprocess.run(
            [sys.executable, str(HERE / "distributed" / name)],
            capture_output=True, text=True, timeout=timeout,
            env={"PYTHONPATH": str(REPO / "src"),
                 "PATH": "/usr/bin:/bin", "HOME": "/root",
                 "JAX_PLATFORMS": "cpu",
                 "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        )
        if proc.returncode != 0:
            raise AssertionError(
                f"{name} failed\n--- stdout ---\n{proc.stdout[-4000:]}\n"
                f"--- stderr ---\n{proc.stderr[-4000:]}")
        return proc.stdout

    return run


@pytest.mark.slow
@pytest.mark.parametrize("script", SCRIPTS)
def test_8dev_suite(run_8dev, script):
    out = run_8dev(script)
    assert "ALL OK" in out, f"{script} finished without its ALL OK marker"
