"""Static verifier vs runtime oracle (randomized).

The contract of ``repro.analysis.program_check``: its verdict is the
*static* image of what the datapath oracle realizes at runtime —

* every shipped steering constructor checks clean on random (possibly
  ragged) fabrics;
* the static ``coverage`` map equals :func:`repro.core.ref.served_mask`
  for every (requester, page) pair;
* the runtime telemetry walk (:func:`ref.expected_transfer_telemetry`)
  prunes exactly the pairings ``coverage`` marks unwired, and conserves
  every live request;
* random corruptions of a valid program always surface at least one
  finding, and ``ControlPlane.route_program(verify=True)`` refuses to
  install them.

Real hypothesis when installed, the seeded fallback otherwise (same
convention as test_bridge_properties.py).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from topologies import make_pool, random_fabric  # noqa: F401

from repro.analysis import (ProgramVerificationError, check_program,
                            coverage, errors)
from repro.core import ref, steering
from repro.core.control_plane import ControlPlane
from repro.core.memport import MemPortTable

pytestmark = [pytest.mark.property, pytest.mark.analysis]


def _flat_variants(rng, n):
    w = rng.integers(0, 5, size=max(n - 1, 1))
    w[int(rng.integers(0, w.size))] += 1  # at least one live distance
    variants = [steering.unidirectional_program(n),
                steering.unidirectional_program(n, direction=-1),
                steering.bidirectional_program(n),
                steering.link_avoiding_program(n, 1),
                steering.link_avoiding_program(n, -1),
                steering.load_balanced_program(n, w)]
    keep = [d for d in range(1, n) if rng.random() < 0.6] or [1]
    variants.append(
        steering.pruned_program(steering.bidirectional_program(n), keep))
    return variants


def _hier_variants(rng, topo):
    full = steering.hierarchical_program(topo)
    mask = rng.random(np.asarray(full.rank_epoch).shape) < 0.8
    return [full, steering.masked_ranks_program(full, mask)]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_shipped_constructors_verify_clean(seed):
    """Every constructor's output is finding-free — warnings included."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n = topo.num_nodes
    for prog in _flat_variants(rng, n):
        assert check_program(prog) == []
    for prog in _hier_variants(rng, topo):
        assert check_program(prog, topo) == []


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_coverage_agrees_with_served_mask(seed):
    """static coverage[d-1, i] == runtime served_mask for every request."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n, ppn = topo.num_nodes, 8
    num_logical = int(rng.integers(1, n * ppn + 1))
    table = MemPortTable.striped(num_logical, n, ppn)
    progs = _hier_variants(rng, topo) + [
        _flat_variants(rng, n)[int(rng.integers(0, 7))]]
    r = int(rng.integers(1, 12))
    ids = rng.integers(0, num_logical, size=(n, r)).astype(np.int32)
    home = np.asarray(table.home)
    for prog in progs:
        cov = coverage(prog)
        got = np.asarray(ref.served_mask(table, jnp.asarray(ids), prog))
        d = (home[ids] - np.arange(n)[:, None]) % n
        exp = np.where(d == 0, True,
                       cov[np.maximum(d - 1, 0), np.arange(n)[:, None]])
        np.testing.assert_array_equal(got, exp)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_telemetry_oracle_prunes_exactly_uncovered(seed):
    """With no throttle, the runtime walk prunes exactly the pairings the
    static coverage map marks unwired — and conserves every request."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n, ppn = topo.num_nodes, 8
    num_logical = int(rng.integers(1, n * ppn + 1))
    table = MemPortTable.striped(num_logical, n, ppn)
    r = int(rng.integers(1, 12))
    ids = rng.integers(0, num_logical, size=(n, r)).astype(np.int32)
    home = np.asarray(table.home)
    d = (home[ids] - np.arange(n)[:, None]) % n
    for prog in _hier_variants(rng, topo):
        cov = coverage(prog)
        wired = np.where(
            d == 0, True, cov[np.maximum(d - 1, 0), np.arange(n)[:, None]])
        telem = ref.expected_transfer_telemetry(
            ids, table, prog, num_nodes=n, budget=r, topology=topo)
        pruned = np.asarray(telem.pruned)
        loop = np.asarray(telem.loopback_served)
        slot = np.asarray(telem.slot_served)
        np.testing.assert_array_equal(pruned, (~wired).sum(1))
        np.testing.assert_array_equal(loop, (d == 0).sum(1))
        np.testing.assert_array_equal(slot.sum(1),
                                      (wired & (d > 0)).sum(1))
        # conservation: nothing spills at budget == r, nothing vanishes
        assert int(np.asarray(telem.spilled).sum()) == 0
        assert int(pruned.sum() + loop.sum() + slot.sum()) == ids.size


def _corrupt(rng, prog):
    """One random single-field corruption of a live slot; returns
    (mutated program, what was done)."""
    live = np.asarray(prog.live)
    slots = np.nonzero(live)[0]
    k = int(rng.choice(slots))
    n = prog.num_nodes
    off = np.asarray(prog.offsets).copy()
    ep = np.asarray(prog.epoch).copy()
    lv = live.copy()
    re = np.asarray(prog.rank_epoch).copy()
    op = int(rng.integers(0, 6))
    if op == 0:       # live bit cleared, routing state left behind (PC104)
        lv[k] = False
    elif op == 1:     # live slot serving nobody (PC105)
        re[k, :] = -1
    elif op == 2:     # offset off its congruence class (PC102/PC103)
        off[k] += 1
    elif op == 3:     # zero offset on a live slot (PC103)
        off[k] = 0
    elif op == 4:     # base epoch out of step with the group mask (PC106)
        ep[k] += 1
    else:             # epoch beyond the telemetry bins (PC107)
        r0 = int(np.nonzero(re[k] >= 0)[0][0])
        re[k, r0] = 2 * (n - 1) + 3
    return dataclasses.replace(
        prog,
        offsets=jnp.asarray(off, jnp.int32), epoch=jnp.asarray(ep, jnp.int32),
        live=jnp.asarray(lv), rank_epoch=jnp.asarray(re, jnp.int32)), op


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_random_corruption_is_caught_and_refused(seed):
    """Any single corruption yields >= 1 error finding, and the control
    plane refuses to install the program."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n = topo.num_nodes
    hier = rng.random() < 0.5
    if hier:
        prog = _hier_variants(rng, topo)[int(rng.integers(0, 2))]
        cp_topo = topo
    else:
        prog = _flat_variants(rng, n)[int(rng.integers(0, 7))]
        cp_topo = None
    assert errors(check_program(prog, cp_topo)) == []
    bad, op = _corrupt(rng, prog)
    found = errors(check_program(bad, cp_topo))
    assert found, f"corruption op {op} produced no error finding"
    cp = ControlPlane(num_nodes=n, pages_per_node=8, num_logical=2 * n,
                      topology=cp_topo)
    cp.allocate(2 * n)
    with pytest.raises(ProgramVerificationError):
        cp.route_program(program=bad)
    # the escape hatch still installs it (fault-injection path)
    assert cp.route_program(program=bad, verify=False) is bad
