"""Per-architecture smoke tests: reduced config, one forward + train-grad +
decode step on CPU; asserts shapes and no NaNs.  (Assignment deliverable f.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import ATTENTION_KINDS
from repro.models import transformer

ARCHS = configs.lm_archs()


def make_batch(cfg, batch=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    if cfg.embed_inputs:
        out["embeds"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.d_model)).astype(np.float32))
    else:
        out["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32)
    out["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(batch, seq)), jnp.int32)
    if cfg.num_encoder_layers > 0:
        out["enc_embeds"] = jnp.asarray(
            rng.normal(size=(batch, 8, cfg.d_model)).astype(np.float32))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_grad(arch):
    cfg = configs.get_reduced(arch)
    cfg = __import__("dataclasses").replace(cfg, dtype="float32")
    params = transformer.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    logits, _ = jax.jit(lambda p, b: transformer.forward(cfg, p, b))(
        params, batch)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: transformer.loss_fn(cfg, p, batch)[0]))(params)
    assert np.isfinite(float(loss))
    gnorm = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.square(x.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.get_reduced(arch)
    cfg = __import__("dataclasses").replace(cfg, dtype="float32")
    params = transformer.init_params(cfg, jax.random.key(0))
    b = 2
    ops = transformer.DenseCacheOps(max_len=8, dtype=jnp.float32)
    enc_out = None
    if cfg.cross_attention:
        enc_out = jnp.asarray(np.random.default_rng(0).normal(
            size=(b, 8, cfg.d_model)).astype(np.float32))
    state = transformer.init_decode_state(cfg, b, ops, enc_out=enc_out)
    tokens = jnp.asarray([1, 2], jnp.int32)
    step = jax.jit(lambda p, s, t: transformer.decode_step(cfg, p, s, t, ops))
    for i in range(3):
        logits, state = step(params, state, tokens)
        assert logits.shape == (b, cfg.vocab_size)
        assert not np.any(np.isnan(np.asarray(logits, np.float32)))
        tokens = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(state["lengths"][0]) == 3


def test_decode_matches_forward_full_attn():
    """Greedy decode logits == teacher-forced forward logits (dense arch)."""
    cfg = configs.get_reduced("granite-3-8b")
    cfg = __import__("dataclasses").replace(cfg, dtype="float32")
    params = transformer.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(3)
    b, s = 2, 6
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_fwd, _ = transformer.forward(cfg, params, {"tokens": tokens})

    ops = transformer.DenseCacheOps(max_len=s, dtype=jnp.float32)
    state = transformer.init_decode_state(cfg, b, ops)
    outs = []
    for i in range(s):
        lg, state = transformer.decode_step(cfg, params, state,
                                            tokens[:, i], ops)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_fwd), atol=2e-3)


def test_decode_matches_forward_hybrid():
    """Same equivalence for the RG-LRU + SWA hybrid (recurrentgemma)."""
    cfg = configs.get_reduced("recurrentgemma-9b")
    cfg = __import__("dataclasses").replace(cfg, dtype="float32")
    params = transformer.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(3)
    b, s = 2, 6
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_fwd, _ = transformer.forward(cfg, params, {"tokens": tokens})
    ops = transformer.DenseCacheOps(max_len=s, dtype=jnp.float32)
    state = transformer.init_decode_state(cfg, b, ops)
    outs = []
    for i in range(s):
        lg, state = transformer.decode_step(cfg, params, state,
                                            tokens[:, i], ops)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_fwd), atol=2e-3)


def test_decode_matches_forward_xlstm():
    """Recurrent (mLSTM/sLSTM) decode == sequence forward."""
    cfg = configs.get_reduced("xlstm-125m")
    cfg = __import__("dataclasses").replace(cfg, dtype="float32")
    params = transformer.init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(3)
    b, s = 2, 6
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    logits_fwd, _ = transformer.forward(cfg, params, {"tokens": tokens})
    ops = transformer.DenseCacheOps(max_len=s, dtype=jnp.float32)
    state = transformer.init_decode_state(cfg, b, ops)
    outs = []
    for i in range(s):
        lg, state = transformer.decode_step(cfg, params, state,
                                            tokens[:, i], ops)
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_fwd), atol=2e-3)
