"""repro.serve.batcher / traffic — the request-level serving front end.

Covers the continuous-batching acceptance contract:

* traffic: seeded determinism (per-(seed, tenant, step) streams make a
  tenant's arrivals independent of the mix), length bounds, arrival
  windows,
* fidelity: continuous-batched decode is bit-identical to a solo run of
  each request — on the simulated engine (which deliberately leaks state
  across slot reuse unless the batcher resets on admit) and on the real
  reduced-model jitted step,
* invariants, property-tested over random tenant mixes x queue depths:
  no slot double-assigned, every admitted sequence retires, request
  conservation (submitted == completed + shed + queued + active), every
  lease released at drain, peak concurrency >= slot occupancy,
* admission edges: oversized requests shed (never livelock the queue),
  attempt-bounded shedding, naive-vs-QoS flood isolation,
* the orchestrator hook: ``refit_windows`` steers bridge windows from
  serving queue depths.
"""
import numpy as np
import pytest

from repro.core.control_plane import ControlPlane
from repro.obs.clock import ManualClock
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import CAT_REQUEST, TraceRecorder
from repro.orchestrator import Orchestrator, TenantSpec
from repro.serve.batcher import (ContinuousBatcher, SimulatedDecodeEngine,
                                 serve_loop, solo_reference)
from repro.serve.traffic import (Request, TenantTraffic, TrafficGenerator,
                                 make_request)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # pragma: no cover
    from hypofallback import given, settings, st


def mk_orc(num_nodes=4, pages_per_node=64, num_logical=None, specs=None,
           **kw):
    cp = ControlPlane(num_nodes, pages_per_node,
                      num_logical=num_logical or num_nodes * pages_per_node)
    orc = Orchestrator(cp, budget=8, control_period=2, migrate=False, **kw)
    for spec in specs or [TenantSpec(1, "chat", qos="interactive", share=4.0),
                          TenantSpec(2, "crawl", qos="batch", share=1.0)]:
        orc.register(spec)
    return orc


# ---------------------------------------------------------------------------
# traffic generator
# ---------------------------------------------------------------------------

def test_traffic_deterministic_and_mix_independent():
    mixes = [
        [TenantTraffic(1, rate=2.0, prompt_max=32, output_max=16)],
        [TenantTraffic(1, rate=2.0, prompt_max=32, output_max=16),
         TenantTraffic(2, rate=5.0)],
    ]
    seen = []
    for mix in mixes:
        gen = TrafficGenerator(mix, seed=11)
        seen.append([
            (r.req_id is not None, r.tenant_id, r.prompt, r.output_len)
            for s in range(6) for r in gen.arrivals(s) if r.tenant_id == 1])
    # tenant 1's stream is a pure function of (seed, tenant, step): adding
    # tenant 2 to the mix must not perturb it (the solo/flood runs of the
    # serve bench depend on this).
    assert seen[0] == seen[1]
    # and re-running the same mix reproduces byte-identical requests
    gen = TrafficGenerator(mixes[0], seed=11)
    again = [(True, r.tenant_id, r.prompt, r.output_len)
             for s in range(6) for r in gen.arrivals(s)]
    assert again == seen[0]


def test_traffic_bounds_and_windows():
    gen = TrafficGenerator([
        TenantTraffic(3, rate=4.0, prompt_mean=8, output_mean=4, tail=1.3,
                      prompt_max=24, output_max=12, start_step=2,
                      stop_step=5, vocab=100)], seed=5)
    reqs = [r for s in range(8) for r in gen.arrivals(s)]
    assert reqs, "expected arrivals from a rate-4 window"
    assert all(2 <= r.arrive_step < 5 for r in reqs)
    for r in reqs:
        assert 1 <= r.prompt_len <= 24
        assert 1 <= r.output_len <= 12
        assert all(1 <= t < 100 for t in r.prompt)
    ids = [r.req_id for r in reqs]
    assert ids == sorted(set(ids)), "request ids mint monotonically"
    assert gen.total_generated() == len(reqs)
    # num_pages: ceil(total / page_tokens)
    r = reqs[0]
    assert r.num_pages(8) == -(-(r.prompt_len + r.output_len) // 8)


def test_traffic_validation():
    with pytest.raises(ValueError):
        TenantTraffic(1, rate=-1.0)
    with pytest.raises(ValueError):
        TenantTraffic(1, rate=1.0, tail=1.0)
    with pytest.raises(ValueError):
        TrafficGenerator([TenantTraffic(1, rate=1.0),
                          TenantTraffic(1, rate=2.0)])


# ---------------------------------------------------------------------------
# fidelity on the simulated engine (state leaks unless slots reset)
# ---------------------------------------------------------------------------

def test_continuous_matches_solo_sim_engine():
    orc = mk_orc()
    bat = ContinuousBatcher(orc, num_slots=8, page_tokens=8)
    eng = SimulatedDecodeEngine(8)
    traffic = TrafficGenerator([
        TenantTraffic(1, rate=1.0, prompt_mean=6, output_mean=5,
                      prompt_max=20, output_max=16),
        TenantTraffic(2, rate=1.5, prompt_mean=10, output_mean=8,
                      prompt_max=32, output_max=24)], seed=3)
    res = serve_loop(bat, eng, traffic, steps=30, step_us=10.0)
    assert res["completed"] == res["submitted"] > 20
    # slot reuse must have happened for the reset mechanism to be exercised
    assert res["completed"] > bat.num_slots
    for seq in bat.retired:
        assert seq.out == solo_reference(
            SimulatedDecodeEngine(8), seq.req, slot=seq.slot)


def test_sim_engine_leaks_without_reset():
    """The oracle is only meaningful if a forgotten reset would fail."""
    eng = SimulatedDecodeEngine(4)
    req = make_request(0, 1, prompt_len=3, output_len=4, seed=9, vocab=500)
    first = solo_reference(eng, req, slot=2)      # leaves acc dirty
    # replay the same request on the same engine WITHOUT reset
    tokens = np.zeros((4,), np.int32)
    out, fed = [], 0
    while len(out) < req.output_len:
        tokens[2] = (req.prompt[fed] if fed < req.prompt_len
                     else out[fed - req.prompt_len])
        emitted = eng.step(tokens, [])            # no reset: stale acc
        if fed >= req.prompt_len - 1:
            out.append(int(emitted[2]))
        fed += 1
    assert out != first


def test_continuous_matches_solo_real_model():
    """Continuous batching is a pure scheduling change on the jitted model."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro import configs
    from repro.config import RunConfig, ShapeConfig
    from repro.models import transformer
    from repro.serve.batcher import ModelDecodeEngine

    batch, max_len, pt = 4, 24, 8
    cfg = dataclasses.replace(configs.get_reduced("granite-3-8b"),
                              dtype="float32")
    shape = ShapeConfig("serve_test", max_len, batch, "decode")
    params = transformer.init_params(cfg, jax.random.key(0))
    run = RunConfig(model=cfg, shape=shape, kv_placement="local")
    reqs = [make_request(i, 1 + i % 2, prompt_len=2 + i, output_len=3 + i,
                         seed=7, vocab=cfg.vocab_size) for i in range(5)]

    orc = mk_orc()
    bat = ContinuousBatcher(orc, num_slots=batch, page_tokens=pt)
    eng = ModelDecodeEngine(run, params, batch=batch, max_len=max_len,
                            page_tokens=pt, dtype=jnp.float32)
    for r in reqs:
        bat.submit(r)
    guard = 0
    while bat.in_flight() and guard < 200:
        bat.control()
        if bat.active_count():
            tokens, resets = bat.step_inputs()
            bat.observe(eng.step(tokens, resets))
        guard += 1
    assert sum(bat.completed.values()) == len(reqs)
    assert any(s.req.req_id >= batch for s in bat.retired), \
        "expected slot reuse (the reset mechanism under test)"
    # one engine serves every solo reference: the slot reset makes the
    # previous occupant's KV invisible, which is itself the contract
    ref_eng = ModelDecodeEngine(run, params, batch=batch, max_len=max_len,
                                page_tokens=pt, dtype=jnp.float32)
    for seq in bat.retired:
        assert seq.out == solo_reference(ref_eng, seq.req, slot=seq.slot), \
            f"req {seq.req.req_id} diverged from its solo decode"


# ---------------------------------------------------------------------------
# batcher invariants, property-tested over random mixes x depths
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_batcher_invariants_random_mixes(seed):
    rng = np.random.default_rng(seed)
    num_tenants = int(rng.integers(1, 5))
    qos_pool = ["interactive", "batch", "best_effort"]
    specs = [TenantSpec(t + 1, f"t{t + 1}",
                        qos=qos_pool[int(rng.integers(0, 3))],
                        share=float(rng.uniform(0.5, 4.0)))
             for t in range(num_tenants)]
    num_slots = int(rng.integers(2, 17))
    policy = ["qos", "naive"][int(rng.integers(0, 2))]
    orc = mk_orc(specs=specs, max_tenants=8)
    bat = ContinuousBatcher(orc, num_slots=num_slots, page_tokens=8,
                            policy=policy)
    eng = SimulatedDecodeEngine(num_slots)
    mix = [TenantTraffic(s.tenant_id, rate=float(rng.uniform(0.2, 3.0)),
                         prompt_mean=int(rng.integers(2, 12)),
                         output_mean=int(rng.integers(2, 10)),
                         prompt_max=24, output_max=16, vocab=1000)
           for s in specs]
    traffic = TrafficGenerator(mix, seed=seed)
    steps = int(rng.integers(5, 25))
    submitted_reqs = []
    admitted_ids = set()
    for step in range(steps):
        for req in traffic.arrivals(step):
            submitted_reqs.append(req)
            bat.submit(req)
        for seq in bat.control():
            assert seq.req.req_id not in admitted_ids, \
                "sequence admitted twice"
            admitted_ids.add(seq.req.req_id)
        # invariant: no slot double-assigned, slot map consistent
        live = [s for s in bat.slots if s is not None]
        assert len({s.slot for s in live}) == len(live)
        assert set(range(num_slots)) == \
            {s.slot for s in live} | set(bat.free)
        # conservation: submitted == completed + shed + queued + active
        acc = bat.accounting()
        for t in acc["submitted"]:
            assert acc["submitted"][t] == (
                acc["completed"].get(t, 0) + acc["shed"].get(t, 0)
                + acc["queued"].get(t, 0) + acc["active"].get(t, 0))
        assert bat.peak_in_flight >= bat.in_flight()
        if bat.active_count():
            tokens, resets = bat.step_inputs()
            bat.observe(eng.step(tokens, resets))
    # drain: every admitted sequence retires, every lease releases
    guard = 0
    while bat.in_flight() and guard < 3000:
        bat.control()
        if bat.active_count():
            tokens, resets = bat.step_inputs()
            bat.observe(eng.step(tokens, resets))
        guard += 1
    assert bat.in_flight() == 0, f"did not drain: {bat.describe()}"
    assert {s.req.req_id for s in bat.retired} >= admitted_ids
    assert len(orc.leases) == 0, "retirement must release every lease"
    assert len(bat.free) == num_slots
    acc = bat.accounting()
    assert sum(acc["submitted"].values()) == len(submitted_reqs)
    for t in acc["submitted"]:
        assert acc["submitted"][t] == (acc["completed"].get(t, 0)
                                       + acc["shed"].get(t, 0))
    # every retired sequence decoded exactly its requested output length
    for seq in bat.retired:
        assert len(seq.out) == seq.req.output_len


# ---------------------------------------------------------------------------
# admission edges
# ---------------------------------------------------------------------------

def test_oversized_request_sheds_not_livelocks():
    # pool: 4 nodes x 4 pages = 16 slots; a 40-page request can never fit
    orc = mk_orc(num_nodes=4, pages_per_node=4, num_logical=64)
    bat = ContinuousBatcher(orc, num_slots=4, page_tokens=8)
    whale = make_request(0, 2, prompt_len=300, output_len=20, vocab=100)
    assert whale.num_pages(8) == 40
    assert bat.submit(whale) == "shed"
    assert bat.queue_depth() == 0
    assert bat.shed[2]["terminal"] == 1
    # a feasible request still serves normally afterwards
    ok = make_request(1, 1, prompt_len=4, output_len=3, vocab=100)
    assert bat.submit(ok) == "queued"
    eng = SimulatedDecodeEngine(4)
    guard = 0
    while bat.in_flight() and guard < 100:
        bat.control()
        if bat.active_count():
            tokens, resets = bat.step_inputs()
            bat.observe(eng.step(tokens, resets))
        guard += 1
    assert bat.completed.get(1) == 1


def test_quota_bound_tenant_sheds_at_submit():
    specs = [TenantSpec(1, "small", qos="interactive", page_quota=2)]
    orc = mk_orc(specs=specs)
    bat = ContinuousBatcher(orc, num_slots=4, page_tokens=8)
    big = make_request(0, 1, prompt_len=30, output_len=10, vocab=100)
    assert big.num_pages(8) == 5 > 2
    assert bat.submit(big) == "shed"
    assert bat.shed[1]["terminal"] == 1


def test_attempt_bounded_shedding():
    # one tenant whose single seated lease pins the whole pool forever
    specs = [TenantSpec(1, "hog", qos="batch"),
             TenantSpec(2, "late", qos="interactive")]
    orc = mk_orc(num_nodes=2, pages_per_node=2, num_logical=4, specs=specs)
    dec, hog = orc.request_lease(1, 4, term=0, auto_renew=True)
    assert dec.admitted
    bat = ContinuousBatcher(orc, num_slots=2, page_tokens=8,
                            max_admit_attempts=3)
    late = make_request(0, 2, prompt_len=4, output_len=3, vocab=100)
    assert bat.submit(late) == "queued"   # 2 pages fit the pool in principle
    for _ in range(8):
        bat.control()
    assert bat.queue_depth() == 0, "attempt bound must evict the request"
    assert bat.shed[2]["attempts"] == 1


def test_qos_isolates_interactive_from_flood():
    """QoS slot windows bound interactive latency; naive FIFO does not."""
    def run(policy):
        orc = mk_orc(num_nodes=8, pages_per_node=256, num_logical=2048)
        registry = MetricsRegistry()
        bat = ContinuousBatcher(orc, num_slots=8, page_tokens=16,
                                policy=policy, registry=registry)
        mix = [TenantTraffic(1, rate=0.5, prompt_mean=4, output_mean=4,
                             prompt_max=12, output_max=10, stop_step=20,
                             vocab=1000),
               TenantTraffic(2, rate=15.0, prompt_mean=10, output_mean=8,
                             prompt_max=32, output_max=24, start_step=2,
                             stop_step=8, vocab=1000)]
        serve_loop(bat, SimulatedDecodeEngine(8),
                   TrafficGenerator(mix, seed=4), steps=20, step_us=100.0)
        return registry.family_quantiles(
            "serve_request_latency_us")["interactive"]["p99"]

    qos_p99, naive_p99 = run("qos"), run("naive")
    assert qos_p99 < naive_p99, (
        f"QoS admission (p99 {qos_p99}us) must beat naive FIFO "
        f"({naive_p99}us) under a batch flood")


# ---------------------------------------------------------------------------
# obs + orchestrator integration
# ---------------------------------------------------------------------------

def test_latency_histograms_and_request_spans():
    orc = mk_orc()
    clock = ManualClock(tick_us=0.0)
    recorder = TraceRecorder(clock=clock)
    registry = MetricsRegistry()
    bat = ContinuousBatcher(orc, num_slots=4, page_tokens=8,
                            registry=registry, clock=clock,
                            recorder=recorder)
    traffic = TrafficGenerator([
        TenantTraffic(1, rate=0.8, prompt_mean=4, output_mean=3,
                      prompt_max=12, output_max=8, vocab=500),
        TenantTraffic(2, rate=0.8, prompt_mean=4, output_mean=3,
                      prompt_max=12, output_max=8, vocab=500)], seed=2)
    res = serve_loop(bat, SimulatedDecodeEngine(4), traffic, steps=15,
                     step_us=50.0)
    lat = registry.family_quantiles("serve_request_latency_us")
    assert set(lat) == {"interactive", "batch"}
    for qos, q in lat.items():
        assert q["count"] > 0
        assert 0 < q["p50"] <= q["p99"]
    assert res["latency_us"].keys() == lat.keys()
    # one CAT_REQUEST span per retirement, wall-clock consistent
    spans = recorder.find_all(cat=CAT_REQUEST)
    assert len(spans) == res["completed"]
    for s in spans:
        # a 1-prompt/1-output request can legally retire in its arrival
        # step (zero modeled latency); anything longer takes clock time
        assert s.duration_us >= 0
        assert s.args["qos"] in ("interactive", "batch")
        assert s.args["output_len"] > 0
    # goodput denominated in the modeled clock
    assert res["goodput_tokens_per_s"] > 0
    # ttft <= full latency, per class
    ttft = registry.family_quantiles("serve_ttft_us")
    for qos in lat:
        assert ttft[qos]["p50"] <= lat[qos]["p50"] + 1e-9


def test_refit_windows_from_queue_depths():
    orc = mk_orc()
    # datapath telemetry would say "idle"; queue depths say tenant 2 is
    # flooded — the serving-layer refit must open tenant 2's window.
    sched = orc.refit_windows({1: 1.0, 2: float(orc.budget * 3)})
    assert sched.windows[2] > sched.windows[1] >= 1
    assert sum(sched.windows.values()) <= orc.budget
    # interactive still composes first regardless of window size
    assert sched.order[0] == 1


def test_lease_renewal_rides_control_period():
    """In-flight sequences outlive their lease term via auto-renew."""
    orc = mk_orc()
    bat = ContinuousBatcher(orc, num_slots=2, page_tokens=8, lease_term=2)
    req = make_request(0, 1, prompt_len=6, output_len=12, vocab=100)
    bat.submit(req)
    eng = SimulatedDecodeEngine(2)
    renewals = 0
    guard = 0
    while bat.in_flight() and guard < 100:
        bat.control()
        renewals += len(orc.leases) and any(
            l.auto_renew for l in orc.leases.values())
        if bat.active_count():
            tokens, resets = bat.step_inputs()
            bat.observe(eng.step(tokens, resets))
        guard += 1
    # residency (6 + 12 - 1 = 17 steps) >> term 2: renewal must have fired
    assert bat.completed.get(1) == 1
    assert len(orc.leases) == 0
    assert req.prompt_len + req.output_len - 1 > 2 * orc.default_term \
        or renewals > 0
