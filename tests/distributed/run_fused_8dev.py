"""Fused Pallas datapath parity on 8 virtual CPU devices.

The fused serve/gather/commit engines (one kernel pair + one collective
pair per round, see repro.kernels.bridge_gather) must serve exactly what
the numpy oracles say on a real N-device mesh, across the six steering
program variants x channel depths {1, 2, 4} x multi-tenant lanes — the
N-device face of the loopback-path contract in tests/test_fused_bridge.py
(which additionally fuzzes fused-vs-unfused over random ragged fabrics).

Program variants are runtime inputs, so the whole variant sweep reuses one
trace per (channels, engine) shape — the compile budget stays inside the
tier-1 subprocess timeout; fused-vs-unfused cross-checks are spot checks
here for the same reason.

Run as a subprocess by tests/test_distributed.py (auto-collected).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bridge, ref, steering  # noqa: E402
from repro.core.memport import MemPortTable  # noqa: E402
from repro.core.topology import Topology  # noqa: E402

TELEM_FIELDS = ("slot_served", "loopback_served", "spilled", "pruned",
                "traffic", "epoch_cw", "epoch_ccw", "slot_intra",
                "tier_hops", "tenant_served", "tenant_spilled",
                "tenant_pruned")


def check_equal(name, got, exp):
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp),
                                  err_msg=name)
    print(f"ok: {name}")


def check_telem(name, got, exp):
    for field in TELEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, field)), np.asarray(getattr(exp, field)),
            err_msg=f"{name}: {field}")
    print(f"ok: {name} telemetry")


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((8,), ("data",))
    n, ppn, page = 8, 8, 4
    rng = np.random.default_rng(7)
    pool = jnp.asarray(rng.normal(size=(n * ppn, page)).astype(np.float32))
    table = MemPortTable.striped(48, n, ppn)
    want = jnp.asarray(rng.integers(-1, 48, size=(n, 6)), jnp.int32)
    dest = jnp.asarray(rng.permutation(48).reshape(n, 6), jnp.int32)
    payload = jnp.asarray(rng.normal(size=(n, 6, page)), jnp.float32)
    tenants = jnp.asarray(rng.integers(0, 3, size=(n, 6)), jnp.int32)
    topo = Topology.boards(2, 4)

    # The six program variants of the steering suite (None = default full
    # bidirectional coverage).
    variants = {
        "uni": steering.unidirectional_program(n),
        "bi": steering.bidirectional_program(n),
        "pruned": steering.pruned_program(
            steering.bidirectional_program(n), [1, 2, 7]),
        "lb": steering.load_balanced_program(
            n, [1.0 + (d % 3) for d in range(1, n)]),
        "hier": steering.hierarchical_program(topo),
        "masked": steering.masked_ranks_program(
            steering.bidirectional_program(n),
            np.tile(np.array([1, 1, 0, 1, 1, 1, 0, 1], bool), (n - 1, 1))),
        "default": None,
    }

    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        # fused vs the numpy page oracles: six variants x channels {1,2,4}
        # (one trace per channels — programs swap as runtime inputs)
        for name, prog in variants.items():
            for ch in (1, 2, 4):
                got = bridge.pull_pages(pool, want, table, mesh=mesh,
                                        budget=3, channels=ch, program=prog,
                                        fused=True)
                exp = ref.pull_pages_ref(pool, want, table,
                                         pages_per_node=ppn, program=prog)
                check_equal(f"pull {name} ch={ch} fused vs oracle", got, exp)
                got = bridge.push_pages(pool, dest, payload, table,
                                        mesh=mesh, budget=3, channels=ch,
                                        program=prog, fused=True)
                exp = ref.push_pages_ref(pool, dest, payload, table,
                                         pages_per_node=ppn, program=prog)
                check_equal(f"push {name} ch={ch} fused vs oracle", got, exp)

        # fused telemetry vs the counter oracle, throttled + 3 tenant lanes
        # (again one trace across all variants)
        for name, prog in variants.items():
            tp = topo if name == "hier" else None
            _, telem = bridge.pull_pages(
                pool, want, table, mesh=mesh, budget=3, channels=2,
                program=prog, topology=tp, fused=True,
                collect_telemetry=True, tenant_ids=tenants, max_tenants=4,
                active_budget=jnp.int32(2))
            exp = ref.expected_transfer_telemetry(
                want, table, prog, num_nodes=n, budget=3, active_budget=2,
                topology=tp, tenant_ids=tenants, max_tenants=4)
            check_telem(f"pull {name} fused vs counter oracle", telem, exp)

        # fused vs unfused spot check: pages + telemetry bit-exact under
        # throttle + tenants at the deepest channel count (the loopback
        # property suite fuzzes this across random fabrics; this pins the
        # real-collective engines against each other once per datapath)
        kw = dict(mesh=mesh, budget=3, channels=4, collect_telemetry=True,
                  tenant_ids=tenants, max_tenants=4,
                  active_budget=jnp.int32(2))
        of, tf = bridge.pull_pages(pool, want, table, fused=True, **kw)
        ou, tu = bridge.pull_pages(pool, want, table, fused=False, **kw)
        check_equal("pull ch=4 fused==unfused", of, ou)
        check_telem("pull ch=4 fused==unfused", tf, tu)
        pf, ptf = bridge.push_pages(pool, dest, payload, table, fused=True,
                                    **kw)
        pu, ptu = bridge.push_pages(pool, dest, payload, table, fused=False,
                                    **kw)
        check_equal("push ch=4 fused==unfused", pf, pu)
        check_telem("push ch=4 fused==unfused", ptf, ptu)

        # edge_buffer=False has no fused engine: the knob must fall back
        # to the serial chain, not crash or diverge.
        o1 = bridge.pull_pages(pool, want, table, mesh=mesh, budget=3,
                               edge_buffer=False, fused=True)
        o2 = bridge.pull_pages(pool, want, table, mesh=mesh, budget=3,
                               edge_buffer=False, fused=False)
        check_equal("bufferless fallback", o1, o2)

    print("ALL OK")


if __name__ == "__main__":
    main()
