"""Compressed-DP training validation on 8 virtual devices.

Checks: (1) the int8 ring all-reduce matches jnp mean-reduce within
quantization error; (2) a compressed train step tracks the uncompressed one
(error feedback bounds the drift); (3) the HLO contains s8 collective
traffic (the compression is real, not decorative).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import bridge  # noqa: E402
from repro.config import OptimConfig, RunConfig, ShapeConfig  # noqa: E402
from repro.data.pipeline import SyntheticLM  # noqa: E402
from repro.optim import compress as C  # noqa: E402
from repro.train import step as train_step_mod  # noqa: E402


def test_ring_allreduce(mesh):
    n = 4
    rng = np.random.default_rng(0)
    x = rng.normal(size=(n, 1001)).astype(np.float32)

    def body(xl):
        return C.compressed_ring_allreduce(xl[0], "data", n)[None]

    f = bridge.shard_map(body, mesh, in_specs=P("data", None),
                         out_specs=P("data", None), mem_axis="data")
    got = np.asarray(f(jnp.asarray(x)))
    want = x.mean(axis=0)
    for i in range(n):
        np.testing.assert_allclose(got[i], want, atol=2e-2)
    # all replicas agree bitwise
    for i in range(1, n):
        np.testing.assert_array_equal(got[i], got[0])
    print("ok: int8 ring all-reduce")


def test_compressed_training(mesh):
    cfg = dataclasses.replace(configs.get_reduced("granite-3-8b"),
                              dtype="float32")
    shape = ShapeConfig("t", 32, 8, "train")
    base = RunConfig(model=cfg, shape=shape,
                     optim=OptimConfig(lr=1e-3, warmup_steps=2,
                                       total_steps=10))
    comp = dataclasses.replace(
        base, optim=dataclasses.replace(base.optim, compress_grads=True))

    data = SyntheticLM(cfg, 8, 32)
    with bridge.use_mesh(mesh):
        state_p = train_step_mod.make_train_state(base, jax.random.key(0))
        state_c = train_step_mod.make_train_state(comp, jax.random.key(0),
                                                  compress=True, dp_size=4)
        from repro.parallel.sharding import make_rules
        rules = make_rules(base.sharding, mesh, global_batch=8)
        step_p = jax.jit(train_step_mod.build_train_step(base, mesh, rules))
        step_c = jax.jit(train_step_mod.build_train_step(comp, mesh, rules))

        lowered = jax.jit(
            train_step_mod.build_train_step(comp, mesh, rules)).lower(
            state_c, {k: jnp.asarray(v) for k, v in data.batch_at(0).items()})
        hlo = lowered.compile().as_text()
        assert "s8[" in hlo and "collective-permute" in hlo, \
            "int8 wire traffic missing from compressed step"
        print("ok: s8 collective-permute traffic present in HLO")

        losses_p, losses_c = [], []
        for i in range(8):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            state_p, mp = step_p(state_p, batch)
            state_c, mc = step_c(state_c, batch)
            losses_p.append(float(mp["loss"]))
            losses_c.append(float(mc["loss"]))
    print("plain:", [round(x, 4) for x in losses_p])
    print("compressed:", [round(x, 4) for x in losses_c])
    assert losses_c[-1] < losses_c[0], "compressed training diverged"
    assert abs(losses_c[-1] - losses_p[-1]) < 0.15, \
        "compressed training drifted too far from fp32 baseline"
    print("ok: compressed step tracks fp32 baseline")


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    test_ring_allreduce(mesh)
    test_compressed_training(mesh)
    print("ALL OK")


if __name__ == "__main__":
    main()
