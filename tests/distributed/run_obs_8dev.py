"""Observability-plane validation on 8 virtual CPU devices.

Run as a subprocess by tests/test_distributed.py (auto-collected).  Proves
the tracing/metrics/calibration plane against the *real* 8-way mem ring:

* span <-> counter reconciliation is bit-exact: a fenced span annotated
  from the real datapath's in-band telemetry carries identical counts to
  one annotated from the ref oracle, for every program variant — uni /
  bi / pruned / load-balanced / hierarchical / group-masked — and the
  metrics registry's counter families agree with both,
* with a ManualClock, tracing the real datapath twice produces
  byte-identical Chrome-trace JSON (determinism survives actual jax
  dispatch, not just synthetic spans),
* phase attribution sees the real compiled programs: the unfused
  engine's ``obs:wire_req`` op count scales with pipeline depth while
  the fused engine's stays flat (the measured cause of the depth>1
  wall-clock regression),
* the calibrator closes the loop on real measurements: RLS-fitted
  constants predict the measured pull latencies with lower error than
  the static datasheet prior, and the fitted chunk overhead steers
  ``select_channels``.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import functools  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bridge, perfmodel, ref, steering  # noqa: E402
from repro.core.control_plane import ControlPlane  # noqa: E402
from repro.core.memport import MemPortTable  # noqa: E402
from repro.core.topology import Topology  # noqa: E402
from repro.obs import (ManualClock, MetricsRegistry,  # noqa: E402
                       TraceRecorder, phase_op_counts)
from repro.telemetry import TelemetryAggregator  # noqa: E402

N, PPN, PAGE = 8, 8, 16
TENANT_NAMES = {0: "t0", 1: "t1", 2: "t2", 3: "t3"}


def variants(topo):
    hier = steering.hierarchical_program(topo)
    mask = np.asarray(hier.rank_epoch) >= 0
    r8 = np.arange(N)
    mask[0, :] = topo.pair_intra(r8, (r8 + 1) % N)
    bi = steering.bidirectional_program(N)
    return [
        ("uni", steering.unidirectional_program(N)),
        ("bi", bi),
        ("pruned", steering.pruned_program(bi, [1, 2, 6])),
        ("load_balanced", steering.load_balanced_program(
            N, np.asarray([6, 3, 2, 0, 0, 1, 4], float))),
        ("hierarchical", hier),
        ("masked", steering.masked_ranks_program(hier, mask)),
    ]


def span_reconciliation_checks():
    """Real-telemetry span args == oracle-telemetry span args, bit-exact,
    and the registry's counter families agree with both."""
    mesh8 = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(41)
    pool = jnp.asarray(rng.normal(size=(N * PPN, PAGE)).astype(np.float32))
    table = MemPortTable.striped(48, N, PPN)
    want = jnp.asarray(rng.integers(-1, 48, size=(N, 7)).astype(np.int32))
    lane = jnp.asarray(rng.integers(0, 4, size=(N, 7)).astype(np.int32))
    ab = jnp.asarray(rng.integers(1, 4, size=(N,)).astype(np.int32))
    topo = Topology.boards(2, 4)
    page_bytes = PAGE * 4

    rec = TraceRecorder(ManualClock(), process_name="obs-8dev")
    with bridge.use_mesh(mesh8):
        pull = jax.jit(functools.partial(
            bridge.pull_pages, mesh=mesh8, budget=3, topology=topo,
            collect_telemetry=True))
        for name, prog in variants(topo):
            with rec.span(f"transfer:{name}", variant=name,
                          budget=3) as sp:
                out, telem = pull(pool, want, table, program=prog,
                                  active_budget=ab, tenant_ids=lane)
                rec.fence((out, telem))
            rec.annotate_telemetry(sp, telem, page_bytes=page_bytes,
                                   tenant_names=TENANT_NAMES)

            exp = ref.expected_transfer_telemetry(
                np.asarray(want), table, prog, num_nodes=N, budget=3,
                topology=topo, active_budget=np.asarray(ab),
                tenant_ids=np.asarray(lane))
            with rec.span(f"oracle:{name}", variant=name) as sp_exp:
                pass
            rec.annotate_telemetry(sp_exp, exp, page_bytes=page_bytes,
                                   tenant_names=TENANT_NAMES)
            counters = {k: v for k, v in sp.args.items()
                        if k not in ("variant", "budget")}
            counters_exp = {k: v for k, v in sp_exp.args.items()
                            if k != "variant"}
            assert counters == counters_exp, (
                f"{name}: span counters diverge from oracle\n"
                f"real:   {counters}\noracle: {counters_exp}")
            assert counters["pages_served"] > 0, f"{name}: nothing served"

            reg = MetricsRegistry()
            reg.observe_telemetry(telem, page_bytes=page_bytes)
            snap = reg.snapshot()["counters"]
            assert snap["bridge_pages_served_total"] == \
                counters["pages_served"], name
            assert snap['bridge_wire_pages_total{direction="cw"}'] == \
                counters["wire_pages_cw"], name
            assert snap['bridge_wire_pages_total{direction="ccw"}'] == \
                counters["wire_pages_ccw"], name
            assert snap["bridge_wire_bytes_total"] == \
                counters["wire_bytes"], name
            tenant_total = sum(
                v for k, v in snap.items()
                if k.startswith("bridge_tenant_pages_total"))
            assert tenant_total == sum(counters["tenant_pages"].values())
            print(f"ok: span/registry/oracle reconcile bit-exact [{name}]")
    return rec


def deterministic_trace_checks():
    """Two traced runs of the real datapath serialize byte-identically."""
    mesh8 = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(17)
    pool = jnp.asarray(rng.normal(size=(N * PPN, PAGE)).astype(np.float32))
    table = MemPortTable.striped(48, N, PPN)
    want = jnp.asarray(rng.integers(-1, 48, size=(N, 6)).astype(np.int32))

    def traced_run() -> str:
        rec = TraceRecorder(ManualClock(start_us=10.0, tick_us=3.0),
                            process_name="obs-deterministic")
        with bridge.use_mesh(mesh8):
            pull = jax.jit(functools.partial(
                bridge.pull_pages, mesh=mesh8, budget=3,
                collect_telemetry=True))
            with rec.span("transfer:deterministic", pages=6) as sp:
                out, telem = pull(pool, want, table)
                rec.fence((out, telem))
            rec.annotate_telemetry(sp, telem, page_bytes=PAGE * 4)
        return rec.to_json(indent=1)

    a, b = traced_run(), traced_run()
    assert a == b, "ManualClock trace not byte-identical across runs"
    assert '"ts": 10.0' in a
    print("ok: ManualClock trace byte-identical across two real-ring runs")


def phase_attribution_checks():
    """Compiled-HLO phase op counts: unfused scales with depth, fused
    does not — the structural cause of the pipeline wall-clock regression."""
    mesh8 = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(23)
    pool = jnp.asarray(rng.normal(size=(N * PPN, PAGE)).astype(np.float32))
    table = MemPortTable.striped(N * PPN, N, PPN)
    want = jnp.asarray(
        rng.integers(0, N * PPN, size=(N, 16)).astype(np.int32))
    counts = {}
    with bridge.use_mesh(mesh8):
        for fused in (False, True):
            for c in (1, 4):
                text = jax.jit(
                    lambda p, w, t, _c=c, _f=fused: bridge.pull_pages(
                        p, w, t, mesh=mesh8, budget=8, channels=_c,
                        fused=_f)).lower(pool, want, table) \
                    .compile().as_text()
                counts[(fused, c)] = phase_op_counts(text)
    for key, ops in counts.items():
        assert {"wire_req", "gather", "wire_data", "commit"} <= ops.keys(), (
            key, ops)
    assert counts[(False, 4)]["wire_req"] > counts[(False, 1)]["wire_req"], \
        "unfused steering collectives should scale with channels"
    assert counts[(True, 4)]["wire_req"] == counts[(True, 1)]["wire_req"], \
        "fused engine should issue one request all_gather at any depth"
    print(f"ok: phase op counts attribute the depth regression "
          f"(unfused wire_req {counts[(False, 1)]['wire_req']} -> "
          f"{counts[(False, 4)]['wire_req']}, fused flat at "
          f"{counts[(True, 1)]['wire_req']})")


def calibration_loop_checks():
    """Fit the perfmodel on real measured pulls; fitted must beat static,
    and the fitted chunk overhead must steer select_channels."""
    mesh8 = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(29)
    pool = jnp.asarray(rng.normal(size=(N * PPN, 64)).astype(np.float32))
    table = MemPortTable.striped(N * PPN, N, PPN)
    bi = steering.bidirectional_program(N)
    page_bytes = 64 * 4
    samples = []
    with bridge.use_mesh(mesh8):
        for c in (1, 2, 4):
            for cols in (8, 16):
                want = jnp.asarray(rng.integers(
                    0, N * PPN, size=(N, cols)).astype(np.int32))
                pull = jax.jit(
                    lambda p, w, t, _c=c: bridge.pull_pages(
                        p, w, t, mesh=mesh8, budget=8, channels=_c,
                        fused=False))
                jax.block_until_ready(pull(pool, want, table))
                reps = 3
                t0 = time.perf_counter()
                for _ in range(reps):
                    r = pull(pool, want, table)
                jax.block_until_ready(r)
                us = (time.perf_counter() - t0) / reps * 1e6
                rounds = steering.num_rounds(cols, 8)
                feats = perfmodel.route_features(
                    bi, page_bytes, 8, rounds=rounds, channels=c)
                samples.append((feats, us))

    cal = perfmodel.Calibrator()
    for _ in range(4):
        for feats, us in samples:
            cal.observe(feats, us)
    assert cal.fitted
    static_err = float(np.mean(
        [abs(cal.static_predict_us(f) - m) / m for f, m in samples]))
    fitted_err = float(np.mean(
        [abs(cal.predict_us(f) - m) / m for f, m in samples]))
    assert fitted_err < static_err, (
        f"fitted {fitted_err:.3f} not below static {static_err:.3f}")
    # dispatch dominates this backend: the fitted chunk overhead must be
    # real money, and the calibrated depth pick must not exceed static's
    assert cal.chunk_overhead_us > 0
    cp = ControlPlane(num_nodes=N, pages_per_node=PPN,
                      num_logical=N * PPN)
    agg = TelemetryAggregator(N, page_bytes=4096)
    agg.update(ref.expected_transfer_telemetry(
        np.asarray(rng.integers(0, N * PPN, size=(N, 8)), np.int32),
        table, bi, num_nodes=N, budget=8))
    pick_static = cp.select_channels(8, 4096, telemetry=agg)
    pick_cal = cp.select_channels(8, 4096, telemetry=agg, calibrator=cal)
    assert pick_cal <= pick_static
    print(f"ok: calibrator on real ring: err {static_err:.3f} -> "
          f"{fitted_err:.3f} ({cal.samples} obs), chunk "
          f"{cal.chunk_overhead_us:.0f}us, pick {pick_static} -> "
          f"{pick_cal}")


def main():
    assert jax.device_count() >= 8, "need 8 virtual devices"
    span_reconciliation_checks()
    deterministic_trace_checks()
    phase_attribution_checks()
    calibration_loop_checks()
    print("ALL OK")


if __name__ == "__main__":
    main()
