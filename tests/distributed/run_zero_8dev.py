"""Disaggregated-optimizer-state (zero_bridge) validation on 8 devices."""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import zero_bridge  # noqa: E402
from repro.core.control_plane import ControlPlane  # noqa: E402


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    tree = {
        "w1": jnp.asarray(rng.normal(size=(40, 30)).astype(np.float32)),
        "b1": jnp.asarray(rng.normal(size=(30,)).astype(np.float32)),
        "nested": {"w2": jnp.asarray(
            rng.normal(size=(30, 17)).astype(np.float32))},
    }
    n = 4
    packer = zero_bridge.TreePacker.plan(tree, page_elems=64)
    per_node = -(-packer.num_pages // n)
    cp = ControlPlane(n, per_node + 4, packer.num_pages)

    store = zero_bridge.create_store(tree, mesh=mesh, mem_axis="data",
                                     page_elems=64, budget=4, cp=cp)
    got = zero_bridge.pull_tree(store, mesh=mesh)
    for k in ("w1", "b1"):
        np.testing.assert_allclose(got[k], tree[k], atol=1e-6, err_msg=k)
    np.testing.assert_allclose(got["nested"]["w2"], tree["nested"]["w2"],
                               atol=1e-6)
    print("ok: store/pull roundtrip")

    # update-in-pool cycle: pull, mutate, push, re-pull
    tree2 = jax.tree.map(lambda x: x * 2 + 1, got)
    store = zero_bridge.push_tree(store, tree2, mesh=mesh)
    got2 = zero_bridge.pull_tree(store, mesh=mesh)
    np.testing.assert_allclose(got2["w1"], tree["w1"] * 2 + 1, atol=1e-6)
    print("ok: update cycle")

    # elastic remap after node failure, restore from checkpoint image
    store = zero_bridge.rehome_after_failure(store, cp, failed_node=1,
                                             restore_tree=tree2, mesh=mesh)
    got3 = zero_bridge.pull_tree(store, mesh=mesh)
    np.testing.assert_allclose(got3["nested"]["w2"],
                               tree["nested"]["w2"] * 2 + 1, atol=1e-6)
    assert not np.any(np.asarray(store.table.home) == 1)
    print("ok: elastic remap restore")

    # pipelined round engine: a channels=4 store round-trips bit-exactly
    # (push and pull both run the multi-channel datapath)
    import dataclasses
    store4 = dataclasses.replace(store, channels=4)
    store4 = zero_bridge.push_tree(store4, tree2, mesh=mesh)
    got4 = zero_bridge.pull_tree(store4, mesh=mesh)
    for a, b in zip(jax.tree.leaves(got4), jax.tree.leaves(got3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ok: channels=4 store roundtrip bit-exact")

    print("ALL OK")


if __name__ == "__main__":
    main()
