"""Multi-node bridge validation on 8 virtual CPU devices.

Run as a subprocess by tests/test_distributed.py (device count must be set
before jax initializes, so this cannot live inside the main pytest process).
Exits non-zero on any mismatch.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bridge, ref, kvbridge, steering  # noqa: E402
from repro.core.memport import FREE, MemPortTable  # noqa: E402
from repro.core.control_plane import ControlPlane  # noqa: E402
from repro.core.topology import Topology  # noqa: E402
from repro.telemetry import TelemetryAggregator  # noqa: E402

TELEM_FIELDS = ("slot_served", "loopback_served", "spilled", "pruned",
                "traffic", "epoch_cw", "epoch_ccw", "slot_intra",
                "tier_hops", "tenant_served", "tenant_spilled",
                "tenant_pruned")


def check(name, got, exp, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=atol,
                               err_msg=name)
    print(f"ok: {name}")


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n, ppn, page = 4, 8, 16
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.normal(size=(n * ppn, page)).astype(np.float32))

    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        # --- pull: striped placement, every node asks across the ring -------
        table = MemPortTable.striped(24, n, ppn)
        want = rng.integers(-1, 24, size=(n, 7)).astype(np.int32)
        got = bridge.pull_pages(pool, jnp.asarray(want), table, mesh=mesh,
                                budget=3)
        exp = ref.pull_pages_ref(pool, jnp.asarray(want), table,
                                 pages_per_node=ppn)
        check("pull striped", got, exp)

        # --- pull: adversarial placement (all pages on node 2) --------------
        cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=8)
        cp.allocate(8, policy="affinity", affinity=2)
        t2 = cp.table()
        want2 = rng.integers(0, 8, size=(n, 5)).astype(np.int32)
        got = bridge.pull_pages(pool, jnp.asarray(want2), t2, mesh=mesh,
                                budget=2)
        exp = ref.pull_pages_ref(pool, jnp.asarray(want2), t2,
                                 pages_per_node=ppn)
        check("pull affinity(2)", got, exp)

        # --- pull: bufferless bridge gives identical results -----------------
        got = bridge.pull_pages(pool, jnp.asarray(want), table, mesh=mesh,
                                budget=3, edge_buffer=False)
        exp = ref.pull_pages_ref(pool, jnp.asarray(want), table,
                                 pages_per_node=ppn)
        check("pull bufferless", got, exp)

        # --- pull: runtime rate limiting (throttled budget) ------------------
        want3 = np.arange(16).reshape(4, 4).astype(np.int32)
        got = bridge.pull_pages(pool, jnp.asarray(want3), table, mesh=mesh,
                                budget=4, overprovision=2,
                                active_budget=jnp.int32(2))
        exp = ref.pull_pages_ref(pool, jnp.asarray(want3), table,
                                 pages_per_node=ppn)
        check("pull throttled", got, exp)

        # --- push: single-writer scatter -------------------------------------
        dest = np.full((n, 4), FREE, np.int32)
        for node in range(n):  # node i writes pages 6i .. 6i+3 (single writer)
            dest[node] = np.arange(4) + 6 * node
        payload = rng.normal(size=(n, 4, page)).astype(np.float32)
        got = bridge.push_pages(pool, jnp.asarray(dest), jnp.asarray(payload),
                                table, mesh=mesh, budget=2)
        exp = ref.push_pages_ref(pool, jnp.asarray(dest), jnp.asarray(payload),
                                 table, pages_per_node=ppn)
        check("push", got, exp)

        # --- elastic remap: fail a node, re-pull through new table -----------
        cp2 = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=12)
        cp2.allocate(12, policy="striped")
        t3 = cp2.table()
        payload3 = rng.normal(size=(1, 12, page)).astype(np.float32)
        pool3 = jnp.zeros_like(pool)
        dest3 = np.full((n, 12), FREE, np.int32)
        dest3[0] = np.arange(12)
        pool3 = bridge.push_pages(pool3, jnp.asarray(dest3),
                                  jnp.asarray(np.broadcast_to(
                                      payload3, (n, 12, page))),
                                  t3, mesh=mesh, budget=4)
        plan = cp2.fail_node(1)
        t4 = cp2.table()
        # executor: copy migrated pages into their new homes (from the old
        # pool image, as a checkpoint restore would)
        flat_old = np.asarray(
            ref.flat_index(t3, jnp.arange(12, dtype=jnp.int32), ppn))
        pool_np = np.array(pool3)  # mutable copy
        for step in plan:
            pool_np[step.new_home * ppn + step.new_slot] = (
                pool_np[flat_old[step.page_id]])
        pool4 = jnp.asarray(pool_np)
        want4 = np.tile(np.arange(12, dtype=np.int32), (n, 1))
        got = bridge.pull_pages(pool4, jnp.asarray(want4), t4, mesh=mesh,
                                budget=4)
        exp = np.broadcast_to(payload3[0], (n, 12, page))
        check("pull after elastic remap", got, exp)

        # --- kvbridge: pull & push decode attention vs dense oracle ----------
        b, h, kv, hd, pt, mp = 4, 8, 4, 16, 4, 3
        cache = kvbridge.init_cache(1, b, pt * mp, pt, kv, hd, mesh=mesh,
                                    mem_axis="data", dtype=jnp.float32)
        layer = jax.tree.map(lambda x: x[0], cache.layers)
        lengths = jnp.asarray([5, 9, 0, 12], jnp.int32)
        s_max = pt * mp
        k_dense = rng.normal(size=(b, s_max, kv, hd)).astype(np.float32)
        v_dense = rng.normal(size=(b, s_max, kv, hd)).astype(np.float32)
        # fill pools + tails to mirror the dense cache
        kp = np.zeros(layer.k_pool.shape, np.float32)
        vp = np.zeros(layer.v_pool.shape, np.float32)
        tk = np.zeros((b, pt, kv, hd), np.float32)
        tv = np.zeros((b, pt, kv, hd), np.float32)
        home = np.asarray(cache.table.home)
        slot = np.asarray(cache.table.slot)
        ppn_kv = layer.k_pool.shape[0] // 4
        for bb in range(b):
            ln = int(lengths[bb])
            for p in range(mp):
                pid = bb * mp + p
                lo, hi = p * pt, min((p + 1) * pt, ln)
                if hi <= lo:
                    continue
                if hi - lo == pt:  # full page -> pool
                    row = home[pid] * ppn_kv + slot[pid]
                    kp[row, : hi - lo] = k_dense[bb, lo:hi]
                    vp[row, : hi - lo] = v_dense[bb, lo:hi]
                else:  # tail
                    tk[bb, : hi - lo] = k_dense[bb, lo:hi]
                    tv[bb, : hi - lo] = v_dense[bb, lo:hi]
        layer = kvbridge.PagedKVLayer(
            k_pool=jnp.asarray(kp), v_pool=jnp.asarray(vp),
            tail_k=jnp.asarray(tk), tail_v=jnp.asarray(tv))
        q = jnp.asarray(rng.normal(size=(b, h, hd)).astype(np.float32))
        oracle = kvbridge.decode_attention_ref(
            q, jnp.asarray(k_dense), jnp.asarray(v_dense), lengths)
        got_pull = kvbridge.decode_attention_pull(
            q, layer, cache.table, lengths, page_tokens=pt, max_pages=mp,
            mesh=mesh, mem_axis="data", budget=2)
        check("kv decode pull", got_pull, oracle, atol=2e-5)
        got_push = kvbridge.decode_attention_push(
            q, layer, cache.table, lengths, page_tokens=pt, max_pages=mp,
            mesh=mesh, mem_axis="data")
        check("kv decode push", got_push, oracle, atol=2e-5)

        # --- kvbridge append: tail write + page-boundary flush ---------------
        lens2 = jnp.asarray([3, 3, 3, 3], jnp.int32)
        layer2 = kvbridge.PagedKVLayer(
            k_pool=jnp.zeros_like(layer.k_pool),
            v_pool=jnp.zeros_like(layer.v_pool),
            tail_k=jnp.asarray(tk), tail_v=jnp.asarray(tv))
        k_new = jnp.asarray(rng.normal(size=(b, kv, hd)).astype(np.float32))
        v_new = jnp.asarray(rng.normal(size=(b, kv, hd)).astype(np.float32))
        layer3 = kvbridge.append(layer2, cache.table, lens2, k_new, v_new,
                                 page_tokens=pt, max_pages=mp, mesh=mesh,
                                 mem_axis="data")
        # page 0 of every sequence flushed (length 3 -> 4 == page_tokens)
        for bb in range(b):
            row = home[bb * mp] * ppn_kv + slot[bb * mp]
            exp_page = np.asarray(tk[bb]).copy()
            exp_page[3] = np.asarray(k_new[bb])
            check(f"append flush b{bb}",
                  np.asarray(layer3.k_pool)[row], exp_page)
        check("append tail reset", np.asarray(layer3.tail_k),
              np.zeros_like(tk))

    route_program_checks()
    telemetry_checks()
    hierarchical_checks()
    pipelined_checks()

    print("ALL OK")


def route_program_checks():
    """RouteProgram acceptance on a full 8-way mem ring.

    * switching unidirectional -> bidirectional -> pruned on the same jitted
      pull/push triggers no retrace (programs are runtime inputs),
    * every program's result is bit-exact against the program-aware oracle,
    * the bidirectional program covers all 7 distances in 8 // 2 = 4
      circuit epochs (vs 7 unidirectionally).
    """
    mesh8 = jax.make_mesh((8,), ("data",))
    n, ppn, page = 8, 8, 16
    rng = np.random.default_rng(7)
    pool = jnp.asarray(rng.normal(size=(n * ppn, page)).astype(np.float32))
    table = MemPortTable.striped(48, n, ppn)
    want = jnp.asarray(rng.integers(-1, 48, size=(n, 7)).astype(np.int32))

    uni = steering.unidirectional_program(n)
    bi = steering.bidirectional_program(n)
    assert uni.num_epochs() == n - 1, uni.num_epochs()
    # floor(N/2) in general; for the even 8-ring this equals ceil(8/2) = 4
    assert bi.num_epochs() == n // 2, bi.num_epochs()
    print(f"ok: route epochs uni={uni.num_epochs()} bi={bi.num_epochs()}")

    pull = jax.jit(functools.partial(bridge.pull_pages, mesh=mesh8, budget=3))
    exp = np.asarray(ref.pull_pages_ref(pool, want, table, pages_per_node=ppn))
    for name, prog in [("uni", uni), ("bi", bi),
                       ("avoid_cw", steering.link_avoiding_program(n, +1))]:
        got = np.asarray(pull(pool, want, table, program=prog))
        np.testing.assert_array_equal(got, exp, err_msg=f"pull {name}")
        print(f"ok: pull {name} bit-exact")
    # pruned-to-live-distances from the control plane (affinity placement)
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=48)
    cp.allocate(8, policy="affinity", affinity=2)
    t_aff = cp.table()
    pr = cp.route_program()
    want_aff = jnp.asarray(rng.integers(0, 8, size=(n, 5)).astype(np.int32))
    got = np.asarray(pull(pool, want_aff, t_aff, program=pr))
    np.testing.assert_array_equal(
        got, np.asarray(ref.pull_pages_ref(pool, want_aff, t_aff,
                                           pages_per_node=ppn, program=pr)))
    np.testing.assert_array_equal(
        got, np.asarray(ref.pull_pages_ref(pool, want_aff, t_aff,
                                           pages_per_node=ppn)))
    print("ok: pull pruned (control-plane program) bit-exact")
    # a *wrongly* pruned program drops exactly the pages the oracle drops
    bad = steering.pruned_program(bi, range(2, n))
    got = np.asarray(pull(pool, want, table, program=bad))
    np.testing.assert_array_equal(
        got, np.asarray(ref.pull_pages_ref(pool, want, table,
                                           pages_per_node=ppn, program=bad)))
    assert not np.array_equal(got, exp), "pruning distance 1 dropped nothing"
    print("ok: pull wrong-prune drops distance-1 pages like the oracle")
    assert pull._cache_size() == 2, pull._cache_size()  # 2 table shapes only
    print("ok: program switches triggered no retrace")

    push = jax.jit(functools.partial(bridge.push_pages, mesh=mesh8, budget=2))
    dest = np.stack([np.arange(4) + 6 * node for node in range(n)])
    payload = rng.normal(size=(n, 4, page)).astype(np.float32)
    expp = np.asarray(ref.push_pages_ref(
        pool, jnp.asarray(dest), jnp.asarray(payload), table,
        pages_per_node=ppn))
    for name, prog in [("uni", uni), ("bi", bi)]:
        got = np.asarray(push(pool, jnp.asarray(dest), jnp.asarray(payload),
                              table, program=prog))
        np.testing.assert_array_equal(got, expp, err_msg=f"push {name}")
    assert push._cache_size() == 1, push._cache_size()
    print("ok: push programs bit-exact, no retrace")


def telemetry_checks():
    """In-band counters on a real 8-way mem ring.

    * pull/push counters under arbitrary programs and per-node throttles
      match the oracle's per-request walk exactly,
    * swapping programs / budgets with collection ON triggers no retrace,
    * a throttled push spills exactly the tail the rate limiter drops,
    * counters feed the aggregator and compile a load-balanced program.
    """
    mesh8 = jax.make_mesh((8,), ("data",))
    n, ppn, page = 8, 8, 16
    rng = np.random.default_rng(11)
    pool = jnp.asarray(rng.normal(size=(n * ppn, page)).astype(np.float32))
    table = MemPortTable.striped(48, n, ppn)
    want = jnp.asarray(rng.integers(-1, 48, size=(n, 7)).astype(np.int32))
    ab = jnp.asarray(rng.integers(1, 4, size=(n,)).astype(np.int32))

    uni = steering.unidirectional_program(n)
    bi = steering.bidirectional_program(n)
    pruned = steering.pruned_program(bi, [1, 2, 6])
    pull = jax.jit(functools.partial(bridge.pull_pages, mesh=mesh8, budget=3,
                                     collect_telemetry=True))

    def check_telem(name, got, exp):
        for f in TELEM_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(exp, f)),
                err_msg=f"{name}: {f}")
        print(f"ok: telemetry {name} == oracle")

    telem_bi = None
    for name, prog in [("uni", uni), ("bi", bi), ("pruned", pruned)]:
        out, telem = pull(pool, want, table, program=prog, active_budget=ab)
        exp = ref.expected_transfer_telemetry(
            np.asarray(want), table, prog, num_nodes=n, budget=3,
            active_budget=np.asarray(ab))
        check_telem(f"pull {name}", telem, exp)
        if name == "bi":
            telem_bi = telem
    assert pull._cache_size() == 1, pull._cache_size()
    print("ok: telemetry collection retrace-free across programs/budgets")

    # throttled push: spilled tail leaves slots untouched, counters match
    dest = np.stack([np.arange(6) + 6 * node for node in range(n)])
    payload = rng.normal(size=(n, 6, page)).astype(np.float32)
    got, ptelem = bridge.push_pages(
        pool, jnp.asarray(dest), jnp.asarray(payload), table, mesh=mesh8,
        budget=3, active_budget=jnp.int32(2), collect_telemetry=True)
    served = ref.rate_limit_mask(6, 3, 2)          # 2 rounds x 2 lanes
    masked = jnp.asarray(np.where(served[None, :], dest, FREE))
    expp = ref.push_pages_ref(pool, masked, jnp.asarray(payload), table,
                              pages_per_node=ppn)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expp))
    exp_pt = ref.expected_transfer_telemetry(
        dest, table, None, num_nodes=n, budget=3, active_budget=2)
    check_telem("push throttled", ptelem, exp_pt)
    assert int(np.asarray(ptelem.spilled).sum()) == n * 2
    print("ok: push rate-limiter parity on the 8-ring")

    # measured feedback: aggregate -> load-balanced program, bit-exact pull
    agg = TelemetryAggregator(n, page_bytes=page * 4)
    agg.update(telem_bi)
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=48)
    cp.allocate(48, policy="striped")
    lb = cp.route_program(telemetry=agg)
    lb.validate()
    out_lb, telem_lb = pull(pool, want, table, program=lb, active_budget=ab)
    exp_lb = ref.expected_transfer_telemetry(
        np.asarray(want), table, lb, num_nodes=n, budget=3,
        active_budget=np.asarray(ab))
    check_telem("pull load-balanced", telem_lb, exp_lb)
    want_np = np.asarray(want)
    masked_want = np.stack([
        np.where(ref.rate_limit_mask(want_np.shape[1], 3, int(ab[i])),
                 want_np[i], FREE) for i in range(n)])
    np.testing.assert_array_equal(
        np.asarray(out_lb),
        np.asarray(ref.pull_pages_ref(pool, jnp.asarray(masked_want), table,
                                      pages_per_node=ppn, program=lb)))
    print("ok: telemetry-compiled load-balanced program bit-exact")


def hierarchical_checks():
    """Board + rack fabric acceptance on the real 8-way ring (2 boards x 4).

    * the hierarchical RouteProgram's transfers AND telemetry — including
      the per-tier counters — are bit-exact against the ref oracle,
    * swapping flat <-> hierarchical programs on the same jitted pull is
      retrace-free (one cache entry: the programs share one static shape),
    * the group mask really steers the datapath: masking an offset's
      board-crossing requesters drops exactly their pages, like the oracle,
    * a topology-aware control plane compiles a valid hierarchical program
      from placement.
    """
    mesh8 = jax.make_mesh((8,), ("data",))
    topo = Topology.boards(2, 4)
    n, ppn, page = 8, 8, 16
    rng = np.random.default_rng(23)
    pool = jnp.asarray(rng.normal(size=(n * ppn, page)).astype(np.float32))
    table = MemPortTable.striped(48, n, ppn)
    want = jnp.asarray(rng.integers(-1, 48, size=(n, 7)).astype(np.int32))

    hier = steering.hierarchical_program(topo)
    hier.validate()
    steering.validate_hierarchical(hier, topo)
    bi = steering.bidirectional_program(n)

    def check_telem(name, got, exp):
        for f in TELEM_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(exp, f)),
                err_msg=f"{name}: {f}")
        print(f"ok: telemetry {name} == oracle")

    pull = jax.jit(functools.partial(bridge.pull_pages, mesh=mesh8, budget=3,
                                     topology=topo, collect_telemetry=True))
    exp_pages = np.asarray(ref.pull_pages_ref(pool, want, table,
                                              pages_per_node=ppn))
    for name, prog in [("flat bi", bi), ("hierarchical", hier),
                       ("flat bi again", bi)]:
        out, telem = pull(pool, want, table, program=prog)
        np.testing.assert_array_equal(np.asarray(out), exp_pages,
                                      err_msg=name)
        exp = ref.expected_transfer_telemetry(
            np.asarray(want), table, prog, num_nodes=n, budget=3,
            topology=topo)
        check_telem(name, telem, exp)
    # per-tier occupancy really split: the fabric has both tiers in play
    _, telem_h = pull(pool, want, table, program=hier)
    intra, inter = telem_h.tier_pages()
    assert int(np.asarray(intra).sum()) > 0
    assert int(np.asarray(inter).sum()) > 0
    assert int(np.asarray(telem_h.tier_hops)[:, 1].sum()) > 0
    print("ok: hierarchical per-tier telemetry live on both tiers")
    # acceptance: flat <-> hierarchical swaps share ONE jit cache entry
    assert pull._cache_size() == 1, pull._cache_size()
    print("ok: flat <-> hierarchical program swap triggered no retrace")

    # group-masked offsets steer the datapath: cut slot d=1's board-crossing
    # requesters (local ranks 3 — their +1 neighbour is the next board)
    mask = np.asarray(hier.rank_epoch) >= 0
    r = np.arange(n)
    mask[0, :] = topo.pair_intra(r, (r + 1) % n)
    masked = steering.masked_ranks_program(hier, mask)
    got_m, telem_m = pull(pool, want, table, program=masked)
    exp_m = ref.pull_pages_ref(pool, want, table, pages_per_node=ppn,
                               program=masked)
    np.testing.assert_array_equal(np.asarray(got_m), np.asarray(exp_m))
    check_telem("group-masked", telem_m, ref.expected_transfer_telemetry(
        np.asarray(want), table, masked, num_nodes=n, budget=3,
        topology=topo))
    assert pull._cache_size() == 1, pull._cache_size()
    print("ok: group-masked offsets FREE-mask exactly the cut pairings")

    # topology-aware control plane: placement -> hierarchical program
    cp = ControlPlane(num_nodes=n, pages_per_node=ppn, num_logical=48,
                      topology=topo)
    cp.allocate(48, policy="striped")
    prog = cp.route_program()
    steering.validate_hierarchical(prog, topo)
    out_cp, _ = pull(pool, want, cp.table(), program=prog)
    np.testing.assert_array_equal(
        np.asarray(out_cp),
        np.asarray(ref.pull_pages_ref(pool, want, cp.table(),
                                      pages_per_node=ppn, program=prog)))
    assert pull._cache_size() == 1, pull._cache_size()
    print("ok: control-plane hierarchical program bit-exact, no retrace")

    # push path under the hierarchical program: bit-exact + tier counters
    dest = np.stack([np.arange(4) + 6 * node for node in range(n)])
    payload = rng.normal(size=(n, 4, page)).astype(np.float32)
    got_p, ptelem = bridge.push_pages(
        pool, jnp.asarray(dest), jnp.asarray(payload), table, mesh=mesh8,
        budget=2, program=hier, topology=topo, collect_telemetry=True)
    np.testing.assert_array_equal(
        np.asarray(got_p),
        np.asarray(ref.push_pages_ref(pool, jnp.asarray(dest),
                                      jnp.asarray(payload), table,
                                      pages_per_node=ppn, program=hier)))
    check_telem("push hierarchical", ptelem, ref.expected_transfer_telemetry(
        dest, table, hier, num_nodes=n, budget=2, topology=topo))


def pipelined_checks():
    """Pipelined multi-channel round engine on the real 8-way mem ring.

    * ``channels ∈ {1, 2, 4}`` pull/push results are bit-exact vs the
      serial engine for every program variant (uni / bi / pruned /
      load-balanced / hierarchical / group-masked) — and vs the pipelined
      ref oracle's independent chunk-schedule walk,
    * telemetry counters are bit-exact across depths (channels-blind),
    * throttled + overprovisioned transfers keep the spill semantics,
    * bufferless HLO regression: ``edge_buffer=False`` serializes N-1
      barriers on both paths — the epoch-0 loopback access included
      (historically the pull chain skipped it: N-2), the edge-buffered
      datapath has none.
    """
    mesh8 = jax.make_mesh((8,), ("data",))
    n, ppn, page = 8, 8, 16
    rng = np.random.default_rng(31)
    pool = jnp.asarray(rng.normal(size=(n * ppn, page)).astype(np.float32))
    table = MemPortTable.striped(48, n, ppn)
    want = jnp.asarray(rng.integers(-1, 48, size=(n, 7)).astype(np.int32))
    topo = Topology.boards(2, 4)
    hier = steering.hierarchical_program(topo)
    mask = np.asarray(hier.rank_epoch) >= 0
    r8 = np.arange(n)
    mask[0, :] = topo.pair_intra(r8, (r8 + 1) % n)
    bi = steering.bidirectional_program(n)
    variants = [
        ("uni", steering.unidirectional_program(n)),
        ("bi", bi),
        ("pruned", steering.pruned_program(bi, [1, 2, 6])),
        ("load_balanced", steering.load_balanced_program(
            n, np.asarray([6, 3, 2, 0, 0, 1, 4], float))),
        ("hierarchical", hier),
        ("masked", steering.masked_ranks_program(hier, mask)),
    ]

    with bridge.use_mesh(mesh8):
        # One jitted pull/push per depth; programs stay runtime inputs, so
        # the whole variant sweep compiles each engine exactly once.
        pulls = {ch: jax.jit(functools.partial(
            bridge.pull_pages, mesh=mesh8, budget=3, channels=ch,
            topology=topo, collect_telemetry=True)) for ch in (1, 2, 4)}
        pushes = {ch: jax.jit(functools.partial(
            bridge.push_pages, mesh=mesh8, budget=2, channels=ch))
            for ch in (1, 2, 4)}
        dest = np.stack([np.arange(4) + 6 * node for node in range(n)])
        payload = rng.normal(size=(n, 4, page)).astype(np.float32)
        for name, prog in variants:
            serial, telem_s = pulls[1](pool, want, table, program=prog)
            pserial = pushes[1](pool, jnp.asarray(dest),
                                jnp.asarray(payload), table, program=prog)
            for ch in (2, 4):
                piped, telem_p = pulls[ch](pool, want, table, program=prog)
                np.testing.assert_array_equal(
                    np.asarray(piped), np.asarray(serial),
                    err_msg=f"pull {name} ch={ch}")
                for f in TELEM_FIELDS:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(telem_p, f)),
                        np.asarray(getattr(telem_s, f)),
                        err_msg=f"telemetry {name} ch={ch}: {f}")
                exp = ref.pull_pages_pipelined_ref(
                    pool, want, table, ppn, prog, budget=3, channels=ch)
                np.testing.assert_array_equal(np.asarray(piped),
                                              np.asarray(exp),
                                              err_msg=f"oracle {name} {ch}")
                ppiped = pushes[ch](pool, jnp.asarray(dest),
                                    jnp.asarray(payload), table,
                                    program=prog)
                np.testing.assert_array_equal(
                    np.asarray(ppiped), np.asarray(pserial),
                    err_msg=f"push {name} ch={ch}")
            print(f"ok: pipelined pull+push {name} bit-exact "
                  f"(ch=2,4 + oracle)")

        # throttled + overprovisioned pipelined pull keeps spill semantics
        want3 = jnp.asarray(np.arange(32).reshape(8, 4).astype(np.int32))
        table32 = MemPortTable.striped(32, n, ppn)
        for ch in (2, 4):
            got = jax.jit(functools.partial(
                bridge.pull_pages, mesh=mesh8, budget=4, overprovision=2,
                channels=ch))(pool, want3, table32,
                              active_budget=jnp.int32(2))
            exp = ref.pull_pages_pipelined_ref(
                pool, want3, table32, ppn, None, budget=4, channels=ch,
                active_budget=2, overprovision=2)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
        print("ok: pipelined pull throttled/overprovisioned == oracle")

        # channels swaps retrace (static knob) but never change results;
        # programs still swap retrace-free at any depth
        for ch in (2, 4):
            assert pulls[ch]._cache_size() == 1, pulls[ch]._cache_size()
            assert pushes[ch]._cache_size() == 1, pushes[ch]._cache_size()
        print("ok: program swaps retrace-free at channels=2,4")

        # HLO regression: bufferless serialization barriers (incl. loopback)
        def barriers(f, *args):
            return jax.jit(f).lower(*args).as_text().count(
                "optimization_barrier")

        pull_nb = functools.partial(bridge.pull_pages, mesh=mesh8, budget=3,
                                    edge_buffer=False)
        push_nb = functools.partial(bridge.push_pages, mesh=mesh8, budget=2,
                                    edge_buffer=False)
        assert barriers(pull_nb, pool, want, table) == n - 1
        assert barriers(push_nb, pool, jnp.asarray(dest),
                        jnp.asarray(payload), table) == n - 1
        pull_eb = functools.partial(bridge.pull_pages, mesh=mesh8, budget=3)
        assert barriers(pull_eb, pool, want, table) == 0
        # bufferless results identical on both paths (serialization only)
        got_nb = bridge.pull_pages(pool, want, table, mesh=mesh8, budget=3,
                                   edge_buffer=False, channels=4)
        np.testing.assert_array_equal(
            np.asarray(got_nb),
            np.asarray(ref.pull_pages_ref(pool, want, table,
                                          pages_per_node=ppn)))
        got_pb = bridge.push_pages(pool, jnp.asarray(dest),
                                   jnp.asarray(payload), table, mesh=mesh8,
                                   budget=2, edge_buffer=False)
        np.testing.assert_array_equal(
            np.asarray(got_pb),
            np.asarray(ref.push_pages_ref(pool, jnp.asarray(dest),
                                          jnp.asarray(payload), table,
                                          pages_per_node=ppn)))
        print("ok: bufferless barriers = N-1 (loopback chained), results "
              "bit-exact")


if __name__ == "__main__":
    main()
