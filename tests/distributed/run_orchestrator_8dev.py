"""Multi-tenant orchestration validation on 8 virtual CPU devices.

Run as a subprocess by tests/test_distributed.py (auto-collected).  Covers
the tenancy acceptance contract on the real 8-way mem ring:

* per-tenant telemetry (served / spilled / pruned histograms) is bit-exact
  against the extended ref oracle for every program variant — uni / bi /
  pruned / load-balanced / hierarchical / group-masked — on both the pull
  and push paths,
* tenant share swaps are retrace-free: swapping the tenant-id lane, the
  window composition and the active budget on one jitted pull hits a
  single jit cache entry,
* the orchestrator end-to-end: board-anchored tenant leases on a 2x4
  fabric, schedule-composed request windows through the real datapath,
  measured per-tenant demand re-fitting the windows (interactive demand
  cap + work-conserving batch spill).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import bridge, ref, steering  # noqa: E402
from repro.core.control_plane import ControlPlane  # noqa: E402
from repro.core.memport import MemPortTable  # noqa: E402
from repro.core.topology import Topology  # noqa: E402
from repro.orchestrator import Orchestrator, TenantSpec  # noqa: E402

TELEM_FIELDS = ("slot_served", "loopback_served", "spilled", "pruned",
                "traffic", "epoch_cw", "epoch_ccw", "slot_intra",
                "tier_hops", "tenant_served", "tenant_spilled",
                "tenant_pruned")


def check_telem(name, got, exp):
    for f in TELEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(exp, f)),
            err_msg=f"{name}: {f}")
    print(f"ok: telemetry {name} == oracle")


def tenant_oracle_checks():
    """Tenant lane bit-exact vs the oracle for all six program variants."""
    mesh8 = jax.make_mesh((8,), ("data",))
    n, ppn, page = 8, 8, 16
    rng = np.random.default_rng(41)
    pool = jnp.asarray(rng.normal(size=(n * ppn, page)).astype(np.float32))
    table = MemPortTable.striped(48, n, ppn)
    want = jnp.asarray(rng.integers(-1, 48, size=(n, 7)).astype(np.int32))
    lane = jnp.asarray(rng.integers(0, 4, size=(n, 7)).astype(np.int32))
    ab = jnp.asarray(rng.integers(1, 4, size=(n,)).astype(np.int32))

    topo = Topology.boards(2, 4)
    hier = steering.hierarchical_program(topo)
    mask = np.asarray(hier.rank_epoch) >= 0
    r8 = np.arange(n)
    mask[0, :] = topo.pair_intra(r8, (r8 + 1) % n)
    bi = steering.bidirectional_program(n)
    variants = [
        ("uni", steering.unidirectional_program(n)),
        ("bi", bi),
        ("pruned", steering.pruned_program(bi, [1, 2, 6])),
        ("load_balanced", steering.load_balanced_program(
            n, np.asarray([6, 3, 2, 0, 0, 1, 4], float))),
        ("hierarchical", hier),
        ("masked", steering.masked_ranks_program(hier, mask)),
    ]
    with bridge.use_mesh(mesh8):
        pull = jax.jit(functools.partial(
            bridge.pull_pages, mesh=mesh8, budget=3, topology=topo,
            collect_telemetry=True))
        push = jax.jit(functools.partial(
            bridge.push_pages, mesh=mesh8, budget=2, topology=topo,
            collect_telemetry=True))
        dest = np.stack([np.arange(4) + 6 * node for node in range(n)])
        dlane = jnp.asarray((dest % 4).astype(np.int32))
        payload = rng.normal(size=(n, 4, page)).astype(np.float32)
        for name, prog in variants:
            _, telem = pull(pool, want, table, program=prog,
                            active_budget=ab, tenant_ids=lane)
            exp = ref.expected_transfer_telemetry(
                np.asarray(want), table, prog, num_nodes=n, budget=3,
                active_budget=np.asarray(ab), topology=topo,
                tenant_ids=np.asarray(lane))
            check_telem(f"pull {name} tenants", telem, exp)
            # reconciliation: tenant sums == untagged counters
            np.testing.assert_array_equal(
                np.asarray(telem.tenant_served).sum(-1),
                np.asarray(telem.served_total()))
            _, ptelem = push(pool, jnp.asarray(dest), jnp.asarray(payload),
                             table, program=prog, tenant_ids=dlane)
            check_telem(f"push {name} tenants", ptelem,
                        ref.expected_transfer_telemetry(
                            dest, table, prog, num_nodes=n, budget=2,
                            topology=topo, tenant_ids=np.asarray(dlane)))

        # acceptance: tenant share swaps never retrace.  New lanes, new
        # windows (a different active budget) and new programs all hit the
        # single compiled entry per callable.
        for seed in (1, 2, 3):
            r2 = np.random.default_rng(seed)
            lane2 = jnp.asarray(r2.integers(0, 4, size=(n, 7)), jnp.int32)
            ab2 = jnp.asarray(r2.integers(1, 4, size=(n,)), jnp.int32)
            pull(pool, want, table, program=bi, active_budget=ab2,
                 tenant_ids=lane2)
        assert pull._cache_size() == 1, pull._cache_size()
        assert push._cache_size() == 1, push._cache_size()
        print("ok: tenant share swaps retrace-free (1 cache entry)")


def orchestrator_e2e_checks():
    """Register -> lease -> compose -> measure -> re-fit on the real ring."""
    mesh8 = jax.make_mesh((8,), ("data",))
    topo = Topology.boards(2, 4)
    n, ppn, page = 8, 16, 8
    cp = ControlPlane(n, ppn, num_logical=n * ppn, topology=topo)
    orc = Orchestrator(cp, budget=8, page_bytes=page * 4, control_period=1,
                       migrate=False)
    orc.register(TenantSpec(0, "chat", qos="interactive", share=1.0,
                            page_quota=32))
    orc.register(TenantSpec(1, "crawl", qos="batch", share=1.0))
    d0, l0 = orc.request_lease(0, 16)
    d1, l1 = orc.request_lease(1, 64, policy="striped")
    assert d0.admitted and d1.admitted
    # board anchoring: tenant 0's lease lives on board 0
    g = np.asarray(topo.group)
    home_col = np.asarray(cp.table().home)
    assert {int(g[int(home_col[p])]) for p in l0.region.page_ids} == {0}

    # chat offers 2 pages/node, crawl floods with 8/node
    chat_ids = np.asarray(l0.region.page_ids)
    crawl_ids = np.asarray(l1.region.page_ids)
    backlogs = {0: [chat_ids[i * 2:(i + 1) * 2].tolist() for i in range(n)],
                1: [crawl_ids[i * 8:(i + 1) * 8].tolist()
                    for i in range(n)]}
    want, lane, taken = orc.compose_requests(backlogs)
    assert want.shape[0] == n
    pool = jnp.asarray(np.random.default_rng(0).normal(
        size=(n * ppn, page)).astype(np.float32))
    with bridge.use_mesh(mesh8):
        out, telem = bridge.pull_pages(
            pool, jnp.asarray(want), orc.table(), mesh=mesh8,
            budget=orc.budget, program=orc.route_program(),
            active_budget=jnp.asarray(orc.active_budget()),
            topology=topo, collect_telemetry=True,
            tenant_ids=jnp.asarray(lane))
    exp = ref.expected_transfer_telemetry(
        want, orc.table(), orc.route_program(), num_nodes=n,
        budget=orc.budget, active_budget=orc.active_budget(),
        topology=topo, tenant_ids=lane)
    check_telem("orchestrator composed round", telem, exp)
    # the composed result is bit-exact vs the page oracle too
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(ref.pull_pages_ref(pool, jnp.asarray(want), orc.table(),
                                      pages_per_node=ppn,
                                      program=orc.route_program())))

    rep = orc.step(telem)
    assert rep["refit"]
    w = orc.schedule.windows
    # chat demand-capped (2/node), crawl takes the spilled budget
    assert w[0] >= 2 and w[1] > w[0], w
    assert sum(w.values()) <= orc.budget
    served = np.asarray(telem.tenant_served).sum(0)
    assert served[0] == 2 * n, served        # every chat page served
    print(f"ok: orchestrator e2e (windows {w}, chat served {served[0]}, "
          f"crawl served {served[1]})")
    print(orc.describe())


def kv_append_pad_checks():
    """A batch not divisible by the mesh must not phantom-write page 0.

    append() pads the per-node destination lists when b % n != 0; a zero
    pad would be a live push of all-zero payloads into logical page 0
    (sequence 0's first pooled KV page) on every flush step.
    """
    from repro.core import kvbridge
    mesh8 = jax.make_mesh((8,), ("data",))
    b, kv, hd, pt, mp, n = 5, 2, 4, 4, 2, 8
    rng = np.random.default_rng(53)
    cache = kvbridge.init_cache(1, b, pt * mp, pt, kv, hd, mesh=mesh8,
                                mem_axis="data", dtype=jnp.float32)
    layer = jax.tree.map(lambda x: x[0], cache.layers)
    tails = rng.normal(size=(b, pt, kv, hd)).astype(np.float32)
    layer = kvbridge.PagedKVLayer(
        k_pool=layer.k_pool, v_pool=layer.v_pool,
        tail_k=jnp.asarray(tails), tail_v=jnp.asarray(tails))
    lengths = jnp.full((b,), pt - 1, jnp.int32)   # every tail flushes
    k_new = jnp.asarray(rng.normal(size=(b, kv, hd)).astype(np.float32))
    with bridge.use_mesh(mesh8):
        out = kvbridge.append(layer, cache.table, lengths, k_new, k_new,
                              page_tokens=pt, max_pages=mp, mesh=mesh8,
                              mem_axis="data", budget=2)
    home = np.asarray(cache.table.home)
    slot = np.asarray(cache.table.slot)
    ppn_kv = out.k_pool.shape[0] // n
    row0 = home[0] * ppn_kv + slot[0]             # sequence 0, page 0
    exp = tails[0].copy()
    exp[pt - 1] = np.asarray(k_new[0])
    np.testing.assert_array_equal(np.asarray(out.k_pool)[row0], exp)
    print("ok: kv append pad rows stay FREE (no phantom page-0 write)")


def main():
    assert jax.device_count() == 8, jax.devices()
    tenant_oracle_checks()
    orchestrator_e2e_checks()
    kv_append_pad_checks()
    print("ALL OK")


if __name__ == "__main__":
    main()
