"""Pipeline-parallel validation on 8 virtual devices (4 stages x 2 data).

Checks: (1) the GPipe schedule over ppermute circuits reproduces the
sequential stack bit-for-bit; (2) it is differentiable end-to-end (grads
match the sequential reference); (3) the HLO contains the stage-to-stage
collective-permute route.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.parallel import pipeline  # noqa: E402

S, M, MB, D = 4, 6, 3, 16  # stages, microbatches, microbatch size, width


def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


def sequential(params, x):
    for i in range(S):
        x = stage_fn(jax.tree.map(lambda a, i=i: a[i], params), x)
    return x


def main():
    assert jax.device_count() == 8
    mesh = jax.make_mesh((4, 2), ("stage", "data"))
    rng = np.random.default_rng(0)
    params = {
        "w": jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32)) * 0.3,
        "b": jnp.asarray(rng.normal(size=(S, D)).astype(np.float32)) * 0.1,
    }
    x = jnp.asarray(rng.normal(size=(M * MB, D)).astype(np.float32))
    x_mb = pipeline.split_microbatches(x, M)

    run = jax.jit(lambda p, xm: pipeline.pipeline_apply(
        stage_fn, p, xm, mesh=mesh, stage_axis="stage"))
    got = pipeline.merge_microbatches(run(params, x_mb))
    exp = sequential(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-5)
    print("ok: pipeline == sequential")

    def loss_pipe(p):
        return (pipeline.merge_microbatches(pipeline.pipeline_apply(
            stage_fn, p, x_mb, mesh=mesh, stage_axis="stage")) ** 2).sum()

    def loss_seq(p):
        return (sequential(p, x) ** 2).sum()

    g1 = jax.grad(loss_pipe)(params)
    g2 = jax.grad(loss_seq)(params)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(g1["b"]), np.asarray(g2["b"]),
                               atol=2e-4)
    print("ok: pipeline backward == sequential backward")

    hlo = jax.jit(lambda p, xm: pipeline.pipeline_apply(
        stage_fn, p, xm, mesh=mesh, stage_axis="stage")).lower(
        params, x_mb).compile().as_text()
    assert "collective-permute" in hlo
    print("ok: stage route is a collective-permute circuit")
    print("ALL OK")


if __name__ == "__main__":
    main()
