"""Shared fixtures for the bridge test-suite.

One home for the pool / placement-table / telemetry builders that
test_bridge.py, test_telemetry.py, test_bridge_properties.py and
test_topology_properties.py previously duplicated — plus the random-fabric
generator the topology conformance suite draws from.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import steering
from repro.core.memport import MemPortTable
from repro.core.topology import Topology
from repro.telemetry.counters import (BridgeTelemetry, DEFAULT_MAX_TENANTS,
                                      num_epoch_bins)

#: Every BridgeTelemetry leaf, in dataclass order — keep in sync with
#: repro.telemetry.counters (assert_telem_equal walks all of them).
TELEM_FIELDS = ("slot_served", "loopback_served", "spilled", "pruned",
                "traffic", "epoch_cw", "epoch_ccw", "slot_intra",
                "tier_hops", "tenant_served", "tenant_spilled",
                "tenant_pruned")


def make_pool(num_slots, page, seed=0):
    """Random float32 page pool [num_slots, page]."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(num_slots, page)).astype(np.float32))


def striped_table(num_logical, num_nodes, pages_per_node) -> MemPortTable:
    """Round-robin placement (home = id % nodes) — the default test layout."""
    return MemPortTable.striped(num_logical, num_nodes, pages_per_node)


def assert_telem_equal(got: BridgeTelemetry, exp: BridgeTelemetry, msg=""):
    """Bit-exact comparison over every counter field."""
    for name in TELEM_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(exp, name)),
            err_msg=f"{msg}{name}")


def fake_telem(n, traffic_rows, spilled=None) -> BridgeTelemetry:
    """Telemetry with the given [rows, n] traffic matrix.

    Slot/epoch/tier histograms are derived from it as a flat bidirectional
    program on a single-board fabric would have produced them (distance
    d pages land at epoch min(d, n-d) - 1 on the shortest-way direction;
    everything is intra-board, board page-hops = pages x hops).
    """
    traffic_rows = np.asarray(traffic_rows, np.int32)
    rows = traffic_rows.shape[0]
    slot = np.zeros((rows, n - 1), np.int32)
    loop = np.zeros((rows,), np.int32)
    for i in range(rows):
        for h in range(n):
            d = (h - i) % n
            if d == 0:
                loop[i] += traffic_rows[i, h]
            else:
                slot[i, d - 1] += traffic_rows[i, h]
    bi = steering.bidirectional_program(n)
    off = np.asarray(bi.offsets)
    ep = np.asarray(bi.epoch)
    e = num_epoch_bins(n)
    cw = np.zeros((rows, e), np.int32)
    ccw = np.zeros((rows, e), np.int32)
    hops = np.abs(off)
    tier = np.zeros((rows, 2), np.int32)
    for k in range(n - 1):
        tgt = cw if off[k] > 0 else ccw
        tgt[:, ep[k]] += slot[:, k]
        tier[:, 0] += slot[:, k] * hops[k]
    # Tenant attribution with no lane: everything belongs to tenant 0.
    sp = (np.zeros((rows,), np.int32) if spilled is None
          else np.asarray(spilled, np.int32))
    ten_served = np.zeros((rows, DEFAULT_MAX_TENANTS), np.int32)
    ten_served[:, 0] = loop + slot.sum(1)
    ten_spilled = np.zeros((rows, DEFAULT_MAX_TENANTS), np.int32)
    ten_spilled[:, 0] = sp
    return BridgeTelemetry(
        slot_served=jnp.asarray(slot), loopback_served=jnp.asarray(loop),
        spilled=jnp.asarray(sp),
        pruned=jnp.asarray(np.zeros((rows,), np.int32)),
        traffic=jnp.asarray(traffic_rows),
        epoch_cw=jnp.asarray(cw), epoch_ccw=jnp.asarray(ccw),
        slot_intra=jnp.asarray(slot), tier_hops=jnp.asarray(tier),
        tenant_served=jnp.asarray(ten_served),
        tenant_spilled=jnp.asarray(ten_spilled),
        tenant_pruned=jnp.asarray(
            np.zeros((rows, DEFAULT_MAX_TENANTS), np.int32)))


def random_fabric(rng, min_groups=1, max_groups=4, min_size=2,
                  max_size=8) -> Topology:
    """A random (possibly ragged) board + rack fabric for property tests."""
    num_groups = int(rng.integers(min_groups, max_groups + 1))
    sizes = [int(rng.integers(min_size, max_size + 1))
             for _ in range(num_groups)]
    return Topology.from_sizes(sizes)
