"""Validate the HLO analyzer against hand-computable compiled programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
from benchmarks import hlo_analysis as H  # noqa: E402


def compile_text(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_trip_count_multiplier():
    """FLOPs of a scanned matmul must count every iteration."""
    def f(x, w):
        def body(c, wi):
            return jnp.dot(c, wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    text = compile_text(f, (128, 256), (10, 256, 256))
    stats = H.analyze(text)
    expected = 2 * 128 * 256 * 256 * 10
    assert stats.flops == pytest.approx(expected, rel=0.01)
    assert stats.unknown_trip_counts == 0


def test_single_dot_flops():
    def f(a, b):
        return jnp.dot(a, b)

    stats = H.analyze(compile_text(f, (64, 128), (128, 32)))
    assert stats.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_shape_bytes_parsing():
    assert H.shape_bytes("f32[4,8]{1,0}") == 128
    assert H.shape_bytes("bf16[10]") == 20
    assert H.shape_bytes("(s32[], f32[2,2]{1,0}, pred[8])") == 4 + 16 + 8
    assert H.shape_bytes("f32[]") == 4


def test_collective_bytes_no_collectives():
    stats = H.analyze(compile_text(lambda a: a * 2, (128,)))
    assert stats.collective_bytes == 0
    assert stats.hbm_bytes > 0


def test_hbm_slice_awareness():
    """A dynamic-slice of a big array charges ~slice bytes, not the array."""
    def f(big, idx_like):
        i = idx_like[0].astype(jnp.int32)
        return jax.lax.dynamic_slice(big, (i, 0), (1, 128))

    stats = H.analyze(compile_text(f, (10_000, 128), (1,)))
    # full operand would be 5.1 MB; slice-aware accounting stays tiny
    assert stats.hbm_bytes < 200_000
