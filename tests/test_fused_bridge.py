"""Fused Pallas datapath: bit-exactness, retrace and streaming attention.

The ``fused=`` knob (default ON) must be observationally invisible: the
fused serve/gather/commit kernels and the epoch-batched wire rounds serve
exactly what the unfused ppermute-chain engines serve — pages AND telemetry
bit-exact against both the unfused path and the numpy oracle, for arbitrary
programs, fabrics, budgets, throttles and tenant lanes.  The N-device
engines get the same treatment in tests/distributed/run_bridge_8dev.py;
here the loopback path (a 1-device mesh modelling ``table_nodes`` logical
ring nodes) keeps the whole contract under tier-1.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from topologies import assert_telem_equal, make_pool, random_fabric

from repro.core import bridge, kvbridge, ref, steering
from repro.core.memport import FREE, MemPortTable
from repro.kernels import bridge_gather
from repro.kernels.bridge_attention import stream_decode_accumulate


def _random_program(rng, topo):
    n = topo.num_nodes
    choice = rng.random()
    if n == 1 or choice < 0.2:
        return None
    if choice < 0.45:
        return steering.hierarchical_program(topo)
    if choice < 0.6:
        base = steering.hierarchical_program(topo)
        rank_live = rng.random(np.asarray(base.rank_epoch).shape) < 0.8
        return steering.masked_ranks_program(base, rank_live)
    if choice < 0.8:
        keep = [d for d in range(1, n) if rng.random() < 0.7] or [1]
        return steering.pruned_program(steering.bidirectional_program(n),
                                       keep)
    return steering.unidirectional_program(n)


# ---------------------------------------------------------------------------
# Datapath kernels against plain-jnp oracles
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), width=st.integers(1, 12))
def test_gather_scatter_kernels_match_oracle(seed, width):
    rng = np.random.default_rng(seed)
    pool = make_pool(16, 4, seed)
    reqs = jnp.asarray(rng.integers(-2, 16, size=(width,)), jnp.int32)
    got = bridge_gather.gather_pages(pool, reqs)
    exp = np.where((np.asarray(reqs) >= 0)[:, None],
                   np.asarray(pool)[np.clip(np.asarray(reqs), 0, None)], 0.0)
    np.testing.assert_array_equal(np.asarray(got), exp)
    # scatter: FREE drops, live rows land (single-writer: distinct rows)
    rows = rng.permutation(16)[:width].astype(np.int32)
    slots = jnp.asarray(np.where(rng.random(width) < 0.3, FREE, rows),
                        jnp.int32)
    data = jnp.asarray(rng.normal(size=(width, 4)), jnp.float32)
    got = bridge_gather.scatter_pages(pool, slots, data)
    exp = np.asarray(pool).copy()
    for w, s in enumerate(np.asarray(slots)):
        if s >= 0:
            exp[s] = np.asarray(data)[w]
    np.testing.assert_array_equal(np.asarray(got), exp)


# ---------------------------------------------------------------------------
# fused == unfused == oracle (pages + telemetry), loopback ring model
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    budget=st.integers(1, 8),
    active_budget=st.integers(1, 8),
    channels=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 10_000),
)
def test_fused_pull_push_bit_exact_property(budget, active_budget, channels,
                                            seed):
    """Random ragged fabrics x programs x channels x tenants: the fused
    datapath serves bit-exactly the unfused engine's pages and counters,
    and the full-throttle transfer matches the numpy oracle."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n, ppn = topo.num_nodes, 8
    pool = make_pool(n * ppn, 4, seed)
    num_logical = int(rng.integers(1, n * ppn + 1))
    table = MemPortTable.striped(num_logical, n, ppn)
    r = int(rng.integers(1, 16))
    want = jnp.asarray(rng.integers(-1, num_logical, size=(n, r)), jnp.int32)
    program = _random_program(rng, topo)
    tenants = jnp.asarray(rng.integers(0, 3, size=(n, r)), jnp.int32)
    kw = dict(mesh=None, budget=budget, channels=channels, table_nodes=n,
              program=program, topology=topo, tenant_ids=tenants,
              max_tenants=4, collect_telemetry=True,
              active_budget=jnp.int32(active_budget))

    got_f, telem_f = bridge.pull_pages(pool, want, table, fused=True, **kw)
    got_u, telem_u = bridge.pull_pages(pool, want, table, fused=False, **kw)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(got_u))
    assert_telem_equal(telem_f, telem_u, msg="pull ")

    # full throttle -> the classic oracle covers the fused transfer too
    full = bridge.pull_pages(pool, want, table, fused=True,
                             mesh=None, budget=budget, channels=channels,
                             table_nodes=n, program=program)
    exp = ref.pull_pages_ref(pool, want, table, pages_per_node=ppn,
                             program=program)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(exp))

    # push mirrors (single-writer: duplicate-free destinations)
    dest_ids = rng.permutation(num_logical)[: min(r, num_logical)]
    dest = np.full((n, r), FREE, np.int32)
    dest[0, : len(dest_ids)] = dest_ids
    dest = jnp.asarray(dest)
    payload = jnp.asarray(rng.normal(size=(n, r, 4)), jnp.float32)
    push_f, ptelem_f = bridge.push_pages(pool, dest, payload, table,
                                         fused=True, **kw)
    push_u, ptelem_u = bridge.push_pages(pool, dest, payload, table,
                                         fused=False, **kw)
    np.testing.assert_array_equal(np.asarray(push_f), np.asarray(push_u))
    assert_telem_equal(ptelem_f, ptelem_u, msg="push ")
    push_full = bridge.push_pages(pool, dest, payload, table, fused=True,
                                  mesh=None, budget=budget,
                                  channels=channels, table_nodes=n,
                                  program=program)
    pexp = ref.push_pages_ref(pool, dest, payload, table,
                              pages_per_node=ppn, program=program)
    np.testing.assert_array_equal(np.asarray(push_full), np.asarray(pexp))


def test_fused_pull_push_never_retraces():
    """Program / table / throttle swaps hit one trace under fused=True."""
    n, ppn, budget = 4, 8, 4
    pool = make_pool(n * ppn, 4)
    table = MemPortTable.striped(12, n, ppn)
    want = jnp.asarray(np.arange(12, dtype=np.int32)[None, :])
    payload = jnp.asarray(
        np.random.default_rng(0).normal(size=(1, 12, 4)), jnp.float32)
    pull = jax.jit(functools.partial(
        bridge.pull_pages, mesh=None, budget=budget, table_nodes=n,
        fused=True, collect_telemetry=True))
    push = jax.jit(functools.partial(
        bridge.push_pages, mesh=None, budget=budget, table_nodes=n,
        fused=True, collect_telemetry=True))
    progs = [steering.bidirectional_program(n),
             steering.unidirectional_program(n),
             steering.pruned_program(steering.bidirectional_program(n), [2])]
    t2 = MemPortTable.blocked(12, n, ppn)
    for prog in progs:
        for tab in (table, t2):
            for ab in (4, 2):
                pull(pool, want, tab, program=prog,
                     active_budget=jnp.int32(ab))
                push(pool, want, payload, tab, program=prog,
                     active_budget=jnp.int32(ab))
    assert pull._cache_size() == 1, pull._cache_size()
    assert push._cache_size() == 1, push._cache_size()


# ---------------------------------------------------------------------------
# Streaming decode attention
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_stream_decode_accumulate_matches_dense(seed):
    """The round-streamed kernel == dense softmax over each seq's pages."""
    rng = np.random.default_rng(seed)
    b, h, kv, hd, t = 3, 8, 2, 16, 4
    w = int(rng.integers(1, 9))
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(w, t, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(w, t, kv, hd)), jnp.float32)
    seq = jnp.asarray(rng.integers(0, b + 1, size=(w,)), jnp.int32)  # b=none
    live = jnp.asarray(rng.random(w) < 0.8, jnp.int32)
    m = jnp.full((b, h), -1e30, jnp.float32)
    l = jnp.zeros((b, h), jnp.float32)
    o = jnp.zeros((b, h, hd), jnp.float32)
    # stream the lanes in two arbitrary rounds
    cut = w // 2
    m, l, o = stream_decode_accumulate(q, k[:cut], v[:cut], seq[:cut],
                                       live[:cut], m, l, o)
    m, l, o = stream_decode_accumulate(q, k[cut:], v[cut:], seq[cut:],
                                       live[cut:], m, l, o)
    got = np.asarray(o) / np.maximum(np.asarray(l), 1e-30)[:, :, None]
    g = h // kv
    for bi in range(b):
        sel = (np.asarray(seq) == bi) & (np.asarray(live) > 0)
        if not sel.any():
            assert np.asarray(l)[bi].max() == 0.0
            continue
        kk = np.asarray(k)[sel].reshape(-1, kv, hd)
        vv = np.asarray(v)[sel].reshape(-1, kv, hd)
        qg = np.asarray(q)[bi].reshape(kv, g, hd)
        s = np.einsum("kgd,tkd->kgt", qg, kk).reshape(h, -1) * hd ** -0.5
        p = np.exp(s - s.max(1, keepdims=True))
        exp = (np.einsum("kgt,tkd->kgd", p.reshape(kv, g, -1), vv)
               .reshape(h, hd) / p.sum(1)[:, None])
        np.testing.assert_allclose(got[bi], exp, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_streaming_decode_attention_matches_unfused_and_ref(seed):
    """fused decode_attention_pull: pages consumed per-round inside the
    attention grid == the materialized unfused chain (float tolerance) ==
    the dense oracle; telemetry stays bit-exact."""
    rng = np.random.default_rng(seed)
    b, h, kv, hd = int(rng.integers(1, 5)), 8, 2, 8
    t, max_pages = 4, int(rng.integers(1, 5))
    budget = int(rng.integers(1, 5))
    max_len = t * max_pages
    cache = kvbridge.init_cache(1, b, max_len, t, kv, hd, mesh=None,
                                dtype=jnp.float32)
    layer = jax.tree.map(lambda x: x[0], cache.layers)
    lengths = jnp.zeros((b,), jnp.int32)
    steps = int(rng.integers(1, max_len + 1))
    dense_k = np.zeros((b, steps, kv, hd), np.float32)
    dense_v = np.zeros((b, steps, kv, hd), np.float32)
    for step in range(steps):
        kn = rng.normal(size=(b, kv, hd)).astype(np.float32)
        vn = rng.normal(size=(b, kv, hd)).astype(np.float32)
        dense_k[:, step], dense_v[:, step] = kn, vn
        layer = kvbridge.append(layer, cache.table, lengths, jnp.asarray(kn),
                                jnp.asarray(vn), page_tokens=t,
                                max_pages=max_pages, mesh=None,
                                budget=budget)
        lengths = lengths + 1
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    tenant = jnp.asarray(rng.integers(0, 2, size=(b,)), jnp.int32)
    kwargs = dict(page_tokens=t, max_pages=max_pages, mesh=None,
                  budget=budget, collect_telemetry=True,
                  tenant_of_seq=tenant, max_tenants=3)
    out_f, telem_f = kvbridge.decode_attention_pull(
        q, layer, cache.table, lengths, fused=True, **kwargs)
    out_u, telem_u = kvbridge.decode_attention_pull(
        q, layer, cache.table, lengths, fused=False, **kwargs)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_u),
                               atol=2e-5)
    assert_telem_equal(telem_f, telem_u)
    exp = kvbridge.decode_attention_ref(q, jnp.asarray(dense_k),
                                        jnp.asarray(dense_v), lengths)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(exp), atol=2e-5)
