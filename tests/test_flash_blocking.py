"""Property tests for the q-blocked, chunk-skipping flash attention.

`_live_chunk_range` statically prunes KV chunks; if it ever prunes a chunk
that contains a visible position, attention silently drops context — so we
sweep it adversarially against the dense oracle.
"""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from repro.models import flash


@settings(max_examples=40, deadline=None)
@given(
    sq=st.integers(1, 33),
    sk=st.integers(1, 48),
    chunk=st.sampled_from([4, 8, 16]),
    q_block=st.sampled_from([4, 8, 32]),
    causal=st.booleans(),
    window=st.sampled_from([0, 3, 7, 17]),
    q_offset=st.sampled_from([0, 5, 16]),
    seed=st.integers(0, 100),
)
def test_blocked_flash_matches_dense(sq, sk, chunk, q_block, causal,
                                     window, q_offset, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, sq, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, sk, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, sk, 2, 8)).astype(np.float32))
    got, _ = flash._flash_fwd_inner(q, k, v, causal, window, chunk,
                                    q_offset, q_block=q_block)
    exp = flash.attention_ref(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    # rows with NO visible positions (window entirely before k range or
    # causal offset before any key) are zero in ours, NaN-free in both
    got_np, exp_np = np.asarray(got), np.asarray(exp)
    assert not np.any(np.isnan(got_np))
    visible = np.zeros((sq,), bool)
    for i in range(sq):
        for j in range(sk):
            ok = True
            if causal and j > i + q_offset:
                ok = False
            if window > 0 and (i + q_offset) - j >= window:
                ok = False
            if ok:
                visible[i] = True
                break
    np.testing.assert_allclose(got_np[:, visible], exp_np[:, visible],
                               atol=5e-5)


def test_live_chunk_range_never_prunes_visible():
    """Exhaustive small sweep: every visible (q, k) pair is inside the
    [c_lo, c_hi) chunk range chosen for its q block."""
    for causal in (False, True):
        for window in (0, 3, 9):
            for q_offset in (0, 4):
                sq, sk, chunk, qb = 17, 23, 4, 8
                for q_lo in range(0, sq, qb):
                    q_hi = min(q_lo + qb, sq)
                    c_lo, c_hi = flash._live_chunk_range(
                        q_lo, q_hi, sk, chunk, causal, window, q_offset)
                    for qi in range(q_lo, q_hi):
                        for kj in range(sk):
                            vis = True
                            if causal and kj > qi + q_offset:
                                vis = False
                            if window > 0 and (qi + q_offset) - kj >= window:
                                vis = False
                            if vis:
                                cj = kj // chunk
                                assert c_lo <= cj < c_hi, (
                                    causal, window, q_offset, q_lo, qi, kj)
