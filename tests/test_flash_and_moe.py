"""XLA flash attention (custom VJP) and MoE layer tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from repro import configs
from repro.models import flash, layers, moe


# -- flash (XLA path) ----------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (True, 7), (False, 0)])
def test_flash_fwd_matches_dense(causal, window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 33, 8, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 49, 4, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 49, 4, 16)).astype(np.float32))
    got = flash.flash_attention(q, k, v, causal, window, 16, 8)
    exp = flash.attention_ref(q, k, v, causal=causal, window=window,
                              q_offset=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=3e-5)


def test_flash_grads_match_dense():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 24, 4, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 24, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 24, 2, 8)).astype(np.float32))

    def f(fn):
        return jax.grad(lambda q, k, v: (fn(q, k, v) ** 2).sum(),
                        argnums=(0, 1, 2))(q, k, v)

    g1 = f(lambda q, k, v: flash.flash_attention(q, k, v, True, 0, 8, 0))
    g2 = f(lambda q, k, v: flash.attention_ref(q, k, v, causal=True))
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), sq=st.integers(1, 40),
       sk=st.integers(1, 40), chunk=st.sampled_from([4, 16, 64]))
def test_flash_property_shapes(seed, sq, sk, chunk):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, sq, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, sk, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, sk, 2, 8)).astype(np.float32))
    got = flash.flash_attention(q, k, v, False, 0, chunk, 0)
    exp = flash.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=5e-5)


# -- MoE -----------------------------------------------------------------------

def moe_cfg(**kw):
    base = configs.get_reduced("granite-moe-1b-a400m")
    return dataclasses.replace(base, dtype="float32", **kw)


def test_moe_matches_dense_ref_with_ample_capacity():
    cfg = dataclasses.replace(moe_cfg(), capacity_factor=4.0)
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    got, metrics = moe.moe_ffn(cfg, p, x)
    exp = moe.moe_ffn_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-4, atol=1e-3)
    assert float(metrics["moe_drop_frac"]) == 0.0


def test_moe_capacity_drops_reported():
    cfg = dataclasses.replace(moe_cfg(), capacity_factor=0.25)
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    _, metrics = moe.moe_ffn(cfg, p, x)
    assert float(metrics["moe_drop_frac"]) > 0.0


def test_moe_aux_loss_balanced_router_is_low():
    """Uniform router -> aux loss ~= 1 (its minimum)."""
    cfg = moe_cfg()
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(0), jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform logits
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)).astype(np.float32))
    _, metrics = moe.moe_ffn(cfg, p, x)
    assert float(metrics["moe_aux_loss"]) == pytest.approx(1.0, abs=0.1)


def test_moe_gradients_flow_to_all_param_groups():
    cfg = moe_cfg()
    p = layers.init_tree(moe.moe_specs(cfg), jax.random.key(1), jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 16, cfg.d_model)).astype(np.float32))
    g = jax.grad(lambda p: (moe.moe_ffn(cfg, p, x)[0] ** 2).sum())(p)
    for name, leaf in g.items():
        assert float(jnp.abs(leaf).max()) > 0, f"zero grad for {name}"
