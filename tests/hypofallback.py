"""Deterministic stand-in for hypothesis when it is not installed.

``@given`` runs the test body over a fixed number of seeded draws instead of
skipping the whole module, so property tests keep their coverage in minimal
environments (the real hypothesis, pinned in requirements-dev.txt, is used
when available — see the try/except imports in the test modules).
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


class st:
    """The subset of hypothesis.strategies the test-suite uses."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    @staticmethod
    def sampled_from(seq):
        elems = list(seq)
        return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem.draw(rng)
            for _ in range(int(rng.integers(min_size, max_size + 1)))])


def settings(*_args, **_kwargs):
    def deco(f):
        return f
    return deco


def given(**strategies):
    def deco(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            # Seed from the test name: stable across runs and processes.
            rng = np.random.default_rng(
                zlib.crc32(f.__qualname__.encode()))
            for _ in range(MAX_EXAMPLES):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                f(*args, **drawn, **kwargs)
        # Hide the drawn parameters from pytest's fixture resolution (real
        # hypothesis does the same); __wrapped__ would leak the original
        # signature through inspect.signature.
        del wrapper.__wrapped__
        sig = inspect.signature(f)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in strategies])
        return wrapper
    return deco
