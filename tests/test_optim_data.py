"""Optimizer, gradient compression, data pipeline, zero_bridge (1-dev)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from repro import configs
from repro.config import OptimConfig
from repro.core import zero_bridge
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.optim import adamw
from repro.optim.compress import dequantize_int8, quantize_int8


def test_adamw_reduces_quadratic_loss():
    cfg = OptimConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.adamw_init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, m = adamw.adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert int(state.count) == 60


def test_lr_schedule_warmup_and_decay():
    cfg = OptimConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(cfg, s)) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.01)  # floor = 0.1 * lr


def test_grad_clip_bounds_update():
    cfg = OptimConfig(lr=1.0, warmup_steps=0, total_steps=10, grad_clip=1.0,
                      weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    state = adamw.adamw_init(params)
    grads = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = adamw.adamw_update(cfg, grads, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-6, 1e3))
def test_int8_quantization_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(257,)).astype(np.float32)) * scale
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-9


def test_synthetic_data_deterministic_and_restartable():
    cfg = configs.get_reduced("granite-3-8b")
    data = SyntheticLM(cfg, batch=2, seq_len=16, seed=7)
    b5a = data.batch_at(5)
    b5b = data.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    # iterate from a restart point reproduces the stream
    it = data.iterate(start_step=5)
    np.testing.assert_array_equal(next(it)["tokens"], b5a["tokens"])
    assert b5a["tokens"].max() < cfg.vocab_size
    np.testing.assert_array_equal(b5a["labels"].shape, (2, 16))


def test_prefetcher_preserves_order():
    cfg = configs.get_reduced("xlstm-125m")
    data = SyntheticLM(cfg, batch=1, seq_len=8)
    direct = [data.batch_at(i)["tokens"] for i in range(5)]
    pre = Prefetcher(data.iterate(), depth=3)
    got = [next(pre)["tokens"] for _ in range(5)]
    pre.close()
    for d, g in zip(direct, got):
        np.testing.assert_array_equal(d, g)


def test_zero_bridge_roundtrip_local():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(37, 11)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
    store = zero_bridge.create_store(tree, mesh=None, page_elems=32)
    got = zero_bridge.pull_tree(store, mesh=None)
    np.testing.assert_allclose(got["w"], tree["w"], atol=1e-6)
    np.testing.assert_allclose(got["b"], tree["b"], atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), rows=st.integers(1, 40),
       page=st.sampled_from([16, 64, 256]))
def test_tree_packer_property(seed, rows, page):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=(rows, 7)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(3, 3)).astype(np.float32))}
    packer = zero_bridge.TreePacker.plan(tree, page)
    pages = packer.pack(tree)
    assert pages.shape == (packer.num_pages, page)
    back = packer.unpack(pages)
    np.testing.assert_allclose(back["a"], tree["a"], atol=1e-7)
    np.testing.assert_allclose(back["b"], tree["b"], atol=1e-7)
