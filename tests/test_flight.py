"""Flight recorder: journaling, durability, deterministic replay, sentinel.

The decision-plane acceptance contract:

* every control-plane action journals a typed ``DecisionRecord`` whose
  JSONL export round-trips losslessly and whose seal makes truncation or
  corruption a typed load-time error, never a silent prefix replay,
* ``replay()`` re-executes a journal against a fresh pool and asserts
  the resulting route programs, placements, channel picks, migration
  plans and window schedules are **bit-identical** — property-tested
  over random op interleavings on random ragged fabrics (including the
  RNG-dependent ``hashed`` policy, which rides the journaled generator
  state),
* a full orchestrated serve run (admission + leases + refits +
  migrations on the 8-ring, under a ``ManualClock``) replays end to end,
* ``why(request_id)`` reconstructs the causal chain admission ->
  lease -> placement -> governing route program,
* the ``Sentinel`` flags an injected 2x latency regression within one
  detection window, raises exactly one alert per excursion
  (hysteresis), triggers an RLS covariance reset on calibration drift,
  and stays silent on conserved telemetry.
"""
import json
import zipfile

import numpy as np
import pytest

from topologies import random_fabric

from repro.core import perfmodel, ref, steering
from repro.core.control_plane import ControlPlane
from repro.core.memport import MemPortTable
from repro.core.topology import Topology
from repro.obs import (Alert, FlightRecorder, JournalTruncatedError,
                       ManualClock, MetricsRegistry, ReplayDivergenceError,
                       Sentinel, SLOMonitor, replay)
from repro.obs.flight import placement_digest, program_digest
from repro.orchestrator import Orchestrator, TenantSpec
from repro.telemetry.counters import DEFAULT_MAX_TENANTS

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                        # pragma: no cover
    from hypofallback import given, settings, st


# ---------------------------------------------------------------------------
# Journal durability
# ---------------------------------------------------------------------------

def _scripted_plane():
    """A plane driven through every journaled op kind."""
    fr = FlightRecorder(clock=ManualClock())
    cp = ControlPlane(8, 4, 64, seed=3)
    cp.attach_flight(fr)
    r1 = cp.allocate(6, "a", policy="striped")
    cp.allocate(5, "b", policy="hashed")
    cp.route_program()
    cp.select_channels(8, 1 << 18)
    cp.release(r1)
    cp.fail_node(2)
    cp.revive_node(2)
    cp.report_link_failure(1)
    cp.route_program()
    cp.clear_link_failure()
    tm = np.ones((8, 8)) * 0.01 + np.eye(8)
    tm[3, 5] = 40.0                 # node 3 dominates home 5: forces moves
    cp.affinity_migration(tm, min_share=0.5)
    return cp, fr


def test_journal_jsonl_roundtrip():
    cp, fr = _scripted_plane()
    text = fr.to_jsonl()
    fr2 = FlightRecorder.from_jsonl(text)
    assert len(fr2) == len(fr)
    for a, b in zip(fr.records(), fr2.records()):
        assert a.to_json() == b.to_json()
    # and the round-trip is a fixpoint
    assert fr2.to_jsonl() == text


def test_journal_write_load(tmp_path):
    cp, fr = _scripted_plane()
    p = tmp_path / "journal.jsonl"
    fr.write(str(p))
    fr2 = FlightRecorder.load(str(p))
    assert fr2.to_jsonl() == fr.to_jsonl()
    # replay straight from the path
    res = replay(str(p))
    assert res.placement_digest == placement_digest(cp)


@pytest.mark.parametrize("mangle", [
    "drop_seal", "cut_tail", "corrupt_line", "after_seal",
    "count_lie", "seq_gap",
])
def test_truncated_or_corrupt_journal_is_typed_error(mangle):
    _, fr = _scripted_plane()
    lines = fr.to_jsonl().splitlines()
    if mangle == "drop_seal":
        lines = lines[:-1]
    elif mangle == "cut_tail":
        lines = lines[: len(lines) // 2]
    elif mangle == "corrupt_line":
        lines[3] = lines[3][: len(lines[3]) // 2]
    elif mangle == "after_seal":
        lines = lines + [lines[1]]
    elif mangle == "count_lie":
        seal = json.loads(lines[-1])
        seal["count"] += 1
        lines[-1] = json.dumps(seal)
    elif mangle == "seq_gap":
        del lines[4]
        seal = json.loads(lines[-1])
        seal["count"] -= 1
        lines[-1] = json.dumps(seal)
    with pytest.raises(JournalTruncatedError):
        FlightRecorder.from_jsonl("\n".join(lines) + "\n")


def test_bounded_journal_drops_oldest_and_refuses_replay():
    fr = FlightRecorder(clock=ManualClock(), capacity=4)
    cp = ControlPlane(4, 4, 16, seed=0)
    cp.attach_flight(fr)
    for _ in range(6):
        cp.route_program(verify=False)
    assert len(fr) == 4 and fr.dropped_total > 0
    # the genesis cp_init fell off the ring: replay must refuse, not
    # silently replay a suffix against a wrong initial state
    with pytest.raises(JournalTruncatedError):
        replay(FlightRecorder.from_jsonl(fr.to_jsonl()))


# ---------------------------------------------------------------------------
# Deterministic replay
# ---------------------------------------------------------------------------

def test_scripted_replay_is_bit_identical():
    cp, fr = _scripted_plane()
    res = replay(FlightRecorder.from_jsonl(fr.to_jsonl()))
    assert res.placement_digest == placement_digest(cp)
    assert res.programs == 2 and res.placements == 2
    assert res.releases == 1 and res.failures == 1
    assert res.channel_picks == 1 and res.migrations == 1
    # the replayed plane *is* the recorded plane, table for table
    assert np.array_equal(res.plane._home, cp._home)
    assert np.array_equal(res.plane._slot, cp._slot)


def test_replay_detects_divergence():
    _, fr = _scripted_plane()
    recs = fr.records()
    for r in recs:
        if r.kind == "route_program":
            r.detail["digest"] = "0" * 16
            break
    with pytest.raises(ReplayDivergenceError, match="program digest"):
        replay(recs)


def test_replay_detects_placement_divergence():
    _, fr = _scripted_plane()
    recs = fr.records()
    for r in recs:
        if r.kind == "allocate":
            r.detail["homes"] = [h + 1 for h in r.detail["homes"]]
            break
    with pytest.raises(ReplayDivergenceError, match="homes"):
        replay(recs)


def test_attach_late_journal_replays_from_snapshot():
    """A recorder attached mid-life snapshots live state in its genesis."""
    cp = ControlPlane(6, 4, 32, seed=9)
    keep = cp.allocate(5, "pre", policy="hashed")   # before attach
    cp.fail_node(4)
    fr = FlightRecorder(clock=ManualClock())
    cp.attach_flight(fr)
    cp.allocate(4, "post", policy="hashed")
    cp.release(keep)                                # handle from pre-attach
    cp.route_program()
    res = replay(FlightRecorder.from_jsonl(fr.to_jsonl()))
    assert res.placement_digest == placement_digest(cp)


_OP_NAMES = ("alloc", "release", "fail", "revive", "route", "channels",
             "migrate")
# (op, arg) pairs packed into one int — the fallback shim has no tuples()
_OPS = st.lists(st.integers(0, 7 * 10 ** 6), min_size=4, max_size=24)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), packed=_OPS)
def test_replay_property_random_ops_on_random_fabrics(seed, packed):
    """Journal -> JSONL -> load -> replay is bit-identical for random op
    interleavings on random ragged fabrics (hashed policy included: the
    journaled RNG state makes it deterministic)."""
    rng = np.random.default_rng(seed)
    topo = random_fabric(rng)
    n = topo.num_nodes
    cp = ControlPlane(n, 4, n * 4, seed=seed, topology=topo)
    fr = FlightRecorder(clock=ManualClock())
    cp.attach_flight(fr)
    regions, dead = [], set()
    for v in packed:
        op, arg = _OP_NAMES[v % len(_OP_NAMES)], v // len(_OP_NAMES)
        try:
            if op == "alloc":
                regions.append(cp.allocate(
                    1 + arg % (2 * n),
                    policy=("striped", "hashed")[arg % 2]))
            elif op == "release" and regions:
                cp.release(regions.pop(arg % len(regions)))
            elif op == "fail" and len(dead) < n - 1:
                node = arg % n
                if node not in dead:
                    cp.fail_node(node)
                    dead.add(node)
            elif op == "revive" and dead:
                node = sorted(dead)[arg % len(dead)]
                cp.revive_node(node)
                dead.discard(node)
            elif op == "route":
                cp.route_program(bidirectional=bool(arg % 2))
            elif op == "channels":
                cp.select_channels(4 + arg % 8, 1 << (12 + arg % 8))
            elif op == "migrate":
                tm = rng.random((n, n)) * 0.1
                tm[arg % n, (arg // n) % n] = 30.0
                cp.affinity_migration(tm, min_share=0.4)
        except RuntimeError:
            pass                       # pool exhausted: a fine interleaving
    res = replay(FlightRecorder.from_jsonl(fr.to_jsonl()))
    assert res.placement_digest == placement_digest(cp)
    assert np.array_equal(res.plane._home, cp._home)


# ---------------------------------------------------------------------------
# Orchestrated end-to-end replay + causality
# ---------------------------------------------------------------------------

def _oracle_step_telemetry(cp, orc, rng, lane_hot=False):
    """One step's raw BridgeTelemetry against the orchestrator's table."""
    n = cp.num_nodes
    want = rng.integers(-1, cp.num_logical, size=(n, orc.budget)
                        ).astype(np.int32)
    lane = rng.integers(1, 3, size=(n, orc.budget)).astype(np.int32)
    if lane_hot:            # node 0 hammers logical pages homed on node 3
        homed = np.flatnonzero(np.asarray(cp._home) == 3)[: orc.budget]
        want[0, : len(homed)] = homed.astype(np.int32)
    return ref.expected_transfer_telemetry(
        want, cp.table(), orc.route_program(), num_nodes=n,
        budget=orc.budget, tenant_ids=lane,
        max_tenants=DEFAULT_MAX_TENANTS)


def _orchestrated_run():
    clock = ManualClock()
    fr = FlightRecorder(clock=clock)
    cp = ControlPlane(8, 8, 128, seed=11)
    orc = Orchestrator(cp, budget=8, page_bytes=1 << 16, control_period=2,
                       migrate=True, migration_limit=4, flight=fr)
    orc.register(TenantSpec(1, "chat", qos="interactive", share=3.0,
                            page_quota=48))
    orc.register(TenantSpec(2, "crawl", qos="batch", share=1.0,
                            page_quota=48))
    rng = np.random.default_rng(5)
    leases = []
    for i in range(8):
        dec, lease = orc.request_lease(1 + i % 2, 4 + i % 3,
                                       request_id=100 + i)
        if lease is not None:
            leases.append(lease)
        telem = _oracle_step_telemetry(cp, orc, rng, lane_hot=i >= 4)
        base = perfmodel.predict_round_latency_us(
            orc.route_program(), orc.page_bytes, orc.budget)
        orc.step(telemetry=telem, measured_round_us=base * (1 + 0.01 * i))
    for lease in leases[:2]:
        orc.release_lease(lease)
    orc.step()
    return orc, fr


def test_orchestrated_serve_replay_bit_identical():
    orc, fr = _orchestrated_run()
    journal = FlightRecorder.from_jsonl(fr.to_jsonl())
    res = replay(journal)
    # every compiled program, placement, pick and refit re-verified
    assert res.programs >= 3            # init + per-control-period refits
    assert res.placements >= 6 and res.releases >= 2
    assert res.channel_picks >= 3 and res.refits >= 4
    assert res.placement_digest == placement_digest(orc.cp)
    # the journaled digest is exactly the live installed program's (read
    # the field directly: the accessor recompiles when a migration left
    # the program stale, which would journal a *new* install)
    digests = [r.detail["digest"] for r in journal.records("route_program")]
    assert digests[-1] == program_digest(orc._program)


def test_orchestrated_replay_catches_tampering():
    orc, fr = _orchestrated_run()
    recs = FlightRecorder.from_jsonl(fr.to_jsonl()).records()
    picks = [r for r in recs if r.kind == "select_channels"]
    picks[-1].detail["pick"] = picks[-1].detail["pick"] + 1
    with pytest.raises(ReplayDivergenceError, match="channel pick"):
        replay(recs)


def test_why_reconstructs_request_causal_chain():
    orc, fr = _orchestrated_run()
    chain = fr.why(100)
    kinds = [r.kind for r in chain]
    assert "admission" in kinds and "lease_grant" in kinds
    assert "allocate" in kinds          # the placement behind the lease
    assert kinds[0] == "route_program"  # the program governing admission
    # seq-ordered, and every directly-stamped record carries the id
    assert [r.seq for r in chain] == sorted(r.seq for r in chain)
    assert all(r.request_id == 100 for r in chain
               if r.kind in ("admission", "lease_grant"))
    grant = next(r for r in chain if r.kind == "lease_grant")
    alloc = next(r for r in chain if r.kind == "allocate")
    assert grant.detail["region_id"] == alloc.detail["region_id"]
    assert fr.why(999999) == []


def test_dump_debug_bundle_contents_replayable(tmp_path):
    orc, _ = _orchestrated_run()
    path = str(tmp_path / "bundle.zip")
    assert orc.dump_debug_bundle(path) == path
    with zipfile.ZipFile(path) as z:
        names = set(z.namelist())
        assert {"journal.jsonl", "metrics.txt", "describe.txt"} <= names
        journal = z.read("journal.jsonl").decode()
        assert "obs_" in z.read("metrics.txt").decode()
        assert "orchestrator" in z.read("describe.txt").decode()
    res = replay(FlightRecorder.from_jsonl(journal))
    assert res.placement_digest == placement_digest(orc.cp)


# ---------------------------------------------------------------------------
# Sentinel
# ---------------------------------------------------------------------------

def test_sentinel_flags_injected_regression_within_one_window():
    reg = MetricsRegistry()
    s = Sentinel(registry=reg, window=8)
    for _ in range(20):                       # healthy warm-up
        s.observe_latency(100.0, predicted_us=100.0)
    assert s.alerts == []
    onset = None
    for i in range(8):                        # inject a 2x regression
        if s.observe_latency(200.0, predicted_us=100.0):
            onset = i + 1
            break
    assert onset is not None and onset <= s.window
    assert s.alerts[0].kind == "latency_shift"
    snap = reg.snapshot()["counters"]
    assert snap['obs_alerts_total{kind="latency_shift"}'] == 1


def test_sentinel_latency_hysteresis_one_alert_per_excursion():
    s = Sentinel(window=4)
    for _ in range(4):
        s.observe_latency(100.0, predicted_us=100.0)
    for _ in range(12):                       # sustained anomaly: one alert
        s.observe_latency(200.0, predicted_us=100.0)
    assert len(s.alerts) == 1
    for _ in range(12):                       # recovery clears the alarm
        s.observe_latency(100.0, predicted_us=100.0)
    assert not s.describe()["shift_alarm"]
    for _ in range(12):                       # relapse: second alert
        s.observe_latency(200.0, predicted_us=100.0)
    assert len(s.alerts) == 2


def test_sentinel_clean_run_raises_no_alerts():
    s = Sentinel(window=6)
    rng = np.random.default_rng(0)
    for _ in range(100):                      # ±2% noise around the model
        m = 100.0 * (1.0 + 0.02 * rng.standard_normal())
        s.observe_latency(m, predicted_us=100.0, residual_us=abs(m - 100.0))
    assert s.alerts == []


def test_sentinel_drift_resets_calibrator_and_journals():
    cal = perfmodel.Calibrator()
    p_before = cal._P.copy()
    fr = FlightRecorder(clock=ManualClock())
    s = Sentinel(flight=fr, calibrator=cal, window=4, drift_floor_us=10.0)
    for _ in range(8):                        # healthy baseline ~1us
        s.observe_latency(100.0, residual_us=1.0)
    for _ in range(8):                        # residuals blow up
        s.observe_latency(100.0, residual_us=500.0)
    kinds = {a.kind for a in s.alerts}
    assert "calibration_drift" in kinds
    assert [r.kind for r in fr.records("calibrator_refit")]
    # covariance re-opened: the RLS gain is large again
    assert np.all(np.diag(cal._P) >= np.diag(p_before))


def test_sentinel_slo_burn_hysteresis():
    reg = MetricsRegistry()
    slo = SLOMonitor(window=10, budget_fraction=0.1, registry=reg)
    s = Sentinel(registry=reg, slo=slo, min_slo_samples=8)
    for _ in range(10):
        slo.record(3, latency_us=50.0, slo_us=100.0)
    assert s.check_slo() == []                # healthy tenant
    for _ in range(5):
        slo.record(3, latency_us=500.0, slo_us=100.0)
    assert [a.kind for a in s.check_slo()] == ["slo_burn"]
    assert s.check_slo() == []                # alarmed: no repeat alert
    for _ in range(10):
        slo.record(3, latency_us=50.0, slo_us=100.0)
    s.check_slo()                             # burn fell: alarm clears
    assert 3 not in s.describe()["burn_alarms"]


def test_sentinel_conservation_clean_on_real_telemetry():
    from repro.telemetry import TelemetryAggregator
    n, budget = 8, 4
    rng = np.random.default_rng(2)
    table = MemPortTable.striped(64, n, 8)
    prog = steering.bidirectional_program(n)
    agg = TelemetryAggregator(n, max_tenants=DEFAULT_MAX_TENANTS)
    s = Sentinel(window=4)
    for _ in range(6):
        want = rng.integers(-1, 64, size=(n, budget)).astype(np.int32)
        telem = ref.expected_transfer_telemetry(
            want, table, prog, num_nodes=n, budget=budget)
        agg.update(telem)
        assert s.check_telemetry(agg) == []


def test_sentinel_conservation_catches_tampered_counters():
    from repro.telemetry import TelemetryAggregator
    n = 4
    table = MemPortTable.striped(16, n, 4)
    prog = steering.bidirectional_program(n)
    want = np.arange(n * 2, dtype=np.int32).reshape(n, 2) % 16
    agg = TelemetryAggregator(n, max_tenants=DEFAULT_MAX_TENANTS)
    agg.update(ref.expected_transfer_telemetry(
        want, table, prog, num_nodes=n, budget=2))
    s = Sentinel(window=4)
    assert s.check_telemetry(agg) == []
    agg.served = agg.served + 5.0             # break the accounting
    alerts = s.check_telemetry(agg)
    assert alerts and alerts[0].kind == "conservation"
    agg2 = TelemetryAggregator(n, max_tenants=DEFAULT_MAX_TENANTS)
    agg2.served = agg2.served * np.nan        # non-finite counters
    a2 = Sentinel(window=4).check_telemetry(agg2)
    assert a2 and a2[0].kind == "conservation"


def test_alert_is_frozen_value_type():
    a = Alert("k", "warn", "m", 1.0, 2.0)
    with pytest.raises(AttributeError):
        a.value = 3.0
