"""bridgelint unit suite: lint rules, program verifier, jaxpr/HLO audit.

Negative fixtures live here as source snippets / corrupted programs —
the shipped tree itself must lint clean (asserted below and gated by the
CI lint job), so the rule demonstrations cannot ride on real files.
"""
import dataclasses
import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from repro.analysis import (Finding, ProgramVerificationError,  # noqa: E402
                            check_program, check_transfer_window, coverage,
                            errors)
from repro.analysis import hlo as ahlo  # noqa: E402
from repro.analysis import jaxpr_audit as ja  # noqa: E402
from repro.analysis.lint import lint_paths, lint_source  # noqa: E402
from repro.core import steering  # noqa: E402
from repro.core.control_plane import ControlPlane  # noqa: E402
from repro.core.topology import Topology  # noqa: E402

pytestmark = pytest.mark.analysis

REPO = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# AST lint: every rule fires on its fixture, and only there
# ---------------------------------------------------------------------------

LINT_FIXTURES = [
    ("BL201", "import jax.numpy as jnp\n"
              "def f(x):\n"
              "    return int(jnp.sum(x))\n"),
    ("BL201", "import jax.numpy as jnp\n"
              "def f(x):\n"
              "    return jnp.max(x).item()\n"),
    ("BL202", "import jax.numpy as jnp\n"
              "def f(x):\n"
              "    if jnp.any(x > 0):\n"
              "        return x\n"
              "    return -x\n"),
    ("BL202", "import jax.numpy as jnp\n"
              "def f(x):\n"
              "    return 1 if jnp.sum(x) > 0 else 2\n"),
    ("BL203", "import jax.numpy as jnp\n"
              "def f(vals):\n"
              "    return jnp.asarray([v * 2 for v in vals])\n"),
    ("BL203", "import jax.numpy as jnp\n"
              "def f(a, b):\n"
              "    return jnp.array([a, b, 0])\n"),
    ("BL204", "import jax\n"
              "def step(x, n):\n"
              "    for _ in range(n):\n"
              "        x = x * 2\n"
              "    return x\n"
              "fast = jax.jit(step)\n"),
    ("BL204", "import jax\n"
              "@jax.jit\n"
              "def step(x, depth):\n"
              "    for _ in range(depth):\n"
              "        x = x + 1\n"
              "    return x\n"),
    ("BL205", "def poke(table, homes):\n"
              "    object.__setattr__(table, 'home', homes)\n"),
    ("BL206", "def admit(batcher, seq):\n"
              "    batcher.slots[0] = seq\n"),
    ("BL206", "def drain(batcher):\n"
              "    batcher.queues.clear()\n"),
    ("BL207", "import time\n"
              "def f():\n"
              "    return time.monotonic()\n"),
    ("BL207", "import time\n"
              "def stamp():\n"
              "    return time.time_ns()\n"),
]


@pytest.mark.parametrize("rule,src", LINT_FIXTURES,
                         ids=[f"{r}-{i}" for i, (r, _)
                              in enumerate(LINT_FIXTURES)])
def test_lint_rule_fires(rule, src):
    found = lint_source(src, path="fixture.py")
    assert rule in rules_of(found), f"{rule} not raised: {found}"


CLEAN_SNIPPETS = [
    # host-static backend dispatch (bridge.py does exactly this)
    "import jax\n"
    "def pick():\n"
    "    if jax.default_backend() == 'tpu':\n"
    "        return 'a2a'\n"
    "    return 'ladder'\n",
    # stacking traced values is not a fresh constant
    "import jax.numpy as jnp\n"
    "from jax import lax\n"
    "def f(x):\n"
    "    return jnp.stack([lax.ppermute(x, 'mem', [(0, 1)]), x])\n",
    # constant-only literals are hoisted by jax's constant cache
    "import jax.numpy as jnp\n"
    "W = jnp.asarray([1, 2, 3])\n",
    # static shape reads are host data
    "import jax.numpy as jnp\n"
    "def f(x):\n"
    "    return int(jnp.zeros((4,)).shape[0])\n",
    # numpy conversions of fenced results are the sanctioned pattern
    "import numpy as np\n"
    "def f(out):\n"
    "    return int(np.asarray(out).sum())\n",
    # frozen-dataclass construction may use object.__setattr__
    "class T:\n"
    "    def __post_init__(self):\n"
    "        object.__setattr__(self, 'x', 1)\n",
    # the batcher mutating its own state is the tick discipline
    "class B:\n"
    "    def _admit(self, seq):\n"
    "        self.slots[0] = seq\n"
    "        self.queues.clear()\n",
]


@pytest.mark.parametrize("src", CLEAN_SNIPPETS,
                         ids=[f"clean-{i}" for i in range(len(CLEAN_SNIPPETS))])
def test_lint_clean_snippets(src):
    assert lint_source(src, path="clean.py") == []


def test_lint_suppression_comment():
    src = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return int(jnp.sum(x))  # bridgelint: ignore[BL201]\n")
    assert lint_source(src) == []
    # previous-line form
    src2 = ("import jax.numpy as jnp\n"
            "def f(x):\n"
            "    # bridgelint: ignore\n"
            "    return int(jnp.sum(x))\n")
    assert lint_source(src2) == []
    # a different rule id does not suppress
    src3 = ("import jax.numpy as jnp\n"
            "def f(x):\n"
            "    return int(jnp.sum(x))  # bridgelint: ignore[BL203]\n")
    assert rules_of(lint_source(src3)) == {"BL201"}


def test_lint_syntax_error_is_finding():
    assert rules_of(lint_source("def f(:\n")) == {"BL200"}


def test_raw_clock_rule_exempts_clock_module_and_suppresses():
    src = ("import time\n"
           "def now_us():\n"
           "    return time.perf_counter() * 1e6\n")
    # anywhere else in the tree: flagged
    assert rules_of(lint_source(src, path="src/repro/serve/loop.py")) == \
        {"BL207"}
    # the one sanctioned implementation site is exempt (both separators)
    assert lint_source(src, path="src/repro/obs/clock.py") == []
    assert lint_source(src, path="src\\repro\\obs\\clock.py") == []
    # and the standard suppression comment works
    supp = ("import time\n"
            "def f():\n"
            "    return time.monotonic()  # bridgelint: ignore[BL207]\n")
    assert lint_source(supp, path="fixture.py") == []


def test_shipped_tree_lints_clean():
    """The acceptance bar the CI job enforces, asserted in-tree."""
    assert errors(lint_paths([SRC])) == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _run_cli(args, cwd=REPO):
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    return subprocess.run([sys.executable, "-m", "repro.analysis"] + args,
                          cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_exits_zero_on_shipped_tree():
    r = _run_cli(["--no-programs", "src/"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_fails_on_seeded_fixtures(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "import jax\n"
        "import jax.numpy as jnp\n"
        "def f(x, n):\n"
        "    if jnp.any(x > 0):\n"
        "        x = jnp.asarray([v for v in range(10)]) + int(jnp.sum(x))\n"
        "    for _ in range(n):\n"
        "        x = x + 1\n"
        "    return x\n"
        "g = jax.jit(f)\n")
    report = tmp_path / "report.json"
    r = _run_cli(["--no-programs", "--fix-report", str(report), str(bad)])
    assert r.returncode == 1, r.stdout + r.stderr
    rep = json.loads(report.read_text())
    got = {f["rule"] for f in rep["findings"]}
    # >= 3 distinct rule ids demonstrated on the seeded negative fixture
    assert {"BL201", "BL202", "BL203", "BL204"} <= got
    assert rep["errors"] == len(rep["findings"])


def test_cli_program_self_check_passes():
    r = _run_cli([str(SRC / "repro" / "analysis")])  # tiny lint + programs
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 error(s)" in r.stdout


# ---------------------------------------------------------------------------
# Program verifier
# ---------------------------------------------------------------------------

def _mut(prog, **arrays):
    """dataclasses.replace with jnp-cast arrays."""
    cast = {}
    for k, v in arrays.items():
        ref_dtype = np.asarray(getattr(prog, k)).dtype
        cast[k] = jnp.asarray(np.asarray(v).astype(ref_dtype))
    return dataclasses.replace(prog, **cast)


def test_check_program_clean_on_shipped_variants():
    n = 8
    for prog in (steering.unidirectional_program(n),
                 steering.unidirectional_program(n, direction=-1),
                 steering.bidirectional_program(n),
                 steering.link_avoiding_program(n, +1),
                 steering.pruned_program(steering.bidirectional_program(n),
                                         [1, 3, 5]),
                 steering.load_balanced_program(n, [0, 5, 0, 2, 9, 0, 1])):
        assert check_program(prog) == []
    topo = Topology.from_sizes([3, 5])
    hier = steering.hierarchical_program(topo)
    assert check_program(hier, topo) == []


def test_pc101_rank_epoch_shape():
    p = steering.bidirectional_program(8)
    bad = dataclasses.replace(p, rank_epoch=jnp.zeros((7, 3), jnp.int32))
    assert rules_of(check_program(bad)) == {"PC101"}


def test_pc102_offset_incongruent():
    p = steering.bidirectional_program(8)
    off = np.asarray(p.offsets).copy()
    off[2] = 5  # slot 2 serves distance 3; 5 % 8 == 5
    assert "PC102" in rules_of(check_program(_mut(p, offsets=off)))


def test_pc103_offset_out_of_range():
    p = steering.bidirectional_program(8)
    off = np.asarray(p.offsets).copy()
    off[1] = 0
    off[4] = 13
    found = check_program(_mut(p, offsets=off))
    assert "PC103" in rules_of(found)
    assert sum(f.rule == "PC103" for f in found) == 2


def test_pc104_dead_slot_residue():
    p = steering.bidirectional_program(8)
    live = np.asarray(p.live).copy()
    live[3] = False  # offsets/epoch/rank_epoch untouched: residue
    assert "PC104" in rules_of(check_program(_mut(p, live=live)))


def test_pc105_idle_live_slot():
    p = steering.bidirectional_program(8)
    re = np.asarray(p.rank_epoch).copy()
    re[3, :] = -1  # still live, serves nobody
    assert "PC105" in rules_of(check_program(_mut(p, rank_epoch=re)))


def test_pc106_epoch_mismatch():
    p = steering.bidirectional_program(8)
    ep = np.asarray(p.epoch).copy()
    ep[2] += 1
    assert "PC106" in rules_of(check_program(_mut(p, epoch=ep)))


def test_pc107_epoch_beyond_telemetry_bins():
    p = steering.bidirectional_program(8)
    re = np.asarray(p.rank_epoch).copy()
    re[2, :] = 14  # num_epoch_bins(8) == 14: one past the last bin
    ep = np.asarray(p.epoch).copy()
    ep[2] = 14
    found = check_program(_mut(p, rank_epoch=re, epoch=ep))
    assert "PC107" in rules_of(found)
    # the oracle's epoch histograms agree this is out of range
    from repro.telemetry.counters import num_epoch_bins
    assert num_epoch_bins(8) == 14


def test_pc108_gateway_contention():
    topo = Topology.from_sizes([4, 4])
    p = steering.hierarchical_program(topo)
    re = np.asarray(p.rank_epoch).copy()
    # collapse every board-crossing pairing onto one epoch: gateways contend
    inter = ~np.asarray([[topo.pair_intra(r, (r + k + 1) % 8)
                          for r in range(8)] for k in range(7)])
    gw = re[inter].max()
    re2 = np.where(inter & (re >= 0), gw, re)
    ep = np.where(np.asarray(p.live),
                  np.where(re2 >= 0, re2, 10**6).min(1), -1)
    found = check_program(_mut(p, rank_epoch=re2, epoch=ep), topo)
    assert "PC108" in rules_of(found)


def test_pc109_ring_link_contention():
    p = steering.unidirectional_program(8)  # all clockwise, epochs 0..6
    ep = np.asarray(p.epoch).copy()
    re = np.asarray(p.rank_epoch).copy()
    ep[1] = ep[0]
    re[1, :] = re[0, 0]  # two cw circuits on one epoch: shared links
    found = check_program(_mut(p, epoch=ep, rank_epoch=re))
    assert "PC109" in rules_of(found)


def test_pc110_coverage_gap():
    p = steering.pruned_program(steering.bidirectional_program(8), [1, 2])
    req = np.ones((7, 8), bool)  # require full coverage
    found = check_program(p, required_pairs=req)
    assert "PC110" in rules_of(found)
    # the static coverage map marks exactly the pruned slots
    cov = coverage(p)
    assert cov[:2].all() and not cov[2:].any()


def test_pc111_transfer_window():
    assert rules_of(check_transfer_window(10, 0)) == {"PC111"}
    assert "PC111" in rules_of(check_transfer_window(10, 4, active_budget=9))
    assert "PC111" in rules_of(check_transfer_window(10, 4, active_budget=-1))
    assert check_transfer_window(10, 4) == []
    # guaranteed-spill window: reported as a warning, not a gate
    w = check_transfer_window(100, 4, active_budget=1)
    assert w and all(f.severity == "warning" for f in w)
    assert errors(w) == []


# ---------------------------------------------------------------------------
# route_program: fail loudly on corrupt installs (regression)
# ---------------------------------------------------------------------------

def _plane(n=8, topo=None):
    cp = ControlPlane(num_nodes=n, pages_per_node=16, num_logical=2 * n,
                      topology=topo)
    cp.allocate(2 * n)
    return cp


def test_route_program_rejects_corrupted_install():
    cp = _plane()
    good = cp.route_program()
    live = np.asarray(good.live) & (np.arange(7) != 2)
    bad = _mut(good, live=live)  # rank_epoch still wires slot 2: inconsistent
    with pytest.raises(ProgramVerificationError) as ei:
        cp.route_program(program=bad)
    assert ei.value.findings, "error must carry the structured finding list"
    assert all(isinstance(f, Finding) for f in ei.value.findings)
    assert "PC104" in rules_of(ei.value.findings)


def test_route_program_verify_off_installs_unchecked():
    cp = _plane()
    good = cp.route_program()
    bad = _mut(good, live=np.asarray(good.live) & (np.arange(7) != 2))
    assert cp.route_program(program=bad, verify=False) is bad


def test_route_program_accepts_all_shipped_variants():
    cp = _plane()
    n = 8
    flat = [steering.unidirectional_program(n),
            steering.bidirectional_program(n),
            steering.pruned_program(steering.bidirectional_program(n), [1, 2]),
            steering.load_balanced_program(n, [1, 0, 2, 0, 3, 0, 4]),
            steering.link_avoiding_program(n, -1)]
    for prog in flat:
        assert cp.route_program(program=prog) is prog
    topo = Topology.from_sizes([4, 4])
    cph = _plane(topo=topo)
    hier = steering.hierarchical_program(topo)
    assert cph.route_program(program=hier) is hier
    masked = steering.masked_ranks_program(
        hier, np.broadcast_to(np.arange(8)[None, :] % 2 == 0, (7, 8)))
    assert cph.route_program(program=masked) is masked


def test_route_program_compiled_paths_verify_clean():
    """Every compile branch runs under verify=True by default."""
    _plane().route_program()
    _plane().route_program(bidirectional=False)
    _plane().route_program(prune=False)
    _plane(topo=Topology.from_sizes([2, 3, 3])).route_program()
    cp = _plane()
    cp.route_program(telemetry=np.asarray([4.0, 0, 1, 0, 2, 0, 0]))


# ---------------------------------------------------------------------------
# jaxpr / HLO audit
# ---------------------------------------------------------------------------

def test_audit_clean_fn():
    def f(x):
        return jnp.tanh(x) @ x

    assert ja.audit_fn(f, jnp.ones((4, 4))) == []


def test_audit_flags_pure_callback():
    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)
        return y + 1

    found = ja.audit_fn(f, jnp.ones((4,), jnp.float32))
    assert "JA301" in rules_of(found)


def test_audit_flags_debug_print_in_scan_body():
    def f(x):
        def body(c, _):
            jax.debug.print("c={c}", c=c.sum())
            return c * 2, None
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    found = ja.audit_fn(f, jnp.ones((4,)))
    assert "JA301" in rules_of(found)  # found inside the scan body jaxpr


def test_audit_hlo_flags_callback_custom_call():
    def f(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)
        return y + 1

    text = jax.jit(f).lower(jnp.ones((4,), jnp.float32)).compile().as_text()
    assert "JA301" in rules_of(ja.audit_hlo_text(text))
    clean = jax.jit(lambda x: x * 2).lower(
        jnp.ones((4,), jnp.float32)).compile().as_text()
    assert ja.audit_hlo_text(clean) == []


def test_datapath_loopback_is_pure():
    """pull_pages / push_pages trace with no host callbacks, no dynamic
    shapes — the datapath-purity contract, checked on the 1-node path."""
    from repro.core import bridge
    from repro.core.memport import MemPortTable
    from topologies import make_pool

    pool = make_pool(16, 8)
    table = MemPortTable.striped(12, 1, 16)
    want = jnp.asarray([[3, 0, 7, -1, 11, 2]], jnp.int32)

    def pull(pool, want):
        return bridge.pull_pages(pool, want, table, mesh=None, budget=4)

    assert ja.audit_fn(pull, pool, want, where="pull_pages") == []

    payload = jnp.ones((1, 4, 8), jnp.float32)
    dest = jnp.asarray([[5, 1, -1, 9]], jnp.int32)

    def push(pool, dest, payload):
        return bridge.push_pages(pool, dest, payload, table, mesh=None,
                                 budget=2)

    assert ja.audit_fn(push, pool, dest, payload, where="push_pages") == []


def test_audit_retrace_on_program_swap():
    """Swapping route programs on a jitted consumer must not retrace."""
    @jax.jit
    def consume(x, program):
        return x + program.offsets.sum() + program.rank_epoch.sum()

    x = jnp.ones((4,))
    progs = [steering.bidirectional_program(8),
             steering.unidirectional_program(8),
             steering.pruned_program(steering.bidirectional_program(8), [1]),
             steering.load_balanced_program(8, [1, 2, 3, 4, 5, 6, 7])]
    found = ja.audit_retrace(consume, [(x, p) for p in progs],
                             where="program-swap")
    assert found == []


def test_audit_retrace_flags_static_leak():
    @jax.jit
    def f(x):
        return x * 2

    argsets = [(jnp.ones((k,)),) for k in (3, 4, 5)]  # shape = static
    found = ja.audit_retrace(f, argsets, where="shape-leak")
    assert rules_of(found) == {"JA304"}


# ---------------------------------------------------------------------------
# Collective budgets vs the recorded BENCH phase breakdown
# ---------------------------------------------------------------------------

def _bench_pb():
    bench = json.loads((REPO / "BENCH_bridge.json").read_text())
    return bench["pipeline"]["phase_breakdown"], bench["num_nodes"]


def test_collective_budget_accepts_recorded_bench():
    pb, n = _bench_pb()
    assert ja.check_collective_budget(pb, n) == []


def test_collective_budget_rejects_blowup():
    pb, n = _bench_pb()
    bad = json.loads(json.dumps(pb))
    bad["unfused"]["4"]["phase_ops"]["wire_req"] = 1000
    assert "JA305" in rules_of(ja.check_collective_budget(bad, n))
    # a fused engine whose wire ops scale with depth is the PR 4 regression
    bad2 = json.loads(json.dumps(pb))
    bad2["fused"]["8"]["phase_ops"]["wire_data"] = \
        bad2["fused"]["1"]["phase_ops"]["wire_data"] + 7
    assert "JA305" in rules_of(ja.check_collective_budget(bad2, n))


def test_wire_op_budget_matches_engine_structure():
    assert ja.wire_op_budget(8, 1, fused=False) == {"wire_req": 7,
                                                    "wire_data": 7}
    assert ja.wire_op_budget(8, 4, fused=False) == {"wire_req": 35,
                                                    "wire_data": 35}
    assert ja.wire_op_budget(8, 8, fused=True) == {"wire_req": 1,
                                                   "wire_data": 7}


# ---------------------------------------------------------------------------
# Shared HLO parser: the benchmark re-imports it, obs delegates to it
# ---------------------------------------------------------------------------

def test_benchmark_reexports_shared_parser():
    from benchmarks import hlo_analysis as H
    assert H.parse_hlo is ahlo.parse_hlo
    assert H.shape_bytes is ahlo.shape_bytes
    assert H.count_ops is ahlo.count_ops


def test_scope_op_counts_matches_obs_phase_counts():
    from repro.obs.trace import phase_op_counts
    text = ('x metadata={op_name="jit(f)/obs:wire_req/pp"}\n'
            'y metadata={op_name="jit(f)/obs_wire_req/pp"}\n'
            'z metadata={op_name="jit(f)/obs:commit/add"}\n')
    assert phase_op_counts(text) == ahlo.scope_op_counts(text, "obs")
    assert phase_op_counts(text) == {"wire_req": 2, "commit": 1}


def test_call_multipliers_counts_scan_trips():
    def f(x, w):
        def body(c, wi):
            return jnp.dot(c, wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    text = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
        jax.ShapeDtypeStruct((5, 16, 16), jnp.float32)).compile().as_text()
    comps = ahlo.parse_hlo(text)
    mult, unknown = ahlo.call_multipliers(comps)
    assert unknown == 0
    assert any(abs(m - 5.0) < 1e-9 for m in mult.values()), mult
