"""Checkpoint manager: roundtrip, atomicity, retention, resume."""
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # minimal environments
    from hypofallback import given, settings, st

from repro.checkpoint import CheckpointManager


def tree_of(seed, shapes=((4, 8), (3,), ())):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=shapes[0]).astype(np.float32)),
        "b": {"c": jnp.asarray(rng.normal(size=shapes[1]).astype(np.float32)),
              "count": jnp.asarray(rng.integers(0, 100), jnp.int32)},
        "d": jnp.asarray(rng.normal(size=shapes[0]).astype(jnp.bfloat16)),
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = tree_of(0)
    mgr.save(10, tree, extra={"step": 10, "note": "x"})
    restored, extra = mgr.restore(tree)
    assert_tree_equal(tree, restored)
    assert extra["step"] == 10


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (5, 10, 15, 20):
        mgr.save(s, tree_of(s))
    assert mgr.latest_step() == 20
    assert mgr.steps() == [15, 20]  # older checkpoints garbage-collected


def test_resume_restores_exact_step(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t1, t2 = tree_of(1), tree_of(2)
    mgr.save(1, t1, extra={"step": 1})
    mgr.save(2, t2, extra={"step": 2})
    r1, _ = mgr.restore(t1, step=1)
    assert_tree_equal(t1, r1)
    r2, _ = mgr.restore(t2)       # latest
    assert_tree_equal(t2, r2)


def test_crash_mid_save_preserves_previous(tmp_path):
    """A leftover .tmp dir must not shadow the committed checkpoint."""
    mgr = CheckpointManager(tmp_path)
    tree = tree_of(3)
    mgr.save(1, tree)
    # simulate a crashed save: partial tmp dir for step 2
    crash = pathlib.Path(tmp_path) / "step_2.tmp"
    crash.mkdir()
    (crash / "manifest.json").write_text("{corrupt")
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(tree)
    assert_tree_equal(tree, restored)
    # a new save of step 2 succeeds despite the leftover tmp
    mgr.save(2, tree)
    assert mgr.latest_step() == 2


def test_missing_leaf_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        mgr.restore({"a": jnp.zeros((2,)), "zz": jnp.zeros((3,))})


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), rows=st.integers(1, 32),
       cols=st.integers(1, 64))
def test_roundtrip_property(tmp_path_factory, seed, rows, cols):
    tmp = tmp_path_factory.mktemp("ck")
    mgr = CheckpointManager(tmp)
    rng = np.random.default_rng(seed)
    tree = {"w": jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32)),
            "i": jnp.asarray(rng.integers(-5, 5, size=(cols,)), jnp.int32)}
    mgr.save(seed, tree)
    restored, _ = mgr.restore(tree, step=seed)
    assert_tree_equal(tree, restored)


# -- async manager ------------------------------------------------------------

def test_async_roundtrip_and_ordering(tmp_path):
    from repro.checkpoint import AsyncCheckpointManager
    mgr = AsyncCheckpointManager(tmp_path, keep=2)
    trees = {s: tree_of(s) for s in (1, 2, 3)}
    for s in (1, 2, 3):
        mgr.save(s, trees[s], extra={"step": s})
    mgr.wait()
    assert mgr.latest_step() == 3
    assert mgr.steps() == [2, 3]  # retention applied in order
    restored, extra = mgr.restore(trees[3])
    assert_tree_equal(trees[3], restored)
    assert extra["step"] == 3


def test_async_snapshot_isolated_from_donation(tmp_path):
    """Mutating (donating) the live state after save() must not corrupt
    the image being written."""
    import jax.numpy as jnp
    from repro.checkpoint import AsyncCheckpointManager
    mgr = AsyncCheckpointManager(tmp_path)
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    mgr.save(5, tree, extra={"step": 5})
    # overwrite the live buffer immediately (simulates donation reuse)
    tree = {"w": tree["w"] * 0 - 1.0}
    mgr.wait()
    restored, _ = mgr.restore({"w": jnp.zeros(8)})
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(8, dtype=np.float32))
