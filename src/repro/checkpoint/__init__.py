from repro.checkpoint.async_manager import AsyncCheckpointManager  # noqa: F401
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
