"""Checkpointing: sharded, compressed, atomic, retention-managed.

Layout:
    <dir>/step_<n>/manifest.json        tree structure + leaf metadata
    <dir>/step_<n>/shard_<h>.bin.zst    zstd-compressed leaf payloads
    <dir>/LATEST                        committed step marker (atomic rename)

Writes go to ``step_<n>.tmp`` and are renamed only after every shard and the
manifest are flushed — a crash mid-save can never corrupt the previous
checkpoint (restart safety for the fault-tolerance story).  On multi-host
deployments each host writes the shards it owns; this container is
single-process so host 0 writes everything.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
from typing import Any, Optional

import zlib

import jax
import numpy as np

try:
    import zstandard
except ImportError:          # pragma: no cover - depends on environment
    zstandard = None         # fall back to stdlib zlib (codec recorded in
                             # the manifest, so either side can read both)

SHARD_LEAVES = 64  # leaves per shard file


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 compression_level: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.level = compression_level

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> str:
        leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
        tmp = self.dir / f"step_{step}.tmp"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        codec = "zstd" if zstandard is not None else "zlib"
        manifest: dict[str, Any] = {"step": step, "extra": extra or {},
                                    "codec": codec, "leaves": []}
        if zstandard is not None:
            cctx = zstandard.ZstdCompressor(level=self.level)
            compress = cctx.compress
        else:
            # zstd accepts levels up to 22; zlib caps at 9.
            compress = lambda b: zlib.compress(b, min(self.level, 9))  # noqa: E731
        shard_id, buf, buf_items = 0, [], []

        def flush():
            nonlocal shard_id, buf, buf_items
            if not buf:
                return
            path = tmp / f"shard_{shard_id}.bin.zst"
            with open(path, "wb") as f:
                f.write(compress(b"".join(buf)))
            offset = 0
            for item, nbytes in buf_items:
                item["shard"] = shard_id
                item["offset"] = offset
                item["nbytes"] = nbytes
                offset += nbytes
                manifest["leaves"].append(item)
            shard_id += 1
            buf, buf_items = [], []

        for path, leaf in leaves:
            arr = np.asarray(leaf)
            raw = arr.tobytes()
            buf.append(raw)
            buf_items.append((
                {"path": jax.tree_util.keystr(path),
                 "dtype": str(arr.dtype), "shape": list(arr.shape)},
                len(raw)))
            if len(buf_items) >= SHARD_LEAVES:
                flush()
        flush()

        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(self.dir / "LATEST.tmp", "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.rename(self.dir / "LATEST.tmp", self.dir / "LATEST")
        self._gc()
        return str(final)

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        marker = self.dir / "LATEST"
        if not marker.exists():
            return None
        return int(marker.read_text().strip())

    def restore(self, target: Any, step: Optional[int] = None) -> tuple[Any, dict]:
        """Restore into the structure of ``target`` (a pytree template)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        base = self.dir / f"step_{step}"
        with open(base / "manifest.json") as f:
            manifest = json.load(f)
        codec = manifest.get("codec", "zstd")
        if codec == "zstd":
            if zstandard is None:
                raise RuntimeError(
                    "checkpoint was written with zstd but zstandard is not "
                    "installed")
            decompress = zstandard.ZstdDecompressor().decompress
        else:
            decompress = zlib.decompress
        shards: dict[int, bytes] = {}

        def shard_bytes(sid: int) -> bytes:
            if sid not in shards:
                with open(base / f"shard_{sid}.bin.zst", "rb") as f:
                    shards[sid] = decompress(f.read())
            return shards[sid]

        by_path = {item["path"]: item for item in manifest["leaves"]}
        leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        out = []
        for path, leaf in leaves:
            key = jax.tree_util.keystr(path)
            item = by_path.get(key)
            if item is None:
                raise KeyError(f"checkpoint missing leaf {key}")
            raw = shard_bytes(item["shard"])[
                item["offset"]: item["offset"] + item["nbytes"]]
            arr = np.frombuffer(raw, dtype=np.dtype(item["dtype"])).reshape(
                item["shape"]).copy()
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), out)
        return tree, manifest["extra"]

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir() and not p.name.endswith(".tmp"))

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)
