"""Asynchronous checkpointing: snapshot on the step path, serialize off it.

At pod scale a synchronous multi-GiB checkpoint stalls every chip for
seconds.  ``AsyncCheckpointManager`` copies the state to host numpy
(cheap, bounded by HBM->host bandwidth) and hands compression + fsync +
rename to a background thread, so the training loop resumes immediately.

Correctness properties (tested in tests/test_checkpoint.py):
  * the snapshot is taken synchronously — a later in-place donation of the
    live state cannot corrupt the image being written;
  * saves are ordered: a newer save never lands before an older one
    (single worker thread, FIFO queue);
  * ``wait()`` drains the queue (call before shutdown / failover);
  * the LATEST marker only moves after a fully-committed directory, so a
    crash mid-async-save preserves the previous checkpoint (inherited from
    the atomic rename in CheckpointManager).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


class AsyncCheckpointManager:
    def __init__(self, directory: str, keep: int = 3, depth: int = 2):
        self._sync = CheckpointManager(directory, keep=keep)
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._errors: list = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- API -------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Snapshot now, write in the background (blocks only if the queue
        is full — backpressure instead of unbounded host memory)."""
        snapshot = jax.tree.map(lambda x: np.array(x), tree)
        self._q.put((step, snapshot, extra))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def restore(self, target: Any, step: Optional[int] = None):
        self.wait()
        return self._sync.restore(target, step=step)

    def latest_step(self) -> Optional[int]:
        return self._sync.latest_step()

    def steps(self):
        return self._sync.steps()

    # -- worker ------------------------------------------------------------
    def _worker(self):
        while True:
            step, snapshot, extra = self._q.get()
            try:
                self._sync.save(step, snapshot, extra=extra)
            except Exception as e:  # surfaced at wait()
                self._errors.append(e)
            finally:
                self._q.task_done()
