"""Weighted-fair, work-conserving partition of the bridge round budget.

The bridge rate-limits every node to ``budget`` pages per round
(``active_budget`` lanes live at runtime).  With several tenants sharing the
pool, *whose* requests fill those lanes is the QoS policy: this module
compiles tenant shares into the two knobs the datapath already consumes —

* a per-tenant **request window** (pages per node per step): each step's
  request list is the concatenation of the tenants' windows, interactive
  classes first, so latency-sensitive requests land in the earliest bridge
  rounds while a batch tenant's backlog is clipped to its window instead of
  flooding the round budget (the noisy-neighbour cure);
* the per-node **active_budget** (the sum of the windows), handed straight
  to ``pull_pages`` / ``push_pages``.

The split is weighted-fair with work conservation by water-filling: each
tenant's fair share is ``budget * share / sum(shares)``, but a tenant whose
*measured demand* (telemetry: last step's served + spilled pages) is below
its share only gets its demand — the surplus re-splits among the still-
hungry tenants, so unused interactive budget spills to batch and the wire
never idles while anyone has work.  Shares, windows and the composed
request/tenant lanes are all runtime values: a re-fit never retraces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.memport import FREE
from repro.orchestrator.tenants import TenantSpec, qos_rank


@dataclass(frozen=True)
class Schedule:
    """One control period's compiled budget partition.

    Attributes:
      windows: tenant_id -> pages per node per step (its request window).
      order: tenant ids in composition order (interactive first).
      budget: the bridge round budget the windows partition.
    """

    windows: Dict[int, int]
    order: tuple
    budget: int

    @property
    def total_window(self) -> int:
        return int(sum(self.windows.values()))

    def active_budget(self, num_nodes: int) -> np.ndarray:
        """Per-node ``active_budget`` vector for the bridge (runtime input)."""
        return np.full((num_nodes,), min(self.total_window, self.budget),
                       np.int32)

    def compose_requests(self, backlogs: Dict[int, Sequence[Sequence[int]]],
                         num_nodes: int
                         ) -> tuple[np.ndarray, np.ndarray, Dict[int, int]]:
        """Fill each tenant's window from its per-node backlog queues.

        Args:
          backlogs: tenant_id -> per-node queues of logical page ids (only
            the front ``window`` entries of each are consumed — pop them
            after the transfer using the returned take counts).
        Returns:
          (want [num_nodes, W], tenant_lane [num_nodes, W], taken) where
          ``W == total_window``; unused lanes are FREE (tenant lane 0 —
          FREE requests are never live, so attribution ignores them) and
          ``taken[tid]`` is the max pages consumed from any node's queue.
        """
        w = self.total_window
        want = np.full((num_nodes, max(w, 1)), FREE, np.int32)
        lane = np.zeros((num_nodes, max(w, 1)), np.int32)
        taken: Dict[int, int] = {}
        at = 0
        for tid in self.order:
            win = self.windows.get(tid, 0)
            if win <= 0:
                continue
            rows = backlogs.get(tid, [])
            got = 0
            for node in range(min(num_nodes, len(rows))):
                head = list(rows[node])[:win]
                want[node, at: at + len(head)] = head
                # Tag only the filled prefix: lanes past len(head) stay FREE
                # and must keep tenant lane 0 (the docstring contract) so
                # composed lanes reconcile with per-tenant telemetry
                # attribution without phantom tenant tags on dead lanes.
                lane[node, at: at + len(head)] = tid
                got = max(got, len(head))
            taken[tid] = got
            at += win
        return want[:, :max(w, 1)], lane[:, :max(w, 1)], taken


def water_fill(shares: np.ndarray, demand: np.ndarray,
               budget: int) -> np.ndarray:
    """Weighted-fair split of ``budget`` with demand caps (work conserving).

    Repeatedly splits the unassigned budget among still-hungry tenants in
    proportion to their shares; a tenant capped by its demand frees its
    surplus for the next pass.  Terminates when every tenant is satisfied
    or the budget is exhausted.  Returns real-valued allocations.

    A zero *effective* weight vector (every still-hungry tenant has share
    0 — e.g. shares zeroed by an operator override) falls back to an even
    split among the hungry tenants instead of dividing by zero: NaN
    allocations would otherwise propagate straight into compiled windows.
    Negative shares are clipped to zero.
    """
    n = shares.shape[0]
    shares = np.maximum(np.asarray(shares, float), 0.0)
    alloc = np.zeros((n,))
    remaining = float(budget)
    hungry = demand > 0
    while remaining > 1e-9 and hungry.any():
        w = shares * hungry
        if w.sum() <= 0.0:
            # Zero effective weight: even split keeps the fill NaN-free.
            w = hungry.astype(float)
        fair = remaining * w / w.sum()
        grant = np.minimum(fair, demand - alloc)
        alloc += grant
        remaining -= grant.sum()
        newly_full = hungry & (demand - alloc <= 1e-9)
        if not newly_full.any():
            break  # nobody capped: the whole remainder was dealt fairly
        hungry &= ~newly_full
    return alloc


def _largest_remainder(alloc: np.ndarray, demand: np.ndarray,
                       budget: int) -> np.ndarray:
    """Round real allocations to integers without exceeding the budget."""
    floors = np.floor(alloc).astype(np.int64)
    spare = min(budget, int(np.ceil(alloc.sum() - 1e-9))) - floors.sum()
    if spare > 0:
        frac = alloc - floors
        room = np.minimum(np.ceil(demand), budget) - floors
        order = np.argsort(-frac, kind="stable")
        for i in order:
            if spare <= 0:
                break
            if frac[i] > 1e-9 and room[i] > 0:
                floors[i] += 1
                spare -= 1
    return floors


class WeightedFairScheduler:
    """Compiles tenant specs + measured demand into a :class:`Schedule`."""

    def __init__(self, budget: int):
        if budget < 1:
            raise ValueError(f"budget must be >= 1, got {budget}")
        self.budget = budget

    def compile(self, specs: Sequence[TenantSpec],
                demand: Optional[Dict[int, float]] = None) -> Schedule:
        """Partition the round budget across ``specs``.

        Args:
          demand: tenant_id -> measured offered pages per node per step
            (e.g. ``TelemetryAggregator.tenant_demand()`` normalized per
            node).  None (or a missing tenant) means unknown — treated as
            unbounded, so the tenant gets its full weighted-fair share.
        """
        if not specs:
            return Schedule(windows={}, order=(), budget=self.budget)
        order = tuple(s.tenant_id for s in sorted(
            specs, key=lambda s: (qos_rank(s.qos), -s.priority, s.tenant_id)))
        shares = np.asarray([s.share for s in specs], float)
        dem = np.asarray([
            float("inf") if demand is None
            or demand.get(s.tenant_id) is None
            else max(float(demand[s.tenant_id]), 0.0) for s in specs])
        alloc = water_fill(shares, dem, self.budget)
        windows = _largest_remainder(alloc, dem, self.budget)
        # Work conservation floor: a hungry tenant never rounds to zero
        # while the budget has unassigned lanes.
        spare = self.budget - int(windows.sum())
        for i in np.argsort([qos_rank(s.qos) for s in specs], kind="stable"):
            if spare <= 0:
                break
            if windows[i] == 0 and dem[i] > 0:
                windows[i] += 1
                spare -= 1
        return Schedule(
            windows={s.tenant_id: int(w) for s, w in zip(specs, windows)},
            order=order, budget=self.budget)

    def refit(self, specs: Sequence[TenantSpec], telemetry,
              num_nodes: int, saturated: Sequence[int] = ()) -> Schedule:
        """Re-compile from a :class:`~repro.telemetry.TelemetryAggregator`.

        Uses the aggregator's raw last-step per-tenant demand (served +
        spilled, the offered load under the current split) normalized per
        node.  A tenant whose demand was *clipped* by its current window
        may want more: any tenant that spilled — or whose id is in
        ``saturated`` (the orchestrator passes tenants whose composed
        window was completely filled, i.e. host-side clipping may have
        hidden further backlog) — is treated as unbounded so the next
        split lets it bid for the spare budget.

        Measured demand is floored at one page per node: a tenant that
        offered nothing this period keeps one lane's worth of bid.
        Treating a zero measurement as a hard cap would be a livelock — a
        zero window serves nothing, so the next measurement is zero again
        and the window can never reopen.
        """
        dem = np.asarray(telemetry.tenant_demand(), float) / max(num_nodes, 1)
        spilled = np.asarray(telemetry.last_tenant_spilled, float)
        demand: Dict[int, float] = {}
        for s in specs:
            if s.tenant_id < dem.shape[0]:
                if (spilled[s.tenant_id] > 0
                        or s.tenant_id in saturated):
                    demand[s.tenant_id] = float("inf")  # clipped: wants more
                else:
                    demand[s.tenant_id] = max(float(dem[s.tenant_id]), 1.0)
        return self.compile(specs, demand)
