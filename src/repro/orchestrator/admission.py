"""Admission control: admit, queue or reject lease requests.

DDC-style disaggregated orchestration stands or falls on what it lets in:
an admitted lease consumes pooled slots for its whole term, so the decision
folds three signals —

* **capacity** — free slots across alive nodes (a full pool queues the
  request until lease expiry frees space; the orchestrator drains the queue
  on every ``step()``),
* **quota** — the tenant's ``page_quota`` across all its held leases (a
  quota violation can never heal by waiting, so it rejects outright),
* **SLO** — the :mod:`repro.core.perfmodel`-predicted completion latency of
  the tenant's per-step window under the *measured* pool load
  (``perfmodel.predict_transfer_latency_us``); a pool too busy to meet the
  tenant's ``slo_round_us`` queues the request rather than admitting a
  lease the fabric cannot serve.

Decisions are pure data (:class:`AdmissionDecision`); the controller never
allocates — the orchestrator owns the control plane and executes admitted
requests, so this module stays independently testable.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from repro.orchestrator.tenants import TenantSpec

ADMITTED = "admitted"
QUEUED = "queued"
REJECTED = "rejected"


@dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of one lease request."""

    status: str                  # admitted | queued | rejected
    reason: str = ""

    @property
    def admitted(self) -> bool:
        return self.status == ADMITTED


@dataclass
class PendingRequest:
    """A queued lease request, retried on every orchestrator step."""

    tenant_id: int
    num_pages: int
    policy: str = "affinity"
    term: int = 0
    auto_renew: bool = False
    queued_step: int = 0
    attempts: int = field(default=0)


class AdmissionController:
    """Stateless decision rules + a FIFO retry queue for deferred requests.

    ``max_attempts`` / ``ttl_steps`` bound how long a queued request may
    keep retrying (0 = unbounded): a request that outlives either bound is
    *evicted* from the FIFO on the next :meth:`drain` and counted as a
    rejection.  Without the bound, a request the pool can satisfy in
    principle but never does in practice (e.g. held capacity that never
    frees) parks in the FIFO forever and the serving layer's admission
    loop livelocks on it.
    """

    def __init__(self, queue_limit: int = 64, max_attempts: int = 0,
                 ttl_steps: int = 0):
        if queue_limit < 0:
            raise ValueError("queue_limit must be >= 0")
        if max_attempts < 0 or ttl_steps < 0:
            raise ValueError("max_attempts/ttl_steps must be >= 0")
        self.queue_limit = queue_limit
        self.max_attempts = max_attempts
        self.ttl_steps = ttl_steps
        self.pending: deque[PendingRequest] = deque()
        self.admitted_total = 0
        self.rejected_total = 0
        self.evicted_total = 0
        self.last_evicted: list[PendingRequest] = []

    # -- decision rules --------------------------------------------------------
    def evaluate(self, spec: TenantSpec, num_pages: int, *,
                 free_slots: int, free_logical: int, held_pages: int,
                 predicted_us: Optional[float] = None,
                 total_slots: Optional[int] = None,
                 total_logical: Optional[int] = None) -> AdmissionDecision:
        """Decide one request against the current pool state.

        Args:
          num_pages: pages the lease would pin.
          free_slots: free physical slots across alive nodes.
          free_logical: unclaimed logical page ids (recycled + fresh).
          held_pages: pages the tenant already holds across its leases.
          predicted_us: perfmodel-predicted completion latency of the
            tenant's per-step window if admitted (None = not modeled).
          total_slots: physical slots across *alive* nodes, free or held
            (None = unknown).  A request larger than the whole alive pool
            can never heal by waiting — it REJECTS instead of queueing,
            where it would retry in the FIFO forever.
          total_logical: the pool's whole logical id space (same rule).
        """
        if num_pages <= 0:
            return AdmissionDecision(REJECTED, "empty request")
        if spec.page_quota > 0 and held_pages + num_pages > spec.page_quota:
            # Waiting cannot heal a quota violation: reject, don't queue.
            return AdmissionDecision(
                REJECTED, f"quota: holds {held_pages} + {num_pages} > "
                          f"{spec.page_quota}")
        if total_slots is not None and num_pages > total_slots:
            return AdmissionDecision(
                REJECTED, f"capacity: {num_pages} pages exceeds the whole "
                          f"alive pool ({total_slots} slots)")
        if total_logical is not None and num_pages > total_logical:
            return AdmissionDecision(
                REJECTED, f"capacity: {num_pages} pages exceeds the "
                          f"logical id space ({total_logical})")
        if num_pages > free_slots:
            return AdmissionDecision(
                QUEUED, f"capacity: {num_pages} > {free_slots} free slots")
        if num_pages > free_logical:
            return AdmissionDecision(
                QUEUED, f"capacity: {num_pages} > {free_logical} free "
                        f"logical ids")
        if (spec.slo_round_us > 0 and predicted_us is not None
                and predicted_us > spec.slo_round_us):
            return AdmissionDecision(
                QUEUED, f"slo: predicted {predicted_us:.1f}us > "
                        f"{spec.slo_round_us:.1f}us")
        return AdmissionDecision(ADMITTED)

    # -- deferred-request queue ------------------------------------------------
    def enqueue(self, req: PendingRequest) -> AdmissionDecision:
        if len(self.pending) >= self.queue_limit:
            self.rejected_total += 1
            return AdmissionDecision(
                REJECTED, f"queue full ({self.queue_limit})")
        self.pending.append(req)
        return AdmissionDecision(QUEUED, "waiting for capacity")

    def drain(self, try_admit,
              step: Optional[int] = None) -> list[PendingRequest]:
        """Retry every queued request once, FIFO; return the admitted ones.

        ``try_admit(req) -> bool`` is the orchestrator's executor (evaluate
        against fresh state, allocate on admit).  Requests that still fail
        re-queue in order, so a starved head-of-line request keeps its
        place — unless it has exhausted ``max_attempts`` retries or (with
        ``step`` given) outlived ``ttl_steps`` since it was queued, in
        which case it is evicted and counted as rejected
        (``last_evicted`` holds this drain's evictions).
        """
        granted: list[PendingRequest] = []
        self.last_evicted = []
        for _ in range(len(self.pending)):
            req = self.pending.popleft()
            if (self.max_attempts > 0
                    and req.attempts >= self.max_attempts) or \
                    (self.ttl_steps > 0 and step is not None
                     and step - req.queued_step > self.ttl_steps):
                self.rejected_total += 1
                self.evicted_total += 1
                self.last_evicted.append(req)
                continue
            req.attempts += 1
            if try_admit(req):
                granted.append(req)
            else:
                self.pending.append(req)
        return granted

    def describe(self) -> str:
        return (f"admission: {self.admitted_total} admitted, "
                f"{self.rejected_total} rejected "
                f"({self.evicted_total} evicted), "
                f"{len(self.pending)} queued")
