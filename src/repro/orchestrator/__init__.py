"""repro.orchestrator — multi-tenant, QoS-aware orchestration of the pool.

The layer the paper's closing claim asks for: tenants
(:mod:`~repro.orchestrator.tenants`), admission control
(:mod:`~repro.orchestrator.admission`), weighted-fair QoS scheduling
(:mod:`~repro.orchestrator.scheduler`) and the facade driving the
:class:`~repro.core.control_plane.ControlPlane` through a measure ->
re-fit ``step()`` lifecycle (:mod:`~repro.orchestrator.orchestrator`).
"""
from repro.orchestrator.admission import (ADMITTED, QUEUED, REJECTED,
                                          AdmissionController,
                                          AdmissionDecision, PendingRequest)
from repro.orchestrator.orchestrator import Orchestrator
from repro.orchestrator.scheduler import (Schedule, WeightedFairScheduler,
                                          water_fill)
from repro.orchestrator.tenants import (QOS_CLASSES, Lease, TenantSpec,
                                        qos_rank, validate_tenants)

__all__ = [
    "ADMITTED", "QUEUED", "REJECTED", "AdmissionController",
    "AdmissionDecision", "PendingRequest", "Orchestrator", "Schedule",
    "WeightedFairScheduler", "water_fill", "QOS_CLASSES", "Lease",
    "TenantSpec", "qos_rank", "validate_tenants",
]
