"""Tenants and leases — who owns the pooled pages, and for how long.

The paper closes by arguing the software-defined bridge "enables datacenter
orchestration tools to manage the disaggregated resource allocation"; this
module is the vocabulary those tools speak.  A :class:`TenantSpec` names a
workload and what it is owed — its QoS class, page quota, weighted budget
share and scheduling priority — and a :class:`Lease` ties a
:class:`~repro.core.control_plane.Region` of pooled pages to a tenant with
a *step-denominated* expiry: the orchestrator's ``step()`` clock (not wall
time) ages leases, so reclamation is deterministic and testable.

Everything here is host-side plain data.  The only value that ever reaches
the device is ``TenantSpec.tenant_id`` — the per-request attribution lane
the datapath bins telemetry by — so registering, resizing or re-weighting
tenants never retraces anything.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.control_plane import Region

#: QoS classes, in scheduling-rank order: interactive windows compose ahead
#: of batch, batch ahead of best-effort, so latency-sensitive requests land
#: in the earliest bridge rounds of every step.
QOS_CLASSES = ("interactive", "batch", "best_effort")


def qos_rank(qos: str) -> int:
    """Composition order of a QoS class (lower = earlier rounds)."""
    return QOS_CLASSES.index(qos)


@dataclass(frozen=True)
class TenantSpec:
    """What one workload is owed by the pool.

    Attributes:
      tenant_id: the datapath attribution id (0 <= id < ``max_tenants``) —
        the value carried in the bridge's per-request tenant lane.
      name: human-readable workload name.
      qos: ``interactive`` | ``batch`` | ``best_effort`` (composition and
        spill order of the weighted-fair scheduler).
      page_quota: max pooled pages the tenant may hold across its leases
        (0 = unlimited) — the admission controller's hard cap.
      share: weighted-fair budget weight (> 0); the scheduler splits each
        bridge round's page budget proportionally.
      priority: tie-break within a QoS class (higher composes earlier).
      slo_round_us: admission SLO — the predicted completion latency (µs)
        of the tenant's per-step window must stay below this, else the
        request queues (0 = no SLO).
    """

    tenant_id: int
    name: str
    qos: str = "batch"
    page_quota: int = 0
    share: float = 1.0
    priority: int = 0
    slo_round_us: float = 0.0

    def __post_init__(self) -> None:
        if self.tenant_id < 0:
            raise ValueError(f"tenant_id must be >= 0, got {self.tenant_id}")
        if self.qos not in QOS_CLASSES:
            raise ValueError(f"qos must be one of {QOS_CLASSES}, "
                             f"got {self.qos!r}")
        if self.share <= 0:
            raise ValueError(f"share must be > 0, got {self.share}")


@dataclass
class Lease:
    """A tenant's claim on one allocated region, aged by the step clock.

    ``expires_step`` is absolute (the orchestrator step at which the lease
    lapses; -1 = never).  An ``auto_renew`` lease is re-armed for another
    ``term`` steps each time it would expire; otherwise expiry releases the
    region back to the control plane (its logical ids recycle) and frees
    capacity for queued admissions.
    """

    lease_id: int
    tenant_id: int
    region: Region
    granted_step: int
    term: int                     # steps per grant (<= 0: never expires)
    auto_renew: bool = False
    renewals: int = field(default=0)

    @property
    def expires_step(self) -> int:
        if self.term <= 0:
            return -1
        return self.granted_step + (self.renewals + 1) * self.term

    @property
    def num_pages(self) -> int:
        return len(self.region.page_ids)

    def expired(self, step: int) -> bool:
        return self.term > 0 and step >= self.expires_step

    def renew(self) -> None:
        self.renewals += 1


def validate_tenants(specs: list[TenantSpec], max_tenants: int) -> None:
    """Raise on duplicate / out-of-range tenant ids."""
    seen: set[int] = set()
    for spec in specs:
        if spec.tenant_id >= max_tenants:
            raise ValueError(
                f"tenant {spec.name!r} id {spec.tenant_id} >= max_tenants "
                f"{max_tenants} (the static telemetry histogram width)")
        if spec.tenant_id in seen:
            raise ValueError(f"duplicate tenant id {spec.tenant_id}")
        seen.add(spec.tenant_id)


def tenant_by_id(specs: list[TenantSpec],
                 tenant_id: int) -> Optional[TenantSpec]:
    for spec in specs:
        if spec.tenant_id == tenant_id:
            return spec
    return None
