"""The orchestrator facade: multi-tenant QoS-aware control of one pool.

This is the "datacenter orchestration tool" of the paper's closing claim,
driving every knob the earlier layers made runtime-programmable through one
``step()`` lifecycle:

    register tenants -> lease pages -> schedule windows -> measure -> re-fit

* **Placement** — each tenant anchors to a board (round-robin over the
  :class:`~repro.core.topology.Topology` groups at registration), and its
  leases allocate with board affinity: a tenant's pages cluster on its
  board's local ring, so its traffic stays intra-board and tenants mostly
  do not contend for the rack gateways.
* **Leases** — step-denominated terms; expiry releases the region (logical
  ids recycle through the control plane's free list) or auto-renews, and
  freed capacity immediately drains the admission queue.
* **Admission** — :class:`~repro.orchestrator.admission.AdmissionController`
  rules over live capacity, tenant quota, and the perfmodel-predicted
  completion latency of the tenant's window vs its SLO.
* **Scheduling** — the
  :class:`~repro.orchestrator.scheduler.WeightedFairScheduler` partitions
  the bridge round budget into per-tenant request windows, re-fit every
  ``control_period`` steps from the *measured* per-tenant demand (the
  datapath's tenant-attributed telemetry), interactive unused budget
  spilling to batch.
* **Datapath refresh** — the same control period recompiles the route
  program from measured traffic (``ControlPlane.route_program``), re-picks
  the pipeline depth (``select_channels``) and plans cross-tenant affinity
  migrations (hot pages re-home toward their dominant requester's board).

Every output is a runtime input to the jitted datapath — tables, programs,
budgets, windows, tenant lanes — so a full orchestration cycle never
recompiles anything.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import perfmodel
from repro.core.control_plane import ControlPlane, MigrationStep
from repro.obs.detect import Sentinel
from repro.obs.flight import FlightRecorder
from repro.obs.metrics import MetricsRegistry, SLOMonitor
from repro.orchestrator.admission import (ADMITTED, REJECTED,
                                          AdmissionController,
                                          AdmissionDecision, PendingRequest,
                                          QUEUED)
from repro.orchestrator.scheduler import Schedule, WeightedFairScheduler
from repro.orchestrator.tenants import Lease, TenantSpec, validate_tenants
from repro.telemetry.aggregate import TelemetryAggregator
from repro.telemetry.counters import DEFAULT_MAX_TENANTS


class Orchestrator:
    """Owns tenancy for one :class:`~repro.core.control_plane.ControlPlane`."""

    def __init__(self, control_plane: ControlPlane, *, budget: int = 8,
                 page_bytes: int = 0, channels: int = 1,
                 control_period: int = 4,
                 max_tenants: int = DEFAULT_MAX_TENANTS,
                 default_term: int = 32, queue_limit: int = 64,
                 queue_max_attempts: int = 0, queue_ttl_steps: int = 0,
                 migrate: bool = True, migration_limit: int = 8,
                 alpha: float = 0.25,
                 flight: Optional[FlightRecorder] = None):
        self.cp = control_plane
        self.budget = budget
        self.page_bytes = page_bytes
        self.max_tenants = max_tenants
        self.control_period = max(control_period, 1)
        self.default_term = default_term
        self.migrate = migrate
        self.migration_limit = migration_limit
        self.scheduler = WeightedFairScheduler(budget)
        self.admission = AdmissionController(
            queue_limit, max_attempts=queue_max_attempts,
            ttl_steps=queue_ttl_steps)
        self.telemetry = TelemetryAggregator(
            control_plane.num_nodes, page_bytes=page_bytes, alpha=alpha,
            max_tenants=max_tenants)
        self.specs: Dict[int, TenantSpec] = {}
        self.leases: Dict[int, Lease] = {}
        self.step_count = 0
        self.schedule: Schedule = Schedule(windows={}, order=(),
                                           budget=budget)
        self.channels = channels
        # Observability plane: exact counters + EWMA gauges + span latency
        # histograms (metrics), per-tenant SLO burn rates (slo), and the
        # online perfmodel calibration (measured round latencies -> fitted
        # constants driving select_channels and the admission pricing).
        self.metrics = MetricsRegistry()
        self.slo = SLOMonitor(registry=self.metrics)
        self.calibrator = perfmodel.Calibrator()
        # Decision plane: every control-plane action below journals into
        # the flight recorder (attach records the cp_init genesis, so the
        # initial route-program install is the journal's first decision);
        # the sentinel watches latency/residual/SLO/telemetry for drift.
        self.flight = flight if flight is not None else FlightRecorder()
        control_plane.attach_flight(self.flight)
        self.sentinel = Sentinel(registry=self.metrics, flight=self.flight,
                                 calibrator=self.calibrator, slo=self.slo)
        self._program = control_plane.route_program()
        self._program_stale = False
        self._next_lease = 0
        self._anchor_group: Dict[int, int] = {}   # tenant -> home board
        self._migration_log: List[MigrationStep] = []
        self._last_taken: Dict[int, int] = {}     # last compose consumption

    # -- tenants ---------------------------------------------------------------
    def register(self, spec: TenantSpec) -> TenantSpec:
        """Add a tenant; anchors it to a board and re-fits the schedule."""
        validate_tenants(list(self.specs.values()) + [spec],
                         self.max_tenants)
        self.specs[spec.tenant_id] = spec
        self._anchor_group[spec.tenant_id] = (
            len(self._anchor_group) % self.cp.topology.num_groups)
        self.schedule = self.scheduler.compile(list(self.specs.values()))
        self.flight.record(
            "register", tenant_id=spec.tenant_id, name=spec.name,
            qos=spec.qos, page_quota=spec.page_quota, share=spec.share,
            priority=spec.priority, slo_round_us=spec.slo_round_us,
            anchor_group=self._anchor_group[spec.tenant_id])
        self.flight.record("refit", mode="compile", budget=self.budget,
                           windows=dict(self.schedule.windows))
        return spec

    def held_pages(self, tenant_id: int) -> int:
        return sum(l.num_pages for l in self.leases.values()
                   if l.tenant_id == tenant_id)

    def tenant_leases(self, tenant_id: int) -> List[Lease]:
        return [l for l in self.leases.values()
                if l.tenant_id == tenant_id]

    def _anchor_node(self, tenant_id: int) -> int:
        """The tenant's preferred home: emptiest alive node on its board."""
        group = self._anchor_group.get(tenant_id, 0)
        topo = self.cp.topology
        mates = [n for n in self.cp.alive_nodes if topo.group[n] == group]
        pool = mates or self.cp.alive_nodes
        if not pool:
            raise RuntimeError("no alive nodes")
        return max(pool, key=lambda n: self.cp.free_slots(n))

    # -- admission + leasing ---------------------------------------------------
    def _free_capacity(self) -> Tuple[int, int]:
        slots = sum(self.cp.free_slots(n) for n in self.cp.alive_nodes)
        return slots, self.cp.free_logical()

    def _total_capacity(self) -> Tuple[int, int]:
        """Whole-pool capacity over alive nodes (free or held).

        The REJECT side of admission: a request bigger than this can
        never heal by waiting and must not park in the retry queue.
        """
        slots = len(self.cp.alive_nodes) * self.cp.pages_per_node
        return slots, self.cp.num_logical

    def can_ever_admit(self, tenant_id: int, num_pages: int) -> bool:
        """Whether ``num_pages`` could *ever* be admitted for the tenant.

        Checks only the terminal conditions — tenant quota and whole-pool
        capacity — ignoring current occupancy.  A serving layer uses this
        to shed impossible requests immediately instead of retrying them
        until a TTL fires.
        """
        spec = self.specs[tenant_id]
        if num_pages <= 0:
            return False
        if spec.page_quota > 0 and num_pages > spec.page_quota:
            return False
        total_slots, total_logical = self._total_capacity()
        return num_pages <= min(total_slots, total_logical)

    def predicted_window_us(self, tenant_id: int) -> Optional[float]:
        """perfmodel completion latency of the tenant's per-step window.

        Priced under the *measured* pool load when telemetry exists (each
        live slot's pages per requester-round), worst-case full-budget
        rounds otherwise.  None when the model has no page size to price.
        """
        if self.page_bytes <= 0:
            return None
        window = self.schedule.windows.get(tenant_id, 0) or self.budget
        slot_pages = self._measured_slot_pages()
        topo = (None if self.cp.topology.is_flat else self.cp.topology)
        if self.calibrator.fitted:
            # Price with the fitted constants (including the chunk/base
            # software overheads the static model omits).
            return self.calibrator.predict_transfer_latency_us(
                self.route_program(), self.page_bytes, self.budget, window,
                slot_pages=slot_pages, topology=topo,
                channels=self.channels)
        return perfmodel.predict_transfer_latency_us(
            self.route_program(), self.page_bytes, self.budget, window,
            slot_pages=slot_pages, topology=topo, channels=self.channels)

    def _measured_slot_pages(self):
        """Per-slot pages of one requester-round under the measured load
        (None with no telemetry yet)."""
        if self.telemetry.steps > 0:
            # distance_pages is a per-STEP histogram; one round carries
            # 1/rounds of it (rounds estimated from the busiest requester's
            # measured served pages vs the round budget) — pricing the
            # whole step as one round would overstate the load and starve
            # admission on any multi-round composition.
            rounds = max(1.0, float(np.ceil(
                np.max(self.telemetry.served) / max(self.budget, 1))))
            per_round = np.maximum(
                self.telemetry.distance_pages(), 0.0) / (
                    max(self.cp.num_nodes, 1) * rounds)
            return np.minimum(per_round, self.budget)
        return None

    def observe_round_latency(self, measured_us: float, *,
                              rounds: int = 1) -> float:
        """Feed one fenced span latency (us, ``rounds`` bridge rounds)
        into the calibrator under the currently-measured load.

        This is the measure half of the measure->fit->steer loop: the
        serving layer times its pull/push with a ``TraceRecorder`` span
        and hands the duration here; the next control period's
        ``select_channels`` / window pricing then runs on fitted
        constants.  Returns the calibrator's pre-fit prediction error.
        """
        if self.page_bytes <= 0:
            return 0.0
        topo = (None if self.cp.topology.is_flat else self.cp.topology)
        feats = perfmodel.route_features(
            self.route_program(), self.page_bytes, self.budget,
            rounds=max(rounds, 1), channels=self.channels,
            slot_pages=self._measured_slot_pages(), topology=topo)
        err = self.calibrator.observe(feats, measured_us)
        per_round = measured_us / max(rounds, 1)
        # Sentinel feed: the calibrator's pre-fit prediction for this very
        # sample (measured - err) is the drift reference; only meaningful
        # once the fit has enough samples to be trusted.
        self.sentinel.observe_latency(
            per_round,
            predicted_us=((measured_us - err) / max(rounds, 1)
                          if self.calibrator.fitted else None),
            residual_us=abs(err) if self.calibrator.fitted else None)
        self.metrics.histogram("obs_round_latency_us").record(
            measured_us / max(rounds, 1))
        self.metrics.gauge("calibrator_samples").set(
            self.calibrator.samples)
        self.metrics.gauge("calibrator_abs_error_us").set(abs(err))
        for tid, spec in self.specs.items():
            if spec.slo_round_us > 0:
                self.slo.record(tid, measured_us / max(rounds, 1),
                                spec.slo_round_us)
        return err

    def request_lease(self, tenant_id: int, num_pages: int, *,
                      policy: str = "affinity", term: Optional[int] = None,
                      auto_renew: bool = False, queue: bool = True,
                      request_id: Optional[int] = None
                      ) -> Tuple[AdmissionDecision, Optional[Lease]]:
        """Ask for ``num_pages`` pooled pages under admission control.

        Returns ``(decision, lease)``; the lease is None unless admitted.
        ``queue=True`` parks capacity/SLO-limited requests for retry on
        future steps (lease expiry frees capacity); quota violations always
        reject.  ``request_id`` tags the journaled admission verdict and
        lease grant with the serving request they decide, so
        ``FlightRecorder.why(request_id)`` can reconstruct the chain.
        """
        if tenant_id not in self.specs:
            raise KeyError(f"tenant {tenant_id} not registered")
        spec = self.specs[tenant_id]
        free_slots, free_logical = self._free_capacity()
        total_slots, total_logical = self._total_capacity()
        decision = self.admission.evaluate(
            spec, num_pages, free_slots=free_slots,
            free_logical=free_logical, held_pages=self.held_pages(tenant_id),
            predicted_us=self.predicted_window_us(tenant_id),
            total_slots=total_slots, total_logical=total_logical)
        if decision.status == ADMITTED:
            self._rec_admission(decision, tenant_id, num_pages, request_id)
            lease = self._grant(spec, num_pages, policy, term, auto_renew,
                                request_id=request_id)
            return decision, lease
        if decision.status == QUEUED and queue:
            self._rec_admission(decision, tenant_id, num_pages, request_id)
            return self.admission.enqueue(PendingRequest(
                tenant_id=tenant_id, num_pages=num_pages, policy=policy,
                term=term if term is not None else self.default_term,
                auto_renew=auto_renew, queued_step=self.step_count)), None
        self.admission.rejected_total += 1
        if decision.status == QUEUED:
            # queue=False: a queueable request that was not parked is a
            # rejection — a QUEUED status would promise a retry that will
            # never happen.
            decision = AdmissionDecision(REJECTED, decision.reason)
        self._rec_admission(decision, tenant_id, num_pages, request_id)
        return decision, None

    def _rec_admission(self, decision: AdmissionDecision, tenant_id: int,
                       num_pages: int,
                       request_id: Optional[int] = None) -> None:
        self.flight.record(
            "admission", request_id=request_id, tenant_id=tenant_id,
            num_pages=num_pages, status=decision.status,
            reason=decision.reason)

    def _grant(self, spec: TenantSpec, num_pages: int, policy: str,
               term: Optional[int], auto_renew: bool,
               request_id: Optional[int] = None) -> Lease:
        kw = {}
        if policy == "affinity":
            kw["affinity"] = self._anchor_node(spec.tenant_id)
        region = self.cp.allocate(
            num_pages, name=f"{spec.name}/lease{self._next_lease}",
            policy=policy, **kw)
        lease = Lease(lease_id=self._next_lease, tenant_id=spec.tenant_id,
                      region=region, granted_step=self.step_count,
                      term=term if term is not None else self.default_term,
                      auto_renew=auto_renew)
        self.leases[lease.lease_id] = lease
        self._next_lease += 1
        self.admission.admitted_total += 1
        self.flight.record(
            "lease_grant", request_id=request_id, lease_id=lease.lease_id,
            tenant_id=spec.tenant_id, region_id=region.region_id,
            num_pages=num_pages, policy=policy, term=lease.term,
            auto_renew=auto_renew)
        # Placement changed: the circuit schedule must reach the new pages
        # before the next transfer.  Marked stale and recompiled lazily in
        # route_program() — a step that churns many leases compiles once,
        # not once per lease.
        self._program_stale = True
        return lease

    def release_lease(self, lease: Lease) -> None:
        self.cp.release(lease.region)
        self.leases.pop(lease.lease_id, None)
        self._program_stale = True               # placement changed
        self.flight.record("lease_release", lease_id=lease.lease_id,
                           tenant_id=lease.tenant_id,
                           region_id=lease.region.region_id)

    # -- the step lifecycle ----------------------------------------------------
    def step(self, telemetry=None,
             measured_round_us: Optional[float] = None,
             rounds: int = 1) -> Dict[str, object]:
        """Advance the orchestration clock one serving step.

        Folds the step's measured telemetry, ages leases (expiry reclaims
        or auto-renews), drains the admission queue into freed capacity
        and — every ``control_period`` steps — re-fits the QoS schedule
        from measured per-tenant demand and refreshes the datapath's route
        program / pipeline depth / placement (affinity migration).

        ``measured_round_us`` is the step's fenced datapath span latency
        (``rounds`` bridge rounds' worth): it feeds the perfmodel
        calibrator and the per-tenant SLO burn rates, so the refit half
        of this method steers with fitted constants.

        Returns a report of the actions taken (expired/renewed lease ids,
        granted queued requests, new windows, migration plan).
        """
        self.step_count += 1
        if telemetry is not None:
            self.telemetry.update(telemetry)
            self.metrics.observe_telemetry(
                telemetry, page_bytes=self.page_bytes, specs=self.specs)
            self.metrics.observe_aggregator(self.telemetry)
            self.flight.epoch = self.telemetry.steps
            self.sentinel.check_telemetry(self.telemetry)
        if measured_round_us is not None:
            self.observe_round_latency(measured_round_us, rounds=rounds)
        self.sentinel.check_slo()

        expired, renewed = [], []
        for lease in list(self.leases.values()):
            if lease.expired(self.step_count):
                if lease.auto_renew:
                    lease.renew()
                    renewed.append(lease.lease_id)
                    self.flight.record("lease_renew",
                                       lease_id=lease.lease_id,
                                       tenant_id=lease.tenant_id,
                                       expires_step=lease.expires_step)
                else:
                    self.flight.record("lease_expiry",
                                       lease_id=lease.lease_id,
                                       tenant_id=lease.tenant_id)
                    self.release_lease(lease)
                    expired.append(lease.lease_id)

        # drain() removes every request whose retry is pointless (granted,
        # now-rejected, deregistered tenant); only grants created a lease,
        # so the report derives from the actual lease diff.
        before = set(self.leases)
        self.admission.drain(self._try_admit, step=self.step_count)
        report: Dict[str, object] = {
            "step": self.step_count, "expired": expired, "renewed": renewed,
            "granted": [l.tenant_id for lid, l in self.leases.items()
                        if lid not in before],
            "evicted": [r.tenant_id for r in self.admission.last_evicted],
            "refit": False, "migrations": [],
        }
        for r in self.admission.last_evicted:
            self.flight.record("admission", tenant_id=r.tenant_id,
                               num_pages=r.num_pages, status="EVICTED",
                               reason="queue ttl/attempt limit")
        if self.step_count % self.control_period == 0 and self.specs:
            report["refit"] = True
            if self.telemetry.steps > 0:
                # A tenant whose last composed window was completely
                # consumed may have more backlog hidden behind host-side
                # clipping: let it bid as unbounded.  Consumed on read —
                # a stale take from steps ago must not keep an idle tenant
                # bidding as saturated forever.
                saturated = [tid for tid, got in self._last_taken.items()
                             if got >= self.schedule.windows.get(tid, 0) > 0]
                self._last_taken = {}
                self.schedule = self.scheduler.refit(
                    list(self.specs.values()), self.telemetry,
                    self.cp.num_nodes, saturated=saturated)
                self.flight.record(
                    "refit", mode="telemetry", budget=self.budget,
                    num_nodes=self.cp.num_nodes,
                    demand=np.asarray(self.telemetry.tenant_demand(),
                                      float).tolist(),
                    spilled=np.asarray(self.telemetry.last_tenant_spilled,
                                       float).tolist(),
                    saturated=list(saturated),
                    windows=dict(self.schedule.windows))
                if self._program_stale:
                    # Placement changed this step: the measured compile
                    # would prune the new (not-yet-measured) distances, so
                    # placement reachability wins this period.
                    self._program = self.cp.route_program()
                    self._program_stale = False
                else:
                    self._program = self.cp.route_program(
                        telemetry=self.telemetry)
                if self.page_bytes > 0:
                    self.channels = self.cp.select_channels(
                        self.budget, self.page_bytes,
                        telemetry=self.telemetry, program=self._program,
                        calibrator=self.calibrator)
                    self.metrics.gauge("bridge_selected_channels").set(
                        self.channels)
                if self.migrate:
                    plan = self.cp.affinity_migration(
                        self.telemetry, limit=self.migration_limit)
                    self._migration_log.extend(plan)
                    report["migrations"] = plan
            else:
                self.schedule = self.scheduler.compile(
                    list(self.specs.values()))
                self.flight.record("refit", mode="compile",
                                   budget=self.budget,
                                   windows=dict(self.schedule.windows))
                self._program = self.cp.route_program()
                self._program_stale = False
            report["windows"] = dict(self.schedule.windows)
        self.flight.record(
            "step_report", step=self.step_count, expired=expired,
            renewed=renewed, granted=report["granted"],
            evicted=report["evicted"], refit=report["refit"],
            migrations=len(report["migrations"]))
        return report

    def refit_windows(self, demand: Dict[int, float]) -> Schedule:
        """Re-fit the QoS schedule from serving-layer queue depths.

        The periodic ``step()`` re-fit steers from *datapath* telemetry —
        pages actually moved — which lags the request queues: a tenant
        whose backlog just arrived has moved nothing yet and would bid
        zero.  A request-level front end (the continuous batcher) instead
        hands its live per-tenant queue depths here as the demand signal,
        so the bridge windows track offered load a control period early.
        """
        demand = {tid: max(float(d), 0.0) for tid, d in demand.items()}
        self.schedule = self.scheduler.compile(
            list(self.specs.values()), demand)
        self.flight.record("refit", mode="windows", budget=self.budget,
                           demand={str(k): v for k, v in demand.items()},
                           windows=dict(self.schedule.windows))
        return self.schedule

    def _try_admit(self, req: PendingRequest) -> bool:
        """Queue-drain executor: True removes the request from the queue.

        A queued request that has *become* a rejection (e.g. another lease
        pushed the tenant over quota) is dropped, not retried — waiting
        cannot heal it, and re-queueing forever would poison the queue.
        """
        spec = self.specs.get(req.tenant_id)
        if spec is None:
            return True  # tenant deregistered: drop the request
        free_slots, free_logical = self._free_capacity()
        total_slots, total_logical = self._total_capacity()
        decision = self.admission.evaluate(
            spec, req.num_pages, free_slots=free_slots,
            free_logical=free_logical,
            held_pages=self.held_pages(req.tenant_id),
            predicted_us=self.predicted_window_us(req.tenant_id),
            total_slots=total_slots, total_logical=total_logical)
        if decision.status == QUEUED:
            return False                 # still waiting: keep queued
        if decision.status == REJECTED:
            self.admission.rejected_total += 1
            return True                  # can never heal: drop
        self._grant(spec, req.num_pages, req.policy, req.term,
                    req.auto_renew)
        return True

    # -- datapath inputs -------------------------------------------------------
    def table(self):
        return self.cp.table()

    def route_program(self):
        if self._program_stale:
            # Recompile from placement reachability, not telemetry — newly
            # placed pages' distances have no measured traffic yet and
            # would be pruned; the periodic re-fit tightens back to
            # measured loads later.
            self._program = self.cp.route_program()
            self._program_stale = False
        return self._program

    def active_budget(self) -> np.ndarray:
        return self.schedule.active_budget(self.cp.num_nodes)

    def compose_requests(self, backlogs) -> tuple:
        """Schedule-ordered (want, tenant_lane, taken) for this step —
        see :meth:`repro.orchestrator.scheduler.Schedule.compose_requests`.
        The take counts are remembered: a window consumed in full marks
        its tenant as possibly-clipped for the next re-fit.
        """
        out = self.schedule.compose_requests(backlogs, self.cp.num_nodes)
        self._last_taken = dict(out[2])
        return out

    # -- introspection ---------------------------------------------------------
    def dump_debug_bundle(self, path: str, trace=None) -> str:
        """Write one postmortem archive: journal + trace + metrics + state.

        The zip holds ``journal.jsonl`` (the flight journal —
        ``repro.obs.replay()`` re-executes it), ``trace.json`` (Perfetto
        Chrome-trace of ``trace`` or the journal's attached recorder, when
        either exists), ``metrics.txt`` (Prometheus exposition) and
        ``describe.txt`` (orchestrator + pool state).  Returns ``path``.
        """
        import zipfile

        trace = trace if trace is not None else self.flight.trace
        with zipfile.ZipFile(path, "w", zipfile.ZIP_DEFLATED) as z:
            z.writestr("journal.jsonl", self.flight.to_jsonl())
            if trace is not None:
                z.writestr("trace.json", trace.to_json(indent=1))
            z.writestr("metrics.txt", self.metrics.to_text() + "\n")
            z.writestr("describe.txt", self.describe() + "\n")
        return path

    def describe(self) -> str:
        """Mirror of :meth:`ControlPlane.describe` for the tenancy layer."""
        lines = [f"orchestrator: step {self.step_count}, "
                 f"{len(self.specs)} tenants, {len(self.leases)} leases, "
                 f"budget {self.budget} "
                 f"(window {self.schedule.total_window}), "
                 f"channels {self.channels}"]
        for tid in sorted(self.specs):
            s = self.specs[tid]
            held = self.held_pages(tid)
            quota = s.page_quota if s.page_quota > 0 else "inf"
            lines.append(
                f"  tenant {tid} {s.name!r}: {s.qos} share={s.share:g} "
                f"window={self.schedule.windows.get(tid, 0)} "
                f"pages={held}/{quota} board={self._anchor_group[tid]}")
        for lid in sorted(self.leases):
            l = self.leases[lid]
            exp = ("never" if l.expires_step < 0
                   else f"step {l.expires_step}"
                        + (" (auto-renew)" if l.auto_renew else ""))
            lines.append(f"  lease {lid}: tenant {l.tenant_id} "
                         f"{l.num_pages} pages, expires {exp}")
        lines.append("  " + self.admission.describe())
        if self.calibrator.samples:
            c = self.calibrator.constants()
            lines.append(
                f"  calibrator: {c['samples']} samples, "
                f"hop {c['board_hop_rtts']:.3g}us, "
                f"link {c['link_payload_gbps']:.3g}GB/s, "
                f"chunk {self.calibrator.chunk_overhead_us:.3g}us, "
                f"base {self.calibrator.base_overhead_us:.3g}us"
                + ("" if self.calibrator.fitted else " (warming up)"))
        for tid, slo in self.slo.describe().items():
            lines.append(f"  slo tenant {tid}: burn {slo['burn_rate']:g} "
                         f"({slo['violations']}/{slo['samples']} over "
                         f"{slo['slo_us']:g}us)")
        snap = self.metrics.snapshot()
        if any(snap.values()):
            lines.append("  metrics:")
            lines.extend("    " + ln
                         for ln in self.metrics.to_text().splitlines())
        lines.append(self.cp.describe())
        return "\n".join(lines)
