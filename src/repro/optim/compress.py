"""Gradient compression: int8 ring all-reduce with error feedback.

A distributed-optimization trick for slow/oversubscribed interconnects: DP
gradients all-reduce in int8 (4x fewer bytes on the wire) with per-device
error-feedback accumulators so quantization error is re-injected into the
next step instead of lost (1-bit Adam / EF-SGD lineage).

The reduce itself is a manual ring over the DP axis built from the same
static-route ``ppermute`` epochs as the bridge (a gradient bucket is just
another page stream through the circuit network):

    reduce-scatter: N-1 epochs, each device accumulates its stripe in fp32,
                    forwarding int8-quantized partials;
    all-gather:     N-1 epochs of the finished int8 stripes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_ring_allreduce(x: jax.Array, axis: str,
                              num_nodes: int) -> jax.Array:
    """Mean-all-reduce of ``x`` (flat [L] fp32) over ``axis`` in int8 wire
    format.  Must run inside shard_map manual over ``axis``."""
    n = num_nodes
    if n == 1:
        return x
    pad = (-x.shape[0]) % n
    xf = jnp.pad(x, (0, pad)).reshape(n, -1)

    my = jax.lax.axis_index(axis)
    fwd = [(j, (j + 1) % n) for j in range(n)]
    # Reduce-scatter: at epoch e, device d forwards its running partial of
    # stripe (d - e - 1) to d+1, which accumulates it.  After N-1 epochs
    # device d holds the fully-reduced stripe (d + 1) % n.
    partial = xf
    for e in range(n - 1):
        # step e: node d forwards its running partial of stripe (d - e);
        # the receiver (d+1) accumulates it into that same stripe, which it
        # will forward at step e+1.
        send_idx = (my - e) % n
        stripe = jax.lax.dynamic_index_in_dim(partial, send_idx, 0,
                                              keepdims=False)
        q, s = quantize_int8(stripe)
        q_in = jax.lax.ppermute(q, axis, perm=fwd)
        s_in = jax.lax.ppermute(s, axis, perm=fwd)
        recv_idx = (my - e - 1) % n
        partial = partial.at[recv_idx].add(dequantize_int8(q_in, s_in))
    own_idx = (my + 1) % n
    own = jax.lax.dynamic_index_in_dim(partial, own_idx, 0,
                                       keepdims=False) / n

    # All-gather the finished stripes, still int8 on the wire: each node
    # contributes its stripe at its slot (zeros elsewhere) and an int8 psum
    # reconstructs the full vector.  psum also discharges the VMA type to
    # invariant, so every DP replica ends bitwise identical (parameter
    # consistency).  Wire cost: RS 1x int8 + psum 2x int8 = 3/8 of an fp32
    # all-reduce.
    q, s = quantize_int8(own)
    onehot = (jnp.arange(n) == own_idx)
    q_full = jnp.where(onehot[:, None], q[None, :],
                       jnp.zeros_like(xf, dtype=jnp.int8))
    s_full = jnp.where(onehot, s, 0.0)
    q_full = jax.lax.psum(q_full, axis)
    s_full = jax.lax.psum(s_full, axis)
    out = q_full.astype(jnp.float32) * s_full[:, None]
    flat = out.reshape(-1)
    return flat[: x.shape[0]]


class ErrorFeedback:
    """Per-step residual re-injection: g' = g + e;  e = g' - decompress(...)."""

    @staticmethod
    def init(params: Any) -> Any:
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads: Any, residual: Any) -> tuple[Any, Any]:
        """-> (grads + residual, fn(compressed) -> new residual via closure)"""
        boosted = jax.tree.map(
            lambda g, r: g.astype(jnp.float32) + r, grads, residual)
        return boosted, residual

    @staticmethod
    def update(boosted: Any, transmitted: Any) -> Any:
        return jax.tree.map(lambda b, t: b - t.astype(jnp.float32),
                            boosted, transmitted)
