"""AdamW with warmup+cosine schedule, global-norm clipping, fp32 state.

State is a plain pytree (m, v mirror the params; count scalar), so it packs
straight into the bridge's :mod:`repro.core.zero_bridge` pools and into the
checkpointer without adapters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import OptimConfig


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class AdamWState:
    m: Any
    v: Any
    count: jax.Array


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                      count=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: OptimConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: OptimConfig, grads: Any, state: AdamWState,
                 params: Any) -> tuple[Any, AdamWState, dict]:
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    lr = lr_schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(m=new_m, v=new_v, count=count), metrics
