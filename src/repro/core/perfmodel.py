"""Analytical model of the bridge datapath.

Two uses:

1. **Paper validation** — reproduce the published prototype numbers from
   first principles: 134-cycle / 800 ns flit round trip, the 1280 MiB/s
   transceiver ceiling of Fig. 3 (the paper computes 10 Gb/s with binary
   prefixes: 10·2^30 b/s ÷ 8 = 1280 MiB/s), STREAM remote *copy* at
   ~562 MiB/s on one core (−47 % vs. local), saturation beyond 2 cores and
   the −25 % penalty for the FLOP-carrying kernels.  Tests pin these.

2. **TPU projection** — the same pipeline model with TPU v5e constants
   (819 GB/s HBM, ~50 GB/s/link ICI, ~1.5 µs hop latency, page-granular
   transfers) to predict pull-mode bridge throughput, cross-checked against
   the dry-run roofline collective term in ``benchmarks/``.

Model: a STREAM-like loop iterates { move B bytes, do F flops } on each of C
masters.  Memory time and compute time do **not** overlap on the in-order A53
prototype (the paper's penalty shrinking from 47 % to 25 % with added FLOPs
pins this), so

    t_iter(location) = B / bw_mem(location, C)  +  F * t_flop
    bw_app = B / t_iter

Remote memory behind the bridge sustains ``outstanding`` cache lines in
flight per master (edge buffering) against an ``rtt`` pipeline, capped by the
serial link:

    bw_mem(remote, C) = min(C * outstanding * line / rtt, link_payload_bw)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

# STREAM kernels: name -> (bytes per iteration, flops per iteration)
STREAM_KERNELS: Dict[str, tuple[int, int]] = {
    "copy": (16, 0),
    "scale": (16, 1),
    "add": (24, 1),
    "triad": (24, 2),
}

MIB = float(1 << 20)


@dataclass(frozen=True)
class BridgeHW:
    """Hardware constants for the pipeline model."""

    clock_mhz: float = 167.5          # bridge clock (134 cyc == 800 ns)
    rtt_cycles: int = 134             # paper: data-flit round trip
    link_gbps_binary: float = 10.0    # serial link, binary-prefix Gb/s
    line_bytes: int = 64              # transfer granule (cache line)
    outstanding: float = 7.37         # in-flight lines/master (edge buffer
                                      # depth; calibrated: 562 MiB/s copy)
    local_bw_per_core_mibps: float = 1060.0  # calibrated: copy −47 % penalty
    local_bw_cap_mibps: float = 3600.0       # DDR ceiling (4 cores)
    flop_time_ns: float = 23.9        # scalar FP chain on the in-order A53
                                      # (calibrated: −25 % scale penalty)

    @property
    def rtt_ns(self) -> float:
        return self.rtt_cycles / self.clock_mhz * 1e3

    @property
    def link_payload_mibps(self) -> float:
        # The paper quotes 10 Gb/s as 10 * 2^30 / 8 bytes/s = 1280 MiB/s.
        return self.link_gbps_binary * 1024.0 / 8.0


PAPER_HW = BridgeHW()


def mem_bandwidth_mibps(hw: BridgeHW, cores: int, remote: bool) -> float:
    """Raw memory-system bandwidth seen by ``cores`` concurrent masters."""
    if remote:
        per_core = hw.outstanding * hw.line_bytes / (hw.rtt_ns * 1e-9) / MIB
        return min(cores * per_core, hw.link_payload_mibps)
    return min(cores * hw.local_bw_per_core_mibps, hw.local_bw_cap_mibps)


def stream_bandwidth_mibps(kernel: str, cores: int, remote: bool,
                           hw: BridgeHW = PAPER_HW) -> float:
    """Application-perceived STREAM bandwidth (the bars of Fig. 3)."""
    bytes_per_iter, flops = STREAM_KERNELS[kernel]
    bw_mem = mem_bandwidth_mibps(hw, cores, remote) * MIB  # B/s, aggregate
    t_mem = bytes_per_iter / (bw_mem / cores)              # per-core share
    t_iter = t_mem + flops * hw.flop_time_ns * 1e-9        # serial (in-order)
    return cores * bytes_per_iter / t_iter / MIB


def stream_table(hw: BridgeHW = PAPER_HW,
                 max_cores: int = 4) -> Dict[str, Dict[str, list[float]]]:
    """Fig. 3 reproduction: kernel -> {local: [c1..c4], remote: [...]}."""
    out: Dict[str, Dict[str, list[float]]] = {}
    for kernel in STREAM_KERNELS:
        out[kernel] = {
            "local": [stream_bandwidth_mibps(kernel, c, False, hw)
                      for c in range(1, max_cores + 1)],
            "remote": [stream_bandwidth_mibps(kernel, c, True, hw)
                       for c in range(1, max_cores + 1)],
        }
    return out


def penalty(kernel: str, cores: int, hw: BridgeHW = PAPER_HW) -> float:
    """Remote-vs-local application penalty (paper: 47 % copy, ~25 % scale)."""
    loc = stream_bandwidth_mibps(kernel, cores, False, hw)
    rem = stream_bandwidth_mibps(kernel, cores, True, hw)
    return 1.0 - rem / loc


# ---------------------------------------------------------------------------
# Latency pipeline breakdown (paper: 134 cycles round trip)
# ---------------------------------------------------------------------------

#: Stage budget for one data-flit round trip, in bridge cycles.  The paper
#: publishes only the total (134); the split below is the prototype's design
#: partition used for the breakdown table in ``benchmarks/bridge_latency.py``.
RTT_PIPELINE_CYCLES: Dict[str, int] = {
    "master mux / edge buffer in": 8,
    "request preparation & steering (memport)": 10,
    "serdes TX (clock-domain cross + 66b encode)": 24,
    "circuit network flight": 12,
    "remote demux / arbiter": 8,
    "remote slave access (DDR)": 30,
    "serdes RX (return path)": 24,
    "reorder / edge buffer out": 10,
    "master channel demux": 8,
}
assert sum(RTT_PIPELINE_CYCLES.values()) == 134


# ---------------------------------------------------------------------------
# TPU projection
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TpuHW:
    peak_bf16_tflops: float = 197.0
    hbm_gbps: float = 819.0           # GB/s per chip
    ici_link_gbps: float = 50.0       # GB/s per link per direction
    ici_links: int = 4                # torus links usable for one transfer
    ici_hop_latency_us: float = 1.5
    outstanding_pages: int = 8        # DMA queue depth (edge buffer analogue)


TPU_HW = TpuHW()


def tpu_remote_page_bandwidth_gbps(page_bytes: int, hops: int = 1,
                                   hw: TpuHW = TPU_HW) -> float:
    """Pull-mode sustained GB/s per node pair through the bridge."""
    rtt_s = 2 * hops * hw.ici_hop_latency_us * 1e-6
    wire = hw.ici_link_gbps * 1e9  # one circuit = one link direction
    t_page = page_bytes / wire
    # ``outstanding_pages`` in flight against the RTT (edge buffering):
    eff = hw.outstanding_pages * page_bytes / (rtt_s + hw.outstanding_pages * t_page)
    return min(eff, wire) / 1e9


def route_epoch_stats(program) -> Dict[str, int]:
    """Accounting view of a :class:`~repro.core.steering.RouteProgram`.

    ``num_epochs`` is the circuit-switching depth (bidirectional programs
    pair a clockwise and a counter-clockwise circuit per epoch, so it drops
    from N-1 to ⌊N/2⌋); ``total_hops`` drives latency, ``live_slots`` the
    wired-circuit count after pruning.
    """
    import numpy as np
    live = np.asarray(program.live)
    off = np.asarray(program.offsets)
    hops = np.abs(off)
    return {
        "num_nodes": int(program.num_nodes),
        "num_epochs": int(program.num_epochs()),
        "live_slots": int(live.sum()),
        "cw_slots": int((live & (off > 0)).sum()),
        "ccw_slots": int((live & (off < 0)).sum()),
        "total_hops": int(hops[live].sum()) if live.any() else 0,
        "max_hops": int(hops[live].max()) if live.any() else 0,
    }


def hierarchical_route_stats(program, topology) -> Dict[str, int]:
    """Tier-aware accounting of a program on a board + rack fabric.

    Hop counts follow the :mod:`repro.core.topology` realization contract
    (per served (rank, slot) pairing), so a flat program's topology-blind
    direction choices show up as extra board hops here.
    """
    import numpy as np
    served = program.rank_served()
    off = np.asarray(program.offsets)
    n = program.num_nodes
    board = rack = 0
    max_board = max_rack = 0
    inter_slots = 0
    for k in range(n - 1):
        ranks = np.nonzero(served[k])[0]
        if ranks.size == 0:
            continue
        homes = (ranks + k + 1) % n
        sign = 1 if off[k] > 0 else -1
        bh, rh = topology.pair_hops(ranks, homes, sign)
        board += int(bh.sum())
        rack += int(rh.sum())
        max_board = max(max_board, int(bh.max()))
        max_rack = max(max_rack, int(rh.max()))
        if (~topology.pair_intra(ranks, homes)).any():
            inter_slots += 1
    return {
        "num_groups": int(topology.num_groups),
        "num_epochs": int(program.num_epochs()),
        "board_hops": board,
        "rack_hops": rack,
        "max_board_hops": max_board,
        "max_rack_hops": max_rack,
        "gateway_slots": inter_slots,
    }


def predict_round_bytes(program, page_bytes: int, budget: int,
                        slot_pages=None) -> float:
    """Wire bytes one bridge round moves under a route program.

    Worst case (every live slot moves ``budget`` pages) or, with
    ``slot_pages``, the measured/intended per-slot loads.  The ref oracle's
    summed ``slot_bytes`` must equal this exactly whenever the request load
    matches ``slot_pages`` — the byte-conservation invariant pinned by
    ``tests/test_perfmodel.py``.
    """
    return float(_slot_loads(program, budget, slot_pages).sum() * page_bytes)


def _slot_loads(program, budget: int, slot_pages):
    import numpy as np
    live = np.asarray(program.live)
    if slot_pages is None:
        return np.where(live, float(budget), 0.0)
    pages = np.asarray(slot_pages, float).reshape(-1)
    if pages.shape != live.shape:
        raise ValueError(f"slot_pages has shape {pages.shape}; program "
                         f"has {live.shape[0]} slots")
    return np.where(live, pages, 0.0)


def _overlap_round_us(wire_us: float, rtt_us: float, channels: int) -> float:
    """The pipelined round engine's overlap term.

    The serial engine (``channels == 1``) exposes the full wire time *plus*
    the deepest circuit's RTT: the wire idles while the round's last data
    flits fly home, and the RTT idles while the wire drains.  Splitting the
    round into ``channels`` chunks overlaps chunk g+1's request flits with
    chunk g's data flits, so the smaller of (wire, RTT) hides behind the
    larger — except the pipeline's fill and drain, which expose 1/channels
    of the hidden term:

        t(C) = max(wire, rtt) + min(wire, rtt) / C

    ``C=1`` degenerates to ``wire + rtt`` exactly (the classic serial
    model); ``C -> inf`` approaches the fully-overlapped ``max(wire, rtt)``.
    """
    return max(wire_us, rtt_us) + min(wire_us, rtt_us) / max(channels, 1)


def predict_round_latency_us(program, page_bytes: int, budget: int,
                             hw: TpuHW = TPU_HW, edge_buffer: bool = True,
                             slot_pages=None, topology=None,
                             slot_intra_pages=None,
                             channels: int = 1) -> float:
    """Predicted latency of one bridge round under a route program.

    Each live slot is one circuit: RTT = 2 * hops * hop latency, payload =
    ``budget`` pages over one link direction.  Bufferless bridges serialize
    circuits end to end; edge-buffered bridges overlap them, bounded by the
    busier direction's wire occupancy (circuits of one direction share that
    direction's links) plus the deepest circuit's RTT.

    ``channels > 1`` prices the pipelined multi-channel round engine
    (:func:`repro.core.bridge.pull_pages` ``channels=``): the round's RTT
    exposure shrinks by the :func:`_overlap_round_us` overlap term, since
    chunk g+1's request flits fly while chunk g's data flits are still in
    the air.  ``channels=1`` degenerates bit-for-bit to the classic serial
    model, and a bufferless bridge never overlaps (the engine runs serial
    there), so ``edge_buffer=False`` ignores ``channels``.

    ``slot_pages`` switches from the worst-case assumption (every live slot
    moves a full ``budget`` of pages) to *measured* per-slot loads — e.g.
    ``TelemetryAggregator.distance_pages()`` normalized to one round — which
    is what makes a telemetry-compiled
    :func:`~repro.core.steering.load_balanced_program` comparable against
    the static bidirectional split under the observed traffic matrix.

    With a multi-board ``topology`` the model becomes tier-aware (the
    :mod:`repro.core.topology` realization contract):

    * a slot's **intra-board** pages ride that board's local ring — boards
      transfer concurrently, so their wire time divides by the board count
      and is paid at the board-tier link rate;
    * its **board-crossing** pages funnel through the single-ported
      gateways at the rack-tier link rate — their wire time serializes
      across slots;
    * RTTs weight board and rack hops by their own per-hop latencies.

    ``slot_intra_pages`` (e.g. ``TelemetryAggregator.distance_intra_pages``
    normalized like ``slot_pages``) pins the measured tier split; without
    it each slot's load is split by the fraction of its served requester
    ranks whose pair stays on-board.  A flat (single-board) topology —
    or ``topology=None`` — reproduces the classic flat model.
    """
    import numpy as np
    live = np.asarray(program.live)
    off = np.asarray(program.offsets)
    hops = np.abs(off)
    if not live.any():
        return 0.0
    pages = _slot_loads(program, budget, slot_pages)
    if topology is None or topology.num_groups == 1:
        wire_us = pages * page_bytes / (hw.ici_link_gbps * 1e9) * 1e6
        rtt_us = 2.0 * hops * hw.ici_hop_latency_us
        if not edge_buffer:
            return float((rtt_us[live] + wire_us[live]).sum())
        cw_us = float(wire_us[live & (off > 0)].sum())
        ccw_us = float(wire_us[live & (off < 0)].sum())
        if channels <= 1:
            return float(max(cw_us, ccw_us) + rtt_us[live].max())
        return float(_overlap_round_us(max(cw_us, ccw_us),
                                       float(rtt_us[live].max()), channels))

    n = program.num_nodes
    served = program.rank_served()
    s = n - 1
    if slot_intra_pages is None:
        frac = np.zeros((s,))
        for k in range(s):
            ranks = np.nonzero(served[k])[0]
            if ranks.size:
                frac[k] = topology.pair_intra(
                    ranks, (ranks + k + 1) % n).mean()
        intra_pages = pages * frac
    else:
        intra_pages = np.minimum(
            _slot_loads(program, budget, slot_intra_pages), pages)
    inter_pages = pages - intra_pages
    board_wire = (intra_pages / topology.num_groups * page_bytes
                  / (topology.board_link_gbps * 1e9) * 1e6)
    rack_wire = (inter_pages * page_bytes
                 / (topology.rack_link_gbps * 1e9) * 1e6)
    rtt_us = np.zeros((s,))
    for k in np.nonzero(live)[0]:
        ranks = np.nonzero(served[k])[0]
        if ranks.size == 0:
            continue
        homes = (ranks + k + 1) % n
        sign = 1 if off[k] > 0 else -1
        bh, rh = topology.pair_hops(ranks, homes, sign)
        pair_rtt = bh * topology.board_hop_us + rh * topology.rack_hop_us
        # Only tiers that actually move pages pin the slot's circuit depth
        # (an unloaded gateway pairing costs nothing this round).
        intra = topology.pair_intra(ranks, homes)
        depth = 0.0
        if intra.any() and intra_pages[k] > 0:
            depth = float(pair_rtt[intra].max())
        if (~intra).any() and inter_pages[k] > 0:
            depth = max(depth, float(pair_rtt[~intra].max()))
        rtt_us[k] = 2.0 * depth
    if not edge_buffer:
        return float((rtt_us[live] + board_wire[live]
                      + rack_wire[live]).sum())
    cw_us = float(board_wire[live & (off > 0)].sum())
    ccw_us = float(board_wire[live & (off < 0)].sum())
    if channels <= 1:
        return float(max(cw_us, ccw_us) + rack_wire[live].sum()
                     + rtt_us[live].max())
    # Both tiers' wire occupancy pipelines against the deepest RTT alike.
    return float(_overlap_round_us(
        max(cw_us, ccw_us) + float(rack_wire[live].sum()),
        float(rtt_us[live].max()), channels))


def predict_transfer_latency_us(program, page_bytes: int, budget: int,
                                num_requests: int, hw: TpuHW = TPU_HW,
                                edge_buffer: bool = True, slot_pages=None,
                                topology=None, slot_intra_pages=None,
                                channels: int = 1,
                                overprovision: int = 1) -> float:
    """Predicted completion latency of a whole transfer (all its rounds).

    The bridge serves ``num_requests`` pages per requester in
    ``steering.num_rounds`` rounds of ``budget`` lanes; each round costs
    :func:`predict_round_latency_us` under the given loads.  This is the
    admission-control currency of the orchestrator: a tenant's SLO bounds
    the completion latency of its per-step window, and co-located windows
    shift ``slot_pages``/``num_requests`` — the model prices the shift
    without touching the datapath.
    """
    from repro.core import steering
    rounds = steering.num_rounds(num_requests, budget, overprovision)
    if rounds == 0:
        return 0.0
    return rounds * predict_round_latency_us(
        program, page_bytes, budget, hw=hw, edge_buffer=edge_buffer,
        slot_pages=slot_pages, topology=topology,
        slot_intra_pages=slot_intra_pages, channels=channels)


# ---------------------------------------------------------------------------
# Online calibration (measured spans -> fitted constants)
# ---------------------------------------------------------------------------

#: Feature order of :func:`route_features` / :class:`Calibrator.theta`:
#: each coefficient is a physical constant in microseconds (per hop RTT,
#: per wire MiB, per channel chunk, per transfer call).
FEATURE_NAMES = ("board_hop_rtts", "rack_hop_rtts", "wire_mib", "chunks",
                 "transfers")


def route_features(program, page_bytes: int, budget: int, *,
                   rounds: int = 1, channels: int = 1, slot_pages=None,
                   topology=None, slot_intra_pages=None):
    """Linearized route-stats feature vector for one whole transfer.

    The serial analytic model is linear in its hardware constants:
    ``t = hop_latency * (2 * deepest_hops) + (us/MiB) * busier_wire_MiB``.
    This extracts exactly those multiplicities — per tier — plus the two
    software terms the analytic model omits and measurement exposes
    (per channel-chunk dispatch cost, per-call fixed cost):

        x = [ rounds * 2 * deepest board hops,
              rounds * 2 * deepest rack hops,
              rounds * busier-direction wire MiB (board/groups + rack),
              rounds * channels,
              1 ]

    so ``theta . x`` with ``theta = [board_hop_us, rack_hop_us, us_per_mib,
    chunk_us, base_us]`` prices the transfer.  With the static-constant
    prior (:meth:`Calibrator.static_theta`) and ``channels=1`` on a flat
    topology this reproduces ``rounds * predict_round_latency_us`` bit for
    bit — the calibrator *starts* at the static model and RLS walks it to
    the measured one.
    """
    import numpy as np
    live = np.asarray(program.live)
    off = np.asarray(program.offsets)
    x = np.zeros(len(FEATURE_NAMES))
    x[3] = float(rounds * max(channels, 1))
    x[4] = 1.0
    if not live.any() or rounds == 0:
        x[3] = x[4] = 0.0
        return x
    pages = _slot_loads(program, budget, slot_pages)
    if topology is None or topology.num_groups == 1:
        hops = np.abs(off)
        x[0] = rounds * 2.0 * float(hops[live].max())
        cw = float(pages[live & (off > 0)].sum())
        ccw = float(pages[live & (off < 0)].sum())
        x[2] = rounds * max(cw, ccw) * page_bytes / MIB
        return x
    n = program.num_nodes
    served = program.rank_served()
    s = n - 1
    if slot_intra_pages is None:
        frac = np.zeros((s,))
        for k in range(s):
            ranks = np.nonzero(served[k])[0]
            if ranks.size:
                frac[k] = topology.pair_intra(
                    ranks, (ranks + k + 1) % n).mean()
        intra_pages = pages * frac
    else:
        intra_pages = np.minimum(
            _slot_loads(program, budget, slot_intra_pages), pages)
    inter_pages = pages - intra_pages
    board_deep = rack_deep = 0.0
    for k in np.nonzero(live)[0]:
        ranks = np.nonzero(served[k])[0]
        if ranks.size == 0 or pages[k] == 0:
            continue
        homes = (ranks + k + 1) % n
        sign = 1 if off[k] > 0 else -1
        bh, rh = topology.pair_hops(ranks, homes, sign)
        board_deep = max(board_deep, float(bh.max()))
        rack_deep = max(rack_deep, float(rh.max()))
    x[0] = rounds * 2.0 * board_deep
    x[1] = rounds * 2.0 * rack_deep
    bw = intra_pages / topology.num_groups * page_bytes / MIB
    cw = float(bw[live & (off > 0)].sum())
    ccw = float(bw[live & (off < 0)].sum())
    x[2] = rounds * (max(cw, ccw)
                     + float(inter_pages[live].sum()) * page_bytes / MIB)
    return x


class Calibrator:
    """Recursive-least-squares fit of the bridge's latency constants.

    Observes ``(route_features, measured span latency)`` pairs — the
    tracing plane's fenced wall-clock spans — and maintains
    ``theta = [board_hop_us, rack_hop_us, us_per_wire_MiB, chunk_us,
    base_us]`` with a standard RLS update (optional forgetting factor for
    drift).  ``theta`` starts at the **static** constants of ``hw`` (zero
    software overhead), so an unfitted calibrator degenerates to the
    static model; each observation moves it toward what the fabric
    actually does.

    ``hw()`` repackages the fitted hop latency / payload bandwidth as a
    :class:`TpuHW`, so the *full* analytic model (tier pricing, overlap
    term) runs with fitted constants — that is what
    ``ControlPlane.select_channels`` and the orchestrator's window refits
    consume each control period, alongside ``chunk_overhead_us`` for the
    dispatch cost the static model never knew about.
    """

    def __init__(self, hw: TpuHW = TPU_HW, *, forgetting: float = 1.0,
                 p0: float = 1e8, min_samples: int = 3):
        import numpy as np
        self.base_hw = hw
        self.forgetting = float(forgetting)
        self.min_samples = int(min_samples)
        self.theta = self.static_theta(hw)
        self._P = np.eye(len(FEATURE_NAMES)) * float(p0)
        self.samples = 0
        self.last_error_us = 0.0

    @staticmethod
    def static_theta(hw: TpuHW = TPU_HW):
        import numpy as np
        us_per_mib = MIB / (hw.ici_link_gbps * 1e9) * 1e6
        return np.array([hw.ici_hop_latency_us, hw.ici_hop_latency_us,
                         us_per_mib, 0.0, 0.0])

    # ------------------------------------------------------------------ fit
    def observe(self, features, measured_us: float) -> float:
        """One RLS step; returns the pre-update prediction error (us)."""
        import numpy as np
        x = np.asarray(features, float).reshape(-1)
        if x.shape[0] != len(FEATURE_NAMES):
            raise ValueError(f"expected {len(FEATURE_NAMES)} features, "
                             f"got {x.shape[0]}")
        lam = self.forgetting
        Px = self._P @ x
        k = Px / (lam + float(x @ Px))
        err = float(measured_us) - float(self.theta @ x)
        self.theta = self.theta + k * err
        self._P = (self._P - np.outer(k, Px)) / lam
        self.samples += 1
        self.last_error_us = err
        return err

    def reset_covariance(self, p0: float = 1e8) -> None:
        """Re-open the RLS gain after detected drift.

        Keeps ``theta`` (the current best fit) but re-inflates the
        covariance, so the next observations move the fit as fast as a
        cold start — the drift sentinel (:mod:`repro.obs.detect`) calls
        this when the windowed residual shows the fabric no longer
        matches the fitted constants.
        """
        import numpy as np
        self._P = np.eye(len(FEATURE_NAMES)) * float(p0)

    @property
    def fitted(self) -> bool:
        return self.samples >= self.min_samples

    # -------------------------------------------------------------- predict
    def predict_us(self, features) -> float:
        import numpy as np
        return max(float(self.theta @ np.asarray(features, float)), 0.0)

    def static_predict_us(self, features) -> float:
        """Same linear basis priced with the static prior constants."""
        import numpy as np
        return max(float(self.static_theta(self.base_hw)
                         @ np.asarray(features, float)), 0.0)

    def predict_round_latency_us(self, program, page_bytes: int,
                                 budget: int, **kw) -> float:
        return self.predict_us(route_features(
            program, page_bytes, budget, rounds=1, **kw))

    def predict_transfer_latency_us(self, program, page_bytes: int,
                                    budget: int, num_requests: int,
                                    overprovision: int = 1, **kw) -> float:
        from repro.core import steering
        rounds = steering.num_rounds(num_requests, budget, overprovision)
        return self.predict_us(route_features(
            program, page_bytes, budget, rounds=rounds, **kw))

    # ------------------------------------------------------------ constants
    @property
    def chunk_overhead_us(self) -> float:
        return max(float(self.theta[3]), 0.0)

    @property
    def base_overhead_us(self) -> float:
        return max(float(self.theta[4]), 0.0)

    def link_payload_gbps(self) -> float:
        us_per_mib = max(float(self.theta[2]), 1e-9)
        return MIB / (us_per_mib * 1e-6) / 1e9

    def hw(self) -> TpuHW:
        """Fitted constants as a TpuHW for the full analytic model."""
        from dataclasses import replace
        return replace(
            self.base_hw,
            ici_hop_latency_us=max(float(self.theta[0]), 1e-6),
            ici_link_gbps=max(self.link_payload_gbps(), 1e-6))

    def constants(self) -> Dict[str, float]:
        vals = {n: round(float(v), 6)
                for n, v in zip(FEATURE_NAMES, self.theta)}
        vals["link_payload_gbps"] = round(self.link_payload_gbps(), 6)
        vals["samples"] = self.samples
        return vals


def tpu_stream_penalty(kernel: str, page_bytes: int = 1 << 18,
                       hw: TpuHW = TPU_HW) -> float:
    """Paper Fig. 3 analogue on TPU: HBM-local vs bridge-remote STREAM."""
    bytes_per_iter, flops = STREAM_KERNELS[kernel]
    local_bw = hw.hbm_gbps * 1e9
    remote_bw = tpu_remote_page_bandwidth_gbps(page_bytes, hw=hw) * 1e9
    # VPU flop time is negligible at STREAM intensity; memory dominates both.
    t_loc = bytes_per_iter / local_bw + flops / (hw.peak_bf16_tflops * 1e12)
    t_rem = bytes_per_iter / remote_bw + flops / (hw.peak_bf16_tflops * 1e12)
    return 1.0 - t_loc / t_rem
