"""Pooled memory: the disaggregated "slave" side of the bridge.

A :class:`MemoryPool` is a page array sharded over one mesh axis (the *mem*
axis).  Each node on that axis contributes ``pages_per_node`` slots of
``page_elems`` elements — its HBM plays the role of the remote DDR controller
in the paper's prototype.  The pool is pure functional state: writes return a
new pool (donated under jit).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class MemoryPool:
    """pages: [num_nodes * pages_per_node, page_elems], sharded on dim 0."""

    pages: jax.Array

    def node_view(self, num_nodes: int) -> jax.Array:
        """[num_nodes, pages_per_node, page_elems] view (for shard_map)."""
        total, elems = self.pages.shape
        return self.pages.reshape(num_nodes, total // num_nodes, elems)


def make_pool(num_nodes: int, pages_per_node: int, page_elems: int,
              dtype=jnp.bfloat16, mesh: Optional[Mesh] = None,
              mem_axis: str = "data") -> MemoryPool:
    shape = (num_nodes * pages_per_node, page_elems)
    if mesh is not None and mem_axis in mesh.axis_names:
        sharding = NamedSharding(mesh, P(mem_axis, None))
        pages = jax.device_put(jnp.zeros(shape, dtype), sharding)
    else:
        pages = jnp.zeros(shape, dtype)
    return MemoryPool(pages=pages)


def pool_spec(mem_axis: str = "data") -> P:
    return P(mem_axis, None)


def write_local(pool: MemoryPool, flat_slots: jax.Array,
                payload: jax.Array) -> MemoryPool:
    """Scatter pages into the pool by flat (node-major) slot index."""
    safe = jnp.where(flat_slots >= 0, flat_slots, pool.pages.shape[0])
    pages = pool.pages.at[safe].set(payload.astype(pool.pages.dtype),
                                    mode="drop")
    return replace(pool, pages=pages)


def read_local(pool: MemoryPool, flat_slots: jax.Array) -> jax.Array:
    valid = flat_slots >= 0
    safe = jnp.where(valid, flat_slots, 0)
    out = pool.pages[safe]
    return jnp.where(valid[:, None], out, jnp.zeros_like(out))
