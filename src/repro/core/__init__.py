"""The paper's contribution: a software-defined memory bus bridge, on JAX.

Layers (see DESIGN.md §3):
  memport        — runtime-reprogrammable translation/steering tables (Fig. 2)
  pool           — pooled page memory sharded over the mem axis (the slaves)
  topology       — static board + rack fabric description (two tiers)
  steering       — request preparation: distances, rounds, route schedules
                   (flat and hierarchical circuit programs)
  bridge         — the transfer engine: ring-circuit ppermute epochs,
                   rate limiting, edge buffering (Fig. 1)
  control_plane  — orchestrator: allocation, elastic remap, stragglers
  kvbridge       — disaggregated KV cache (case study at pod scale)
  zero_bridge    — disaggregated optimizer state
  perfmodel      — analytical datapath model (paper Fig. 3 + TPU projection)
  ref            — pure-jnp oracles for everything above
"""
from repro.core.memport import FREE, MemPortTable  # noqa: F401
from repro.core.pool import MemoryPool, make_pool  # noqa: F401
from repro.core.topology import Topology  # noqa: F401
from repro.core.bridge import pull_pages, push_pages  # noqa: F401
from repro.core.control_plane import ControlPlane  # noqa: F401
