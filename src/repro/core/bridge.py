"""The bridge transfer engine: epoch-batched circuit transfers over a mesh axis.

This is the paper's datapath (Fig. 1) mapped onto a TPU pod:

* *time-multiplexing* — requests are coalesced into rounds of ``budget`` pages
  (the software rate limiter; ``active_budget`` can be lowered at **runtime**
  without recompiling, the remaining requests spill into later rounds);
* *request preparation & steering* — each request is translated through the
  :class:`~repro.core.memport.MemPortTable` and assigned to the datapath slot
  equal to its ring distance (a circuit = one static ``ppermute`` route);
* *software-defined circuit scheduling* — **which** slots are wired, in which
  physical ring direction, and at which circuit epoch is a runtime
  :class:`~repro.core.steering.RouteProgram` input compiled by the control
  plane: unidirectional (the historical fixed ring), bidirectional
  (min(d, N-d) shortest-way routing: ⌊N/2⌋ epochs instead of N-1), pruned to
  the distances that actually carry traffic, link-avoiding after a ring
  failure, or **hierarchical** for a board + rack fabric
  (:class:`~repro.core.topology.Topology`): the program's per-rank group
  mask splits every offset between its same-board requesters (concurrent
  local-ring circuits) and its board-crossing ones (exclusive gateway
  epochs).  Programs have fixed static shapes, so swapping them between
  steps — flat for hierarchical, like re-programming the memport table or
  lowering ``active_budget`` — never triggers a retrace;
* *serDES + circuit network* — one ``jax.lax.ppermute`` pair per live slot:
  request ids travel ``rank -> rank+d``, payload returns ``rank+d -> rank``.
  Every slot's wire permutation is **static** (circuit switching; note the
  +d and -(N-d) circuits are the *same permutation*, so direction is pure
  steering data), only the *contents* are runtime values.  Dead slots carry
  FREE requests, so their gather/scatter payload work is masked out;
* *edge buffering* — live slots within a round are independent dataflow
  chains, so the compiler overlaps them exactly like the paper's decoupled
  serdes clock domains pulling from edge buffers.  ``edge_buffer=False``
  inserts ``optimization_barrier`` between consecutive slots — starting
  from the epoch-0 loopback access — to model a bufferless bridge (a
  conservative serialization: it ignores the program's epoch pairing,
  which only affects the analytical cost model);
* *pipelined multi-channel rounds* — ``channels > 1`` splits each round's
  ``budget`` lanes into ``channels`` virtual channels and software-pipelines
  the scan body: chunk *g+1*'s **request flits** (the ``ppermute`` of slot
  ids) are issued while chunk *g*'s **data flits** are still in flight, a
  double-buffered carry of the in-flight ``(pending_req, pending_payload)``
  state with an epilogue chunk draining the pipeline.  Results and
  telemetry are bit-exact vs the serial engine for every ``channels`` (the
  pipeline reorders wire traffic, never what is served); ``channels=1`` *is*
  the serial engine, and a bufferless bridge (``edge_buffer=False``) has no
  buffers to hold overlapped flits, so it always runs serial;
* *lossless, no ack/retx* — ICI collectives are lossless and deterministic,
  so the assumption holds natively;
* *fused datapath* — ``fused=True`` (the default) replaces the per-slot
  mask → dynamic-slice gather → payload-commit op chain *and* the
  2·(N-1)·channels ``ppermute`` ladder with one epoch-batched engine: per
  round, one ``all_gather`` broadcasts every node's request window, the
  Pallas gather kernel (:func:`repro.kernels.bridge_gather.gather_pages`)
  serves all slots from the local pool shard, the payloads return through
  the exchange lowering picked by :func:`_fused_exchange_mode` (one
  ``all_to_all`` on TPU; one backward ``ppermute`` hop per slot off-TPU,
  where XLA's all-to-all emulation is copy-pathological), and the round
  commits without a per-slot select chain
  (:func:`~repro.kernels.bridge_gather.pull_commit` /
  :func:`~repro.kernels.bridge_gather.push_commit`, pool buffer donated
  via ``input_output_aliases``; an add-tree over the landed rows in
  ladder mode) — serve conditions, gather and commit fused exactly as the
  paper couples the transceiver datapath to the circuit network.  Pages
  and telemetry are bit-exact vs ``fused=False`` (the unfused chain stays
  as the escape hatch, and a bufferless bridge always runs the unfused
  serial engine — serialization barriers are the point there);
* *in-band telemetry* — ``collect_telemetry=True`` additionally returns a
  :class:`~repro.telemetry.counters.BridgeTelemetry` of per-slot served
  counts, spills, pruned drops and a traffic-matrix row, computed as masked
  integer sums with static shapes (swapping programs with collection on
  never retraces); the control plane closes the loop on it.  A per-request
  ``tenant_ids`` lane (runtime input, same shape as the request list)
  additionally attributes every outcome to its tenant in static
  ``[max_tenants]`` histograms — the measurement the orchestrator's
  multi-tenant QoS scheduler re-fits its budget shares from.

All functions exist in two forms: a ``*_local`` body to be used inside
``shard_map`` (N nodes on the mem axis) and a reference oracle in
``repro.core.ref`` used by tests (the oracle honours arbitrary programs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.memport import FREE, MemPortTable
from repro.core import ref as _ref
from repro.core import steering
from repro.core.steering import RouteProgram
from repro.core.topology import Topology, TopoTables
from repro.kernels import bridge_gather as _bg
from repro.telemetry import counters as _telemetry


def shard_map(f, mesh, in_specs, out_specs, mem_axis=None):
    """jax.shard_map, manual ONLY over ``mem_axis`` (others stay auto).

    Partial-manual mode keeps the model axis under GSPMD control inside the
    body, so head/ff dims keep their automatic sharding (and non-divisible
    head counts keep working) while the bridge runs manual collectives over
    the mem axis.  check_vma must be True: the check_vma=False path in jax
    0.8 rebuilds specs over *all* mesh axes and rejects partial manual.
    """
    names = frozenset({mem_axis}) if mem_axis else frozenset(mesh.axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=names,
                             check_vma=True)
    # jax < 0.5: shard_map lives in jax.experimental and partial-manual mode
    # (``auto``) is not usable (eager raises NotImplementedError, the jit
    # path trips over PartitionId SPMD lowering).  Every bridge body is
    # replicated over the non-mem axes anyway (specs never mention them), so
    # go full-manual over all axes; replication checking (check_rep)
    # predates VMA typing — disable it, the bridge's replicated inputs
    # (table, program) are genuinely replicated.
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


def use_mesh(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on current jax; on jax < 0.5 a Mesh is itself the
    context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# ---------------------------------------------------------------------------
# shard_map bodies
# ---------------------------------------------------------------------------

def _pvary(x: jax.Array, axis: str) -> jax.Array:
    """Mark ``x`` as varying over ``axis`` (VMA typing for scan carries).

    jax < 0.5 has no VMA typing (and no ``jax.lax.pcast``): no-op there.
    Where pcast exists, real errors must surface, not be swallowed.
    """
    if not hasattr(jax.lax, "pcast"):
        return x
    return jax.lax.pcast(x, axis, to="varying")


def _gather_local(pool_local: jax.Array, slots: jax.Array) -> jax.Array:
    """Masked local gather: FREE slots produce zeros."""
    valid = slots >= 0
    safe = jnp.where(valid, slots, 0)
    out = pool_local[safe]
    mask = valid.reshape(valid.shape + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, jnp.zeros_like(out))


def _scatter_local(pool_local: jax.Array, slots: jax.Array,
                   payload: jax.Array) -> jax.Array:
    # FREE slots are routed out of bounds and dropped: a where-fallback would
    # scatter stale values onto slot 0 and race with live writes there.
    safe = jnp.where(slots >= 0, slots, pool_local.shape[0])
    return pool_local.at[safe].set(payload.astype(pool_local.dtype),
                                   mode="drop")


def _round_pull(pool_local: jax.Array, sub_ids: jax.Array, table: MemPortTable,
                program: RouteProgram, axis: str, num_nodes: int,
                edge_buffer: bool) -> jax.Array:
    """Serve one round of <=budget requests; returns [budget, *page_shape]."""
    my = jax.lax.axis_index(axis)
    home, slot = table.translate(sub_ids)
    dist = steering.ring_distance(home, my, num_nodes)

    # Epoch 0: loopback fast path (locally mapped region — no circuit hop).
    out = _gather_local(pool_local, jnp.where(dist == 0, slot, FREE))

    # A bufferless bridge serializes everything the datapath does in a
    # round, *including* the epoch-0 loopback access: chain it into the
    # barrier chain so the first circuit slot cannot launch under it.
    prev = out
    for k, d in enumerate(steering.default_route_schedule(num_nodes)):
        # Runtime steering: slot k carries traffic only if the program wires
        # it *for this rank* (the group mask — a hierarchical program may
        # serve an offset's same-board requesters while cutting its
        # board-crossing ones).  Dead pairings move FREE requests, so their
        # payload gathers are masked to zeros and their pages dropped.
        serve = ((dist == d) & program.live[k]
                 & (program.rank_epoch[k, my] >= 0))
        req = jnp.where(serve, slot, FREE)                         # [B]
        if not edge_buffer:
            # A bufferless bridge serializes slots: model it explicitly.
            req, prev = jax.lax.optimization_barrier((req, prev))
        fwd = [(j, (j + d) % num_nodes) for j in range(num_nodes)]
        bwd = [(j, (j - d) % num_nodes) for j in range(num_nodes)]
        # obs:* scopes tag each phase's HLO ops (metadata op_name) so
        # compiled-program attribution (obs.trace.phase_op_counts) can
        # apportion a round's dispatch cost per phase.
        with jax.named_scope("obs:wire_req"):
            req_at_home = jax.lax.ppermute(req, axis, perm=fwd)    # request flits
        with jax.named_scope("obs:gather"):
            payload = _gather_local(pool_local, req_at_home)       # remote read
        with jax.named_scope("obs:wire_data"):
            payload = jax.lax.ppermute(payload, axis, perm=bwd)    # data flits
        with jax.named_scope("obs:commit"):
            mask = serve.reshape((-1,) + (1,) * (payload.ndim - 1))
            out = jnp.where(mask, payload, out)
        prev = payload
    return out


# ---------------------------------------------------------------------------
# Pipelined multi-channel round engine (channels > 1)
# ---------------------------------------------------------------------------
#
# The serial engine completes every epoch of round r before round r+1 issues
# a single flit — the RTT of the deepest circuit is paid once per round with
# the wire idle underneath it.  The paper couples serial transceivers to a
# circuit network precisely so multiple outstanding transactions share the
# wire; the pipelined engine reproduces that in software: each round's budget
# splits into ``channels`` chunks, and while chunk g's data flits are still
# in flight, chunk g+1's request flits are already on the wire.  The carry is
# the classic double buffer — the in-flight (pending_req, pending_payload)
# state — and an epilogue chunk drains the pipeline after the scan.

def _pull_wire(pool_local: jax.Array, sub_ids: jax.Array, table: MemPortTable,
               program: RouteProgram, axis: str, num_nodes: int, my):
    """Request phase of one chunk: issue every live slot's request flits.

    Returns the in-flight pipeline state (the double-buffered carry): the
    request flits landed at their homes [S, cb], the serve masks [S, cb]
    and the epoch-0 loopback pages [cb, *page_shape] (local, no flit).
    """
    home, slot = table.translate(sub_ids)
    dist = steering.ring_distance(home, my, num_nodes)
    out0 = _gather_local(pool_local, jnp.where(dist == 0, slot, FREE))
    reqs, serves = [], []
    for k, d in enumerate(steering.default_route_schedule(num_nodes)):
        serve = ((dist == d) & program.live[k]
                 & (program.rank_epoch[k, my] >= 0))
        req = jnp.where(serve, slot, FREE)
        fwd = [(j, (j + d) % num_nodes) for j in range(num_nodes)]
        with jax.named_scope("obs:wire_req"):
            reqs.append(jax.lax.ppermute(req, axis, perm=fwd))
        serves.append(serve)
    return jnp.stack(reqs), jnp.stack(serves), out0


def _pull_drain(pool_local: jax.Array, pending, axis: str,
                num_nodes: int) -> jax.Array:
    """Data phase of one chunk: serve the in-flight request flits.

    Remote reads against the landed requests, returning data flits, merged
    over the chunk's loopback pages.  FREE in-flight requests (the pipeline
    prologue, dead slots) gather zeros and are masked out.
    """
    reqs, serves, out = pending
    for k, d in enumerate(steering.default_route_schedule(num_nodes)):
        bwd = [(j, (j - d) % num_nodes) for j in range(num_nodes)]
        with jax.named_scope("obs:gather"):
            payload = _gather_local(pool_local, reqs[k])           # remote read
        with jax.named_scope("obs:wire_data"):
            payload = jax.lax.ppermute(payload, axis, perm=bwd)    # data flits
        with jax.named_scope("obs:commit"):
            mask = serves[k].reshape((-1,) + (1,) * (payload.ndim - 1))
            out = jnp.where(mask, payload, out)
    return out


def _reassemble(chunks: jax.Array, want_len: int, lanes_per_round: int,
                active_budget: jax.Array, page_shape, dtype) -> jax.Array:
    """Re-assemble served round lanes into logical request order.

    ``chunks`` is [rounds * lanes_per_round, *page_shape] in (round, lane)
    order.  Round ``r`` served ``want[r*active_budget + k]`` in lane ``k``
    (k < active_budget); lanes beyond the live budget (and the pipelined
    engine's chunk padding) carried FREE requests and are dropped.
    """
    with jax.named_scope("obs:commit"):
        idx = jnp.arange(chunks.shape[0])
        r = idx // lanes_per_round
        k = idx % lanes_per_round
        dest = r * active_budget + k
        live = (k < active_budget) & (dest < want_len)
        dest = jnp.where(live, dest, 0)
        mask = live.reshape((-1,) + (1,) * len(page_shape))
        upd = jnp.where(mask, chunks, jnp.zeros_like(chunks))
        out = jnp.zeros((want_len,) + page_shape, dtype)
        return out.at[dest].add(upd)


def _pull_local(pool_local: jax.Array, want: jax.Array, table: MemPortTable,
                active_budget: jax.Array, program: RouteProgram, *, axis: str,
                num_nodes: int, budget: int, rounds: int,
                edge_buffer: bool, channels: int = 1,
                fused: bool = False) -> jax.Array:
    """Pull ``want`` pages ([rounds*budget], FREE-padded) through the bridge.

    Returns [want.shape[0], *page_shape]; requests the rate limiter never
    reaches (``rounds == 0``, spilled tails) come back as zeros.

    ``channels > 1`` runs the pipelined multi-channel engine (see the
    module docstring); 1 is the serial engine.  A bufferless bridge or a
    1-node ring has nothing to overlap — both always run serial.

    ``fused`` runs the epoch-batched fused engine instead
    (:func:`_pull_local_fused`): one collective pair + one Pallas kernel
    pair per round, bit-exact vs both unfused engines.  A bufferless
    bridge has no edge buffers to land a whole round's flits in, so it
    always runs the unfused serial engine.
    """
    want = want.reshape(-1)
    page_shape = pool_local.shape[1:]
    if rounds == 0:
        # All-dropped, correctly shaped: the docstring's contract even when
        # a caller hands a non-empty ``want`` to a zero-round transfer.
        return jnp.zeros((want.shape[0],) + page_shape, pool_local.dtype)
    # Clamp the (runtime) rate limiter to the lane budget: an overdriven
    # ``active_budget`` would walk ``ptr`` past the final round's window and
    # make ``dynamic_slice`` silently re-serve tail requests.
    active_budget = jnp.clip(active_budget, 0, budget)
    if fused and num_nodes > 1 and edge_buffer:
        return _pull_local_fused(
            pool_local, want, table, active_budget, program, axis=axis,
            num_nodes=num_nodes, budget=budget, rounds=rounds,
            channels=channels)
    pipelined = channels > 1 and num_nodes > 1 and edge_buffer

    if not pipelined:
        def body(ptr, _):
            # Rate limiter: only the first ``active_budget`` slots of this
            # round carry live requests; the pointer advances by the same
            # amount, so a throttled node simply uses more of its
            # (overprovisioned) rounds.
            sub = jax.lax.dynamic_slice(want, (ptr,), (budget,))
            lane = jnp.arange(budget)
            sub = jnp.where((lane < active_budget)
                            & (ptr + lane < want.shape[0]), sub, FREE)
            out = _round_pull(pool_local, sub, table, program, axis,
                              num_nodes, edge_buffer)
            return ptr + active_budget, out

        ptr0 = _pvary(jnp.int32(0), axis)
        _, chunks = jax.lax.scan(body, ptr0, None, length=rounds)
        return _reassemble(chunks.reshape(rounds * budget, *page_shape),
                           want.shape[0], budget, active_budget, page_shape,
                           pool_local.dtype)

    # Pipelined engine: rounds split into ``channels`` chunks of ``cb``
    # lanes; the scan body issues chunk g+1's request flits, then drains
    # chunk g's data flits (still in flight from the previous step) — the
    # double-buffered carry.  Emission is therefore shifted by one chunk:
    # the first emission is the pipeline prologue (all-FREE, dropped) and an
    # epilogue drain after the scan yields the final chunk.
    my = jax.lax.axis_index(axis)
    cb = -(-budget // channels)
    lane = jnp.arange(channels * cb)
    nslots = num_nodes - 1

    def empty_pending():
        return tuple(_pvary(x, axis) for x in (
            jnp.full((nslots, cb), FREE, jnp.int32),
            jnp.zeros((nslots, cb), bool),
            jnp.zeros((cb,) + page_shape, pool_local.dtype)))

    def body(carry, _):
        ptr, pending = carry
        window = jax.lax.dynamic_slice(want, (ptr,), (budget,))
        if channels * cb > budget:
            window = jnp.concatenate(
                [window, jnp.full((channels * cb - budget,), FREE,
                                  want.dtype)])
        window = jnp.where((lane < active_budget)
                           & (ptr + lane < want.shape[0]), window, FREE)
        outs = []
        for c in range(channels):
            inflight = _pull_wire(pool_local, window[c * cb:(c + 1) * cb],
                                  table, program, axis, num_nodes, my)
            outs.append(_pull_drain(pool_local, pending, axis, num_nodes))
            pending = inflight
        return (ptr + active_budget, pending), jnp.stack(outs)

    ptr0 = _pvary(jnp.int32(0), axis)
    (_, pending), chunks = jax.lax.scan(body, (ptr0, empty_pending()), None,
                                        length=rounds)
    last = _pull_drain(pool_local, pending, axis, num_nodes)   # epilogue
    flat = chunks.reshape((rounds * channels, cb) + page_shape)
    flat = jnp.concatenate([flat[1:], last[None]], 0)          # un-shift
    return _reassemble(flat.reshape((rounds * channels * cb,) + page_shape),
                       want.shape[0], channels * cb, active_budget,
                       page_shape, pool_local.dtype)


# ---------------------------------------------------------------------------
# Fused round engine (Pallas datapath kernels + epoch-batched wire rounds)
# ---------------------------------------------------------------------------
#
# The unfused engines move every circuit slot's flits as a separate
# ``ppermute`` pair — 2*(N-1) collectives per round (per chunk when
# pipelined), each a sync point, with per-slot gather/merge ops
# materializing an intermediate between them.  The fused engine batches a
# round's *entire* request traffic into one collective and collapses the
# node-local datapath into the :mod:`repro.kernels.bridge_gather` kernels:
#
#   1. ONE ``all_gather`` ships every node's request window [n, L] (the
#      round's request flits, all slots and channels together);
#   2. every node re-derives the steering for the requesters it serves from
#      the replicated table/program (pure local compute — the request
#      preparation unit runs where the data lives) and serves all slots in
#      :func:`~repro.kernels.bridge_gather.gather_pages` grids;
#   3. the payload flits return via the exchange lowering picked by
#      :func:`_fused_exchange_mode` — ONE ``all_to_all`` ("a2a": node h's
#      row j carries the pages it served for requester j; on the push
#      path, a second ``all_gather`` lands the write payloads), or one
#      backward ``ppermute`` hop per slot ("ladder");
#   4. the round retires without a per-slot select chain: in "a2a" mode
#      the ``pull_commit`` / ``push_commit`` kernel merges loopback +
#      landed payloads in one grid (pool buffer donated on push); in
#      "ladder" mode the schedule wires every distance to exactly one slot
#      and unserved lanes carry zero flits, so the pull commit is a pure
#      add-tree over the landed rows.
#
# Collective count per round drops from 2*(N-1)*channels to 2 ("a2a") or
# N ("ladder"), independent of pipeline depth; results and telemetry stay
# bit-exact vs the unfused engines (same serve conditions, same commit
# order — the fused round only batches wire traffic, never changes what is
# served).  With every channel's lanes riding the same collectives, the
# channels knob no longer multiplies dispatch overhead.

# Payload-exchange pattern for the fused pull engine: "a2a" batches every
# slot's data flits into one ``all_to_all``; "ladder" rotates each slot's
# row home with one ``ppermute`` hop.  Both are bit-exact; see
# :func:`_fused_exchange_mode` for the selection policy.
_FUSED_EXCHANGE: str | None = None


def _fused_exchange_mode() -> str:
    """Pick the fused pull engine's payload-exchange lowering.

    On TPU the single ``all_to_all`` is the whole point — one collective
    retires every slot's data flits.  XLA:CPU's all-to-all emulation is
    copy-pathological at large payloads (measured ~9x a ppermute ladder
    moving identical bytes at 256 KiB pages), so off-TPU the ladder wins
    wire-bound rounds while staying well under the unfused engine's
    2*(N-1) collectives (it drops the request ppermutes and the per-slot
    merge chain).  ``_FUSED_EXCHANGE`` overrides for A/B measurement.
    """
    if _FUSED_EXCHANGE is not None:
        return _FUSED_EXCHANGE
    return "a2a" if jax.default_backend() == "tpu" else "ladder"


def _fused_steering(allwin: jax.Array, table: MemPortTable,
                    program: RouteProgram, my, num_nodes: int):
    """Re-derive every node's steering from the replicated control plane.

    allwin: [n, L] the round's gathered request windows.  Returns
    (requester ring ranks [S], per-slot served pool rows [S, L] with FREE
    on unserved lanes) for the slots *this* node serves: slot k's
    requester sits at ring distance d_k behind us.
    """
    home_all, slot_all = table.translate(allwin)
    reqs, requesters = [], []
    for k, d in enumerate(steering.default_route_schedule(num_nodes)):
        requester = jnp.mod(my - d, num_nodes)
        dist = steering.ring_distance(home_all[requester], requester,
                                      num_nodes)
        serve = ((dist == d) & program.live[k]
                 & (program.rank_epoch[k, requester] >= 0))
        reqs.append(jnp.where(serve, slot_all[requester], FREE))
        requesters.append(requester)
    return jnp.stack(requesters), jnp.stack(reqs)


def _fused_window(want: jax.Array, ptr, budget: int, lanes: int, lane,
                  active_budget) -> jax.Array:
    """One round's request window, padded to ``lanes`` and rate-limited."""
    window = jax.lax.dynamic_slice(want, (ptr,), (budget,))
    if lanes > budget:
        window = jnp.concatenate(
            [window, jnp.full((lanes - budget,), FREE, want.dtype)])
    return jnp.where((lane < active_budget)
                     & (ptr + lane < want.shape[0]), window, FREE)


def _pull_local_fused(pool_local: jax.Array, want: jax.Array,
                      table: MemPortTable, active_budget: jax.Array,
                      program: RouteProgram, *, axis: str, num_nodes: int,
                      budget: int, rounds: int, channels: int) -> jax.Array:
    """Fused pull engine: 2 collectives + 2 kernels per round (see above)."""
    page_shape = pool_local.shape[1:]
    cb = -(-budget // channels)
    lanes = channels * cb
    lane = jnp.arange(lanes)
    sched = steering.default_route_schedule(num_nodes)
    my = jax.lax.axis_index(axis)
    pool2, _, _e = _bg._flatten_pages(pool_local)
    exchange = _fused_exchange_mode()

    def body(ptr, _):
        window = _fused_window(want, ptr, budget, lanes, lane, active_budget)
        with jax.named_scope("obs:wire_req"):
            allwin = jax.lax.all_gather(window, axis)          # request flits
        src_rows, reqs = _fused_steering(allwin, table, program, my,
                                         num_nodes)
        home, slot = table.translate(window)
        dist = steering.ring_distance(home, my, num_nodes)
        loop_slot = jnp.where(dist == 0, slot, FREE)
        if exchange == "a2a":
            # Payload flits: node h's send row j is what it served for
            # requester j.  Steering the *request ids* into exchange row
            # order (a [n, lanes] int scatter) lets the gather kernel emit
            # payloads straight into the ``all_to_all`` layout — no
            # full-size zeros + payload-scatter materialization around the
            # collective.  Requester j then finds slot k's pages in the
            # row of its serving home (j + d_k), so the commit kernel's
            # per-lane choice indexes ``recv`` rows directly.
            reqs_by_row = jnp.full((num_nodes, lanes), FREE, jnp.int32)
            reqs_by_row = reqs_by_row.at[src_rows].set(reqs)
            with jax.named_scope("obs:gather"):
                send = _bg.gather_pages(pool2, reqs_by_row)    # [n, lanes, e]
            with jax.named_scope("obs:wire_data"):
                recv = jax.lax.all_to_all(send, axis, 0, 0)
            choice = jnp.where(dist == 0, 0, -1)
            for k, d in enumerate(sched):
                serve = ((dist == d) & program.live[k]
                         & (program.rank_epoch[k, my] >= 0))
                choice = jnp.where(serve, jnp.mod(my + d, num_nodes) + 1,
                                   choice)
            with jax.named_scope("obs:commit"):
                out = _bg.pull_commit(pool2, recv, choice, loop_slot)
        else:
            # Rotation ladder: slot k's send lanes are ``reqs[k]`` verbatim
            # (what we serve for the requester d_k behind us), so each
            # slot's gathered flits ppermute straight back by distance.
            # The schedule wires every distance to exactly one slot and
            # unserved lanes gather zero flits, so the commit merge
            # degenerates to an add-tree over the landed rows + the
            # epoch-0 loopback gather — no staged exchange buffer, no
            # per-slot select chain, and XLA fuses the whole tree into a
            # single output pass.
            with jax.named_scope("obs:gather"):
                out = _bg.gather_pages(pool2, loop_slot)
            for k, d in enumerate(sched):
                with jax.named_scope("obs:gather"):
                    flit = _bg.gather_pages(pool2, reqs[k])
                with jax.named_scope("obs:wire_data"):
                    flit = jax.lax.ppermute(
                        flit, axis,
                        perm=[(j, (j - d) % num_nodes)
                              for j in range(num_nodes)])
                with jax.named_scope("obs:commit"):
                    out = out + flit
        return ptr + active_budget, out

    ptr0 = _pvary(jnp.int32(0), axis)
    _, chunks = jax.lax.scan(body, ptr0, None, length=rounds)
    return _reassemble(
        chunks.reshape((rounds * lanes,) + page_shape), want.shape[0],
        lanes, active_budget, page_shape, pool_local.dtype)


def _push_local_fused(pool_local: jax.Array, ids: jax.Array, pay: jax.Array,
                      table: MemPortTable, active_budget: jax.Array,
                      program: RouteProgram, *, axis: str, num_nodes: int,
                      budget: int, rounds: int, channels: int) -> jax.Array:
    """Fused push engine: batched data flits + 1 commit kernel per round.

    The write payloads travel batched — one ``all_gather`` in "a2a"
    exchange mode (every node lands the full round of data flits), one
    forward ``ppermute`` hop per slot in "ladder" mode (the same bytes the
    unfused engine moves, without its request-flit collectives) — and the
    round retires in a single
    :func:`~repro.kernels.bridge_gather.push_commit` grid against the
    **donated** pool shard, walking the serial engine's commit order.
    """
    cb = -(-budget // channels)
    lanes = channels * cb
    lane = jnp.arange(lanes)
    sched = steering.default_route_schedule(num_nodes)
    my = jax.lax.axis_index(axis)
    pool2, _, e = _bg._flatten_pages(pool_local)
    nrows = pool2.shape[0]
    pay2 = pay.reshape(pay.shape[0], e)
    exchange = _fused_exchange_mode()

    def body(carry, _):
        pool_pad, ptr = carry
        window = _fused_window(ids, ptr, budget, lanes, lane, active_budget)
        dwin = jax.lax.dynamic_slice(pay2, (ptr, 0), (budget, e))
        if lanes > budget:
            dwin = jnp.concatenate(
                [dwin, jnp.zeros((lanes - budget, e), pay2.dtype)])
        with jax.named_scope("obs:wire_req"):
            allwin = jax.lax.all_gather(window, axis)          # request flits
        src_rows, slots = _fused_steering(allwin, table, program, my,
                                          num_nodes)
        if exchange == "a2a":
            with jax.named_scope("obs:wire_data"):
                alldata = jax.lax.all_gather(dwin, axis)       # data flits
            landed = alldata[src_rows]                         # [S, lanes, e]
        else:
            # Rotation ladder: requester j's flits for distance d land at
            # home (j + d) in one forward hop — slot k's landed data is
            # the window of the requester d_k behind us, no full-fabric
            # broadcast or landed-row re-gather.
            with jax.named_scope("obs:wire_data"):
                landed = jnp.stack([
                    jax.lax.ppermute(
                        dwin, axis,
                        perm=[(j, (j + d) % num_nodes)
                              for j in range(num_nodes)])
                    for d in sched])
        home, slot = table.translate(window)
        dist = steering.ring_distance(home, my, num_nodes)
        loop_slots = jnp.where(dist == 0, slot, FREE)
        slots_all = jnp.concatenate([loop_slots[None], slots])  # [S+1, lanes]
        with jax.named_scope("obs:commit"):
            pool_pad = _bg.push_commit(pool_pad, slots_all, dwin, landed,
                                       channels=channels, cb=cb)
        return (pool_pad, ptr + active_budget), None

    ptr0 = _pvary(jnp.int32(0), axis)
    (pool_pad, _), _ = jax.lax.scan(
        body, (_bg.pad_pool(pool2), ptr0), None, length=rounds)
    return pool_pad[:nrows].reshape(pool_local.shape)


def _push_wire(sub_ids: jax.Array, data: jax.Array, table: MemPortTable,
               program: RouteProgram, axis: str, num_nodes: int, my):
    """Request phase of one push chunk: launch slot-id + payload flits.

    Push flits travel together in the request direction; the in-flight
    carry is (slots landed at home [S, cb], payload landed at home
    [S, cb, *page], loopback slots [cb], loopback payload [cb, *page]).
    """
    home, slot = table.translate(sub_ids)
    dist = steering.ring_distance(home, my, num_nodes)
    slots_h, datas_h = [], []
    for k, d in enumerate(steering.default_route_schedule(num_nodes)):
        serve = ((dist == d) & program.live[k]
                 & (program.rank_epoch[k, my] >= 0))
        req = jnp.where(serve, slot, FREE)
        fwd = [(j, (j + d) % num_nodes) for j in range(num_nodes)]
        with jax.named_scope("obs:wire_req"):
            slots_h.append(jax.lax.ppermute(req, axis, perm=fwd))
        with jax.named_scope("obs:wire_data"):
            datas_h.append(jax.lax.ppermute(data, axis, perm=fwd))
    return (jnp.stack(slots_h), jnp.stack(datas_h),
            jnp.where(dist == 0, slot, FREE), data)


def _push_commit(pool: jax.Array, pending) -> jax.Array:
    """Commit phase of one push chunk: scatter the landed flits home.

    Loopback first, then slots in order — the serial engine's write order,
    so the pipelined pool image is identical under the single-writer
    contract.  FREE slots (pipeline prologue, dead pairings) drop.
    """
    slots_h, datas_h, loop_slots, loop_data = pending
    with jax.named_scope("obs:commit"):
        pool = _scatter_local(pool, loop_slots, loop_data)
        for k in range(slots_h.shape[0]):
            pool = _scatter_local(pool, slots_h[k], datas_h[k])
        return pool


def _push_local(pool_local: jax.Array, dest_ids: jax.Array, payload: jax.Array,
                table: MemPortTable, active_budget: jax.Array,
                program: RouteProgram, *, axis: str, num_nodes: int,
                budget: int, rounds: int, edge_buffer: bool = True,
                channels: int = 1, fused: bool = False) -> jax.Array:
    """Write payload pages to their homes (single-writer contract).

    Rate-limiter parity with :func:`_pull_local`: each round writes only the
    first ``active_budget`` lanes and the pointer advances by the same
    amount, so requests past ``rounds * active_budget`` spill off the end of
    the (overprovisioned) round budget and are dropped.  ``edge_buffer`` and
    ``channels`` carry the same semantics as on the pull path: a bufferless
    bridge serializes the wire (loopback commit chained under the first
    slot's flits), and ``channels > 1`` pipelines chunk g+1's request/data
    flits over chunk g's commits (serial when bufferless or 1-node).
    ``fused`` batches each round into one collective pair + one donated
    commit kernel (:func:`_push_local_fused`; unfused-serial fallback when
    bufferless).
    """
    my = jax.lax.axis_index(axis)
    page_shape = pool_local.shape[1:]
    ids = dest_ids.reshape(-1)
    pay = payload.reshape((-1,) + page_shape)
    if rounds == 0:
        return pool_local
    active_budget = jnp.clip(active_budget, 0, budget)  # see _pull_local
    if fused and num_nodes > 1 and edge_buffer:
        return _push_local_fused(
            pool_local, ids, pay, table, active_budget, program, axis=axis,
            num_nodes=num_nodes, budget=budget, rounds=rounds,
            channels=channels)
    pipelined = channels > 1 and num_nodes > 1 and edge_buffer

    if not pipelined:
        def body(carry, _):
            pool, ptr = carry
            sub = jax.lax.dynamic_slice(ids, (ptr,), (budget,))
            data = jax.lax.dynamic_slice(
                pay, (ptr,) + (0,) * len(page_shape), (budget,) + page_shape)
            lane = jnp.arange(budget)
            sub = jnp.where((lane < active_budget)
                            & (ptr + lane < ids.shape[0]), sub, FREE)
            home, slot = table.translate(sub)
            dist = steering.ring_distance(home, my, num_nodes)
            pool = _scatter_local(pool, jnp.where(dist == 0, slot, FREE),
                                  data)
            prev = pool
            for k, d in enumerate(steering.default_route_schedule(num_nodes)):
                fwd = [(j, (j + d) % num_nodes) for j in range(num_nodes)]
                serve = ((dist == d) & program.live[k]
                         & (program.rank_epoch[k, my] >= 0))
                req = jnp.where(serve, slot, FREE)
                data_k = data
                if not edge_buffer:
                    # Bufferless: slot k's flits leave only after slot k-1's
                    # (and the epoch-0 loopback commit) — see _round_pull.
                    req, data_k, prev = jax.lax.optimization_barrier(
                        (req, data_k, prev))
                with jax.named_scope("obs:wire_req"):
                    slot_at_home = jax.lax.ppermute(req, axis, perm=fwd)
                with jax.named_scope("obs:wire_data"):
                    data_at_home = jax.lax.ppermute(data_k, axis, perm=fwd)
                with jax.named_scope("obs:commit"):
                    pool = _scatter_local(pool, slot_at_home, data_at_home)
                prev = data_at_home
            return (pool, ptr + active_budget), None

        ptr0 = _pvary(jnp.int32(0), axis)
        (pool_local, _), _ = jax.lax.scan(body, (pool_local, ptr0), None,
                                          length=rounds)
        return pool_local

    # Pipelined engine (mirror of _pull_local): issue chunk g+1's flits,
    # then commit chunk g's (carried in flight), epilogue commits the last.
    cb = -(-budget // channels)
    lane = jnp.arange(channels * cb)
    nslots = num_nodes - 1

    def empty_pending():
        return tuple(_pvary(x, axis) for x in (
            jnp.full((nslots, cb), FREE, jnp.int32),
            jnp.zeros((nslots, cb) + page_shape, pool_local.dtype),
            jnp.full((cb,), FREE, jnp.int32),
            jnp.zeros((cb,) + page_shape, pool_local.dtype)))

    def body(carry, _):
        pool, ptr, pending = carry
        window = jax.lax.dynamic_slice(ids, (ptr,), (budget,))
        dwin = jax.lax.dynamic_slice(
            pay, (ptr,) + (0,) * len(page_shape), (budget,) + page_shape)
        if channels * cb > budget:
            window = jnp.concatenate(
                [window, jnp.full((channels * cb - budget,), FREE,
                                  ids.dtype)])
            dwin = jnp.concatenate(
                [dwin, jnp.zeros((channels * cb - budget,) + page_shape,
                                 pay.dtype)])
        window = jnp.where((lane < active_budget)
                           & (ptr + lane < ids.shape[0]), window, FREE)
        for c in range(channels):
            inflight = _push_wire(window[c * cb:(c + 1) * cb],
                                  dwin[c * cb:(c + 1) * cb], table, program,
                                  axis, num_nodes, my)
            pool = _push_commit(pool, pending)
            pending = inflight
        return (pool, ptr + active_budget, pending), None

    ptr0 = _pvary(jnp.int32(0), axis)
    (pool_local, _, pending), _ = jax.lax.scan(
        body, (pool_local, ptr0, empty_pending()), None, length=rounds)
    return _push_commit(pool_local, pending)                   # epilogue


# ---------------------------------------------------------------------------
# Public API (shard_map wrappers)
# ---------------------------------------------------------------------------

def _mem_axis_size(mesh: Optional[Mesh], axis: str) -> int:
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def _resolve_program(program: Optional[RouteProgram],
                     num_nodes: int) -> RouteProgram:
    """Default program (full bidirectional coverage) + static shape check."""
    if program is None:
        return steering.bidirectional_program(num_nodes)
    if program.num_slots != num_nodes - 1:
        raise ValueError(
            f"route program has {program.num_slots} slots; a {num_nodes}-node "
            f"ring needs {num_nodes - 1}")
    return program


def _resolve_topology(topology: Optional[Topology],
                      num_nodes: int) -> Topology:
    """Default (flat single-board) fabric + node-count check.

    The topology is **static**: its tables enter the jitted datapath as
    constants, so a deployment's fabric shape never appears in the jit
    cache key — only a topology *change* retraces (as it must: it is a
    different machine).
    """
    if topology is None:
        return Topology.flat(num_nodes)
    if topology.num_nodes != num_nodes:
        raise ValueError(
            f"topology spans {topology.num_nodes} endpoints; the bridge has "
            f"{num_nodes}")
    return topology


def _loopback_telemetry(ids: jax.Array, table: MemPortTable,
                        program: Optional[RouteProgram], tn: int,
                        active_budget, budget: int, rounds: int,
                        topology: Optional[Topology],
                        tenant_ids: Optional[jax.Array] = None,
                        max_tenants: int = _telemetry.DEFAULT_MAX_TENANTS
                        ) -> _telemetry.BridgeTelemetry:
    """Telemetry for the 1-device path: row i of ``ids`` is logical
    requester i; the whole batch shares ``active_budget``'s first element
    (mirroring the loopback rate limiter)."""
    prog = _resolve_program(program, tn)
    topo = _resolve_topology(topology, tn)
    tt = topo.tables()
    ab = jnp.clip(jnp.asarray(active_budget).reshape(-1)[0], 0, budget)
    rows = ids.reshape((-1, ids.shape[-1]))
    if tenant_ids is None:
        tenant_ids = jnp.zeros_like(ids)
    trows = tenant_ids.reshape((-1, tenant_ids.shape[-1]))

    def per_row(row, my, trow):
        return _telemetry.transfer_telemetry(
            row, table, prog, ab, my=my, num_nodes=tn, budget=budget,
            rounds=rounds, topo=tt, num_groups=topo.num_groups,
            tenant_ids=trow, max_tenants=max_tenants)

    return jax.vmap(per_row)(rows, jnp.arange(rows.shape[0]), trows)


def _telemetry_specs(mem_axis: str) -> _telemetry.BridgeTelemetry:
    """shard_map out_specs for per-node telemetry (leading node dim)."""
    return _telemetry.BridgeTelemetry(
        slot_served=P(mem_axis, None), loopback_served=P(mem_axis),
        spilled=P(mem_axis), pruned=P(mem_axis), traffic=P(mem_axis, None),
        epoch_cw=P(mem_axis, None), epoch_ccw=P(mem_axis, None),
        slot_intra=P(mem_axis, None), tier_hops=P(mem_axis, None),
        tenant_served=P(mem_axis, None), tenant_spilled=P(mem_axis, None),
        tenant_pruned=P(mem_axis, None))


def _loopback_mask(flat: jax.Array, ids: jax.Array, table: MemPortTable,
                   program: Optional[RouteProgram], tn: int) -> jax.Array:
    """Apply a route program on the 1-device (loopback) fast path.

    The loopback circuit still models ``tn`` logical ring nodes: row i of
    ``ids`` is logical requester i, and requests whose logical ring distance
    has no wired circuit are dropped — identical semantics (and oracle) as
    the N-device path.
    """
    if program is None:
        return flat
    _resolve_program(program, tn)
    rows = ids.reshape((-1, ids.shape[-1]))
    served = _ref.served_mask(table, rows, program).reshape(-1)
    return jnp.where(served, flat, FREE)


def _resolve_channels(channels: int) -> int:
    if channels < 1:
        raise ValueError(f"channels must be >= 1, got {channels}")
    return int(channels)


def pull_pages(pool_pages: jax.Array, want: jax.Array, table: MemPortTable,
               *, mesh: Optional[Mesh], mem_axis: str = "data",
               budget: int = 8, edge_buffer: bool = True,
               channels: int = 1, overprovision: int = 1,
               active_budget: Optional[jax.Array] = None,
               program: Optional[RouteProgram] = None,
               table_nodes: int = 0, collect_telemetry: bool = False,
               topology: Optional[Topology] = None,
               tenant_ids: Optional[jax.Array] = None,
               max_tenants: int = 0, fused: bool = True):
    """Pull logical pages through the bridge.

    Args:
      pool_pages: [num_nodes * pages_per_node, *page_shape], sharded on dim 0
        over ``mem_axis`` (or unsharded when N == 1).
      want: [num_nodes, R] per-node request lists (logical page ids, FREE pad),
        sharded on dim 0.
      table: replicated memport table.
      program: runtime circuit schedule (default: full bidirectional
        coverage).  A **runtime input**: swapping unidirectional /
        bidirectional / pruned programs on a jitted caller never retraces.
      channels: pipeline depth of the round engine (static, like
        ``budget``).  1 = the serial engine; > 1 splits each round's budget
        into ``channels`` virtual channels and overlaps chunk g+1's request
        flits with chunk g's data flits (results and telemetry stay
        bit-exact — the pipeline reorders wire traffic, never what is
        served).  Ignored on the loopback path and under
        ``edge_buffer=False`` (a bufferless bridge cannot hold overlapped
        flits).
      table_nodes: logical node count of the table (0 = mesh size).  On a
        1-device mesh the pool may still model several logical memory nodes
        (loopback circuit); their slots flatten node-major.
      collect_telemetry: also return a per-node
        :class:`~repro.telemetry.counters.BridgeTelemetry` of what this
        transfer served/spilled/pruned.  The counters have static shapes, so
        with collection on, swapping programs / tables / budgets still never
        retraces (the flag itself is static: toggling it changes the output
        structure).
      topology: the static board + rack fabric
        (:class:`~repro.core.topology.Topology`, default: one flat board).
        Classifies each transfer's tier for the telemetry counters; its
        tables are compile-time constants, so flat and hierarchical
        *programs* swap on one trace.
      tenant_ids: optional [num_nodes, R] tenant-id lane aligned with
        ``want`` (a **runtime input**, like the table: swapping tenant
        shares / window compositions never retraces).  Attribution is
        observational — it bins the telemetry's per-tenant counters and
        never changes what is served.  None = all tenant 0; without
        ``collect_telemetry`` the lane is ignored entirely (never
        materialized on the hot path).
      max_tenants: static width of the per-tenant telemetry histograms
        (0 = the :data:`repro.telemetry.counters.DEFAULT_MAX_TENANTS`).
      fused: run each epoch through the fused Pallas datapath (default ON):
        serve-condition evaluation, the page gather and the payload commit
        collapse into one kernel pair per round, and the round's wire
        traffic batches into a single request ``all_gather`` plus the
        payload exchange (an ``all_to_all`` on TPU, one ``ppermute`` hop
        per slot off-TPU — :func:`_fused_exchange_mode`) instead of
        2·(N-1)·channels ``ppermute`` sync
        points.  Results and telemetry are bit-exact vs ``fused=False``
        (the escape hatch back to the unfused ppermute-chain engines); a
        bufferless bridge (``edge_buffer=False``) always runs unfused
        serial.  On the loopback path the fused gather runs as one
        :func:`~repro.kernels.bridge_gather.gather_pages` grid.
    Returns:
      [num_nodes, R, *page_shape] gathered pages, sharded on dim 0 — or
      ``(pages, telemetry)`` when ``collect_telemetry`` is set.
    """
    n = _mem_axis_size(mesh, mem_axis)
    channels = _resolve_channels(channels)
    if max_tenants <= 0:
        max_tenants = _telemetry.DEFAULT_MAX_TENANTS
    r = want.shape[-1]
    rounds = steering.num_rounds(r, budget, overprovision)
    if tenant_ids is not None and tenant_ids.shape != want.shape:
        raise ValueError(f"tenant_ids shape {tenant_ids.shape} != request "
                         f"shape {want.shape}")
    # The lane only feeds the telemetry counters: without collection it is
    # never materialized or threaded (no wasted operand on the hot path).
    if collect_telemetry and tenant_ids is None:
        tenant_ids = jnp.zeros(want.shape, jnp.int32)
    pad = rounds * budget - r
    if pad:
        want = jnp.concatenate(
            [want, jnp.full(want.shape[:-1] + (pad,), FREE, want.dtype)], -1)
        if collect_telemetry:
            tenant_ids = jnp.concatenate(
                [tenant_ids, jnp.zeros(tenant_ids.shape[:-1] + (pad,),
                                       tenant_ids.dtype)], -1)
    if active_budget is None:
        active_budget = jnp.int32(budget)

    if n == 1:
        tn = table_nodes or 1
        ppn = pool_pages.shape[0] // tn
        home, slot = table.translate(want.reshape(-1))
        flat = jnp.where(home >= 0, home * ppn + slot, FREE)
        # Rate-limiter parity with the N-device path: round ``r`` serves
        # request indices [r*ab, (r+1)*ab), so anything past rounds*ab spills
        # off the end of the (overprovisioned) round budget and is dropped.
        ab = jnp.clip(jnp.asarray(active_budget).reshape(-1)[0], 0, budget)
        idx = jnp.arange(want.shape[-1])
        served = jnp.broadcast_to(idx < rounds * ab, want.shape).reshape(-1)
        flat = jnp.where(served, flat, FREE)
        flat = _loopback_mask(flat, want, table, program, tn)
        if fused:
            out = _bg.gather_pages(pool_pages, flat)
        else:
            out = _gather_local(pool_pages, flat)
        out = out.reshape(want.shape + pool_pages.shape[1:])
        # Trim the round padding on the *request* dim (pages may be
        # multi-dimensional, so slice by position, not from the back).
        out = out[(slice(None),) * (want.ndim - 1) + (slice(0, r),)]
        if collect_telemetry:
            return out, _loopback_telemetry(want, table, program, tn,
                                            active_budget, budget, rounds,
                                            topology, tenant_ids, max_tenants)
        return out
    if table_nodes and table_nodes != n:
        raise ValueError(f"table has {table_nodes} nodes but mem axis "
                         f"{mem_axis!r} has {n}")
    program = _resolve_program(program, n)
    topo = _resolve_topology(topology, n)

    pages_spec = P(mem_axis, *([None] * (pool_pages.ndim - 1)))
    out_spec = P(mem_axis, *([None] * pool_pages.ndim))
    body = functools.partial(
        _pull_local, axis=mem_axis, num_nodes=n, budget=budget,
        rounds=rounds, edge_buffer=edge_buffer, channels=channels,
        fused=fused)
    ab_vec = jnp.clip(jnp.broadcast_to(active_budget, (n,)), 0, budget)

    def mapped(pool, want_l, table_l, ab, prog, tt, *ten_l):
        out = body(pool, want_l[0], table_l, ab[0], prog)
        if not collect_telemetry:
            return out[None]
        telem = _telemetry.transfer_telemetry(
            want_l[0], table_l, prog, ab[0],
            my=jax.lax.axis_index(mem_axis), num_nodes=n, budget=budget,
            rounds=rounds, topo=tt, num_groups=topo.num_groups,
            tenant_ids=ten_l[0][0], max_tenants=max_tenants)
        return out[None], jax.tree.map(lambda x: x[None], telem)

    out_specs = ((out_spec, _telemetry_specs(mem_axis))
                 if collect_telemetry else out_spec)
    in_specs = (pages_spec, P(mem_axis, None), P(), P(mem_axis), P(),
                TopoTables(group=P(), local_rank=P(), group_size=P()))
    args = (pool_pages, want, table, ab_vec, program, topo.tables())
    if collect_telemetry:
        in_specs += (P(mem_axis, None),)
        args += (tenant_ids,)
    out = shard_map(
        mapped, mesh, in_specs=in_specs, out_specs=out_specs,
        mem_axis=mem_axis,
    )(*args)
    if collect_telemetry:
        return out[0][:, :r], out[1]
    return out[:, :r]


def push_pages(pool_pages: jax.Array, dest: jax.Array, payload: jax.Array,
               table: MemPortTable, *, mesh: Optional[Mesh],
               mem_axis: str = "data", budget: int = 8,
               edge_buffer: bool = True, channels: int = 1,
               overprovision: int = 1,
               active_budget: Optional[jax.Array] = None,
               program: Optional[RouteProgram] = None,
               table_nodes: int = 0, collect_telemetry: bool = False,
               topology: Optional[Topology] = None,
               tenant_ids: Optional[jax.Array] = None,
               max_tenants: int = 0, fused: bool = True):
    """Write pages to their homes through the bridge (single-writer pages).

    Args:
      pool_pages: as in :func:`pull_pages` (returned updated).
      dest: [num_nodes, R] logical page ids each node writes.
      payload: [num_nodes, R, *page_shape].
      edge_buffer: as in :func:`pull_pages` — ``False`` models a bufferless
        bridge by serializing each round's wire activity (loopback commit,
        then slot after slot) with ``optimization_barrier``.
      channels: pipeline depth of the round engine, same semantics as in
        :func:`pull_pages` (chunk g+1's request/data flits overlap chunk
        g's commits; the pool image stays identical under the
        single-writer contract).
      active_budget: runtime rate limiter, same spill semantics as
        :func:`pull_pages`: each round writes only the first
        ``active_budget`` lanes, writes past ``rounds * active_budget``
        spill off the (overprovisioned) round budget and are dropped.
      program: runtime circuit schedule (default: full bidirectional
        coverage), same semantics as in :func:`pull_pages`.
      collect_telemetry: also return per-node write-path counters
        (:class:`~repro.telemetry.counters.BridgeTelemetry`).
      tenant_ids / max_tenants: per-request tenant attribution lane for the
        telemetry counters, same semantics as in :func:`pull_pages`.
      fused: run each epoch through the fused Pallas datapath, same
        semantics as in :func:`pull_pages` — on the write path the round's
        address flits batch into one ``all_gather``, data flits take the
        backend-picked payload exchange (an ``all_gather`` on TPU, one
        forward ``ppermute`` hop per slot off-TPU —
        :func:`_fused_exchange_mode`), and everything retires through one
        :func:`~repro.kernels.bridge_gather.push_commit` grid against the
        donated pool shard.
    """
    n = _mem_axis_size(mesh, mem_axis)
    channels = _resolve_channels(channels)
    if max_tenants <= 0:
        max_tenants = _telemetry.DEFAULT_MAX_TENANTS
    r = dest.shape[-1]
    rounds = steering.num_rounds(r, budget, overprovision)
    if tenant_ids is not None and tenant_ids.shape != dest.shape:
        raise ValueError(f"tenant_ids shape {tenant_ids.shape} != request "
                         f"shape {dest.shape}")
    if collect_telemetry and tenant_ids is None:
        tenant_ids = jnp.zeros(dest.shape, jnp.int32)
    pad = rounds * budget - r
    if pad:
        dest = jnp.concatenate(
            [dest, jnp.full(dest.shape[:-1] + (pad,), FREE, dest.dtype)], -1)
        if collect_telemetry:
            tenant_ids = jnp.concatenate(
                [tenant_ids, jnp.zeros(tenant_ids.shape[:-1] + (pad,),
                                       tenant_ids.dtype)], -1)
        zeros = jnp.zeros(payload.shape[:1] + (pad,) + payload.shape[2:],
                          payload.dtype)
        payload = jnp.concatenate([payload, zeros], 1)
    if active_budget is None:
        active_budget = jnp.int32(budget)

    if n == 1:
        tn = table_nodes or 1
        ppn = pool_pages.shape[0] // tn
        home, slot = table.translate(dest.reshape(-1))
        flat = jnp.where(home >= 0, home * ppn + slot, FREE)
        # Rate-limiter parity with the N-device path (see pull_pages).
        ab = jnp.clip(jnp.asarray(active_budget).reshape(-1)[0], 0, budget)
        idx = jnp.arange(dest.shape[-1])
        served = jnp.broadcast_to(idx < rounds * ab, dest.shape).reshape(-1)
        flat = jnp.where(served, flat, FREE)
        flat = _loopback_mask(flat, dest, table, program, tn)
        flat_pay = payload.reshape((-1,) + payload.shape[2:])
        if fused:
            out = _bg.scatter_pages(pool_pages, flat, flat_pay)
        else:
            out = _scatter_local(pool_pages, flat, flat_pay)
        if collect_telemetry:
            return out, _loopback_telemetry(dest, table, program, tn,
                                            active_budget, budget, rounds,
                                            topology, tenant_ids, max_tenants)
        return out
    if table_nodes and table_nodes != n:
        raise ValueError(f"table has {table_nodes} nodes but mem axis "
                         f"{mem_axis!r} has {n}")
    program = _resolve_program(program, n)
    topo = _resolve_topology(topology, n)

    pages_spec = P(mem_axis, *([None] * (pool_pages.ndim - 1)))
    body = functools.partial(_push_local, axis=mem_axis, num_nodes=n,
                             budget=budget, rounds=rounds,
                             edge_buffer=edge_buffer, channels=channels,
                             fused=fused)
    ab_vec = jnp.clip(jnp.broadcast_to(active_budget, (n,)), 0, budget)

    def mapped(pool, dest_l, pay_l, table_l, ab, prog, tt, *ten_l):
        out = body(pool, dest_l[0], pay_l[0], table_l, ab[0], prog)
        if not collect_telemetry:
            return out
        telem = _telemetry.transfer_telemetry(
            dest_l[0], table_l, prog, ab[0],
            my=jax.lax.axis_index(mem_axis), num_nodes=n, budget=budget,
            rounds=rounds, topo=tt, num_groups=topo.num_groups,
            tenant_ids=ten_l[0][0], max_tenants=max_tenants)
        return out, jax.tree.map(lambda x: x[None], telem)

    out_specs = ((pages_spec, _telemetry_specs(mem_axis))
                 if collect_telemetry else pages_spec)
    in_specs = (pages_spec, P(mem_axis, None),
                P(mem_axis, None, *([None] * (payload.ndim - 2))), P(),
                P(mem_axis), P(),
                TopoTables(group=P(), local_rank=P(), group_size=P()))
    args = (pool_pages, dest, payload, table, ab_vec, program, topo.tables())
    if collect_telemetry:
        in_specs += (P(mem_axis, None),)
        args += (tenant_ids,)
    return shard_map(
        mapped, mesh, in_specs=in_specs, out_specs=out_specs,
        mem_axis=mem_axis,
    )(*args)
