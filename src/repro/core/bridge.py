"""The bridge transfer engine: epoch-batched circuit transfers over a mesh axis.

This is the paper's datapath (Fig. 1) mapped onto a TPU pod:

* *time-multiplexing* — requests are coalesced into rounds of ``budget`` pages
  (the software rate limiter; ``active_budget`` can be lowered at **runtime**
  without recompiling, the remaining requests spill into later rounds);
* *request preparation & steering* — each request is translated through the
  :class:`~repro.core.memport.MemPortTable` and assigned to the datapath slot
  equal to its ring distance (a circuit = one static ``ppermute`` route);
* *software-defined circuit scheduling* — **which** slots are wired, in which
  physical ring direction, and at which circuit epoch is a runtime
  :class:`~repro.core.steering.RouteProgram` input compiled by the control
  plane: unidirectional (the historical fixed ring), bidirectional
  (min(d, N-d) shortest-way routing: ⌊N/2⌋ epochs instead of N-1), pruned to
  the distances that actually carry traffic, link-avoiding after a ring
  failure, or **hierarchical** for a board + rack fabric
  (:class:`~repro.core.topology.Topology`): the program's per-rank group
  mask splits every offset between its same-board requesters (concurrent
  local-ring circuits) and its board-crossing ones (exclusive gateway
  epochs).  Programs have fixed static shapes, so swapping them between
  steps — flat for hierarchical, like re-programming the memport table or
  lowering ``active_budget`` — never triggers a retrace;
* *serDES + circuit network* — one ``jax.lax.ppermute`` pair per live slot:
  request ids travel ``rank -> rank+d``, payload returns ``rank+d -> rank``.
  Every slot's wire permutation is **static** (circuit switching; note the
  +d and -(N-d) circuits are the *same permutation*, so direction is pure
  steering data), only the *contents* are runtime values.  Dead slots carry
  FREE requests, so their gather/scatter payload work is masked out;
* *edge buffering* — live slots within a round are independent dataflow
  chains, so the compiler overlaps them exactly like the paper's decoupled
  serdes clock domains pulling from edge buffers.  ``edge_buffer=False``
  inserts ``optimization_barrier`` between consecutive slots to model a
  bufferless bridge (a conservative serialization: it ignores the program's
  epoch pairing, which only affects the analytical cost model);
* *lossless, no ack/retx* — ICI collectives are lossless and deterministic,
  so the assumption holds natively;
* *in-band telemetry* — ``collect_telemetry=True`` additionally returns a
  :class:`~repro.telemetry.counters.BridgeTelemetry` of per-slot served
  counts, spills, pruned drops and a traffic-matrix row, computed as masked
  integer sums with static shapes (swapping programs with collection on
  never retraces); the control plane closes the loop on it.

All functions exist in two forms: a ``*_local`` body to be used inside
``shard_map`` (N nodes on the mem axis) and a reference oracle in
``repro.core.ref`` used by tests (the oracle honours arbitrary programs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.memport import FREE, MemPortTable
from repro.core import ref as _ref
from repro.core import steering
from repro.core.steering import RouteProgram
from repro.core.topology import Topology, TopoTables
from repro.telemetry import counters as _telemetry


def shard_map(f, mesh, in_specs, out_specs, mem_axis=None):
    """jax.shard_map, manual ONLY over ``mem_axis`` (others stay auto).

    Partial-manual mode keeps the model axis under GSPMD control inside the
    body, so head/ff dims keep their automatic sharding (and non-divisible
    head counts keep working) while the bridge runs manual collectives over
    the mem axis.  check_vma must be True: the check_vma=False path in jax
    0.8 rebuilds specs over *all* mesh axes and rejects partial manual.
    """
    names = frozenset({mem_axis}) if mem_axis else frozenset(mesh.axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=names,
                             check_vma=True)
    # jax < 0.5: shard_map lives in jax.experimental and partial-manual mode
    # (``auto``) is not usable (eager raises NotImplementedError, the jit
    # path trips over PartitionId SPMD lowering).  Every bridge body is
    # replicated over the non-mem axes anyway (specs never mention them), so
    # go full-manual over all axes; replication checking (check_rep)
    # predates VMA typing — disable it, the bridge's replicated inputs
    # (table, program) are genuinely replicated.
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)


def use_mesh(mesh: Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on current jax; on jax < 0.5 a Mesh is itself the
    context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# ---------------------------------------------------------------------------
# shard_map bodies
# ---------------------------------------------------------------------------

def _pvary(x: jax.Array, axis: str) -> jax.Array:
    """Mark ``x`` as varying over ``axis`` (VMA typing for scan carries).

    jax < 0.5 has no VMA typing (and no ``jax.lax.pcast``): no-op there.
    Where pcast exists, real errors must surface, not be swallowed.
    """
    if not hasattr(jax.lax, "pcast"):
        return x
    return jax.lax.pcast(x, axis, to="varying")


def _gather_local(pool_local: jax.Array, slots: jax.Array) -> jax.Array:
    """Masked local gather: FREE slots produce zeros."""
    valid = slots >= 0
    safe = jnp.where(valid, slots, 0)
    out = pool_local[safe]
    mask = valid.reshape(valid.shape + (1,) * (out.ndim - 1))
    return jnp.where(mask, out, jnp.zeros_like(out))


def _scatter_local(pool_local: jax.Array, slots: jax.Array,
                   payload: jax.Array) -> jax.Array:
    # FREE slots are routed out of bounds and dropped: a where-fallback would
    # scatter stale values onto slot 0 and race with live writes there.
    safe = jnp.where(slots >= 0, slots, pool_local.shape[0])
    return pool_local.at[safe].set(payload.astype(pool_local.dtype),
                                   mode="drop")


def _round_pull(pool_local: jax.Array, sub_ids: jax.Array, table: MemPortTable,
                program: RouteProgram, axis: str, num_nodes: int,
                edge_buffer: bool) -> jax.Array:
    """Serve one round of <=budget requests; returns [budget, *page_shape]."""
    my = jax.lax.axis_index(axis)
    home, slot = table.translate(sub_ids)
    dist = steering.ring_distance(home, my, num_nodes)

    # Epoch 0: loopback fast path (locally mapped region — no circuit hop).
    out = _gather_local(pool_local, jnp.where(dist == 0, slot, FREE))

    prev = None
    for k, d in enumerate(steering.default_route_schedule(num_nodes)):
        # Runtime steering: slot k carries traffic only if the program wires
        # it *for this rank* (the group mask — a hierarchical program may
        # serve an offset's same-board requesters while cutting its
        # board-crossing ones).  Dead pairings move FREE requests, so their
        # payload gathers are masked to zeros and their pages dropped.
        serve = ((dist == d) & program.live[k]
                 & (program.rank_epoch[k, my] >= 0))
        req = jnp.where(serve, slot, FREE)                         # [B]
        if not edge_buffer and prev is not None:
            # A bufferless bridge serializes slots: model it explicitly.
            req, prev = jax.lax.optimization_barrier((req, prev))
        fwd = [(j, (j + d) % num_nodes) for j in range(num_nodes)]
        bwd = [(j, (j - d) % num_nodes) for j in range(num_nodes)]
        req_at_home = jax.lax.ppermute(req, axis, perm=fwd)        # request flits
        payload = _gather_local(pool_local, req_at_home)           # remote read
        payload = jax.lax.ppermute(payload, axis, perm=bwd)        # data flits
        mask = serve.reshape((-1,) + (1,) * (payload.ndim - 1))
        out = jnp.where(mask, payload, out)
        prev = payload
    return out


def _pull_local(pool_local: jax.Array, want: jax.Array, table: MemPortTable,
                active_budget: jax.Array, program: RouteProgram, *, axis: str,
                num_nodes: int, budget: int, rounds: int,
                edge_buffer: bool) -> jax.Array:
    """Pull ``want`` pages ([rounds*budget], FREE-padded) through the bridge."""
    want = want.reshape(-1)
    page_shape = pool_local.shape[1:]

    def body(ptr, _):
        # Rate limiter: only the first ``active_budget`` slots of this round
        # carry live requests; the pointer advances by the same amount, so a
        # throttled node simply uses more of its (overprovisioned) rounds.
        sub = jax.lax.dynamic_slice(want, (ptr,), (budget,))
        lane = jnp.arange(budget)
        sub = jnp.where(lane < active_budget, sub, FREE)
        out = _round_pull(pool_local, sub, table, program, axis, num_nodes,
                          edge_buffer)
        return ptr + active_budget, (out, sub)

    if rounds == 0:
        return jnp.zeros((0,) + page_shape, pool_local.dtype)
    ptr0 = _pvary(jnp.int32(0), axis)
    _, (chunks, _) = jax.lax.scan(body, ptr0, None, length=rounds)
    # Re-assemble in logical request order.  Round ``r`` served
    # ``want[r*active_budget + k]`` in lane ``k`` (k < active_budget); lanes
    # beyond the live budget carried FREE requests and yield zeros.
    flat = chunks.reshape(rounds * budget, *page_shape)
    r = jnp.arange(rounds * budget) // budget
    k = jnp.arange(rounds * budget) % budget
    dest = r * active_budget + k
    live = (k < active_budget) & (dest < want.shape[0])
    dest = jnp.where(live, dest, 0)
    mask = live.reshape((-1,) + (1,) * len(page_shape))
    upd = jnp.where(mask, flat, jnp.zeros_like(flat))
    out = jnp.zeros((want.shape[0],) + page_shape, pool_local.dtype)
    return out.at[dest].add(upd)


def _push_local(pool_local: jax.Array, dest_ids: jax.Array, payload: jax.Array,
                table: MemPortTable, active_budget: jax.Array,
                program: RouteProgram, *, axis: str, num_nodes: int,
                budget: int, rounds: int) -> jax.Array:
    """Write payload pages to their homes (single-writer contract).

    Rate-limiter parity with :func:`_pull_local`: each round writes only the
    first ``active_budget`` lanes and the pointer advances by the same
    amount, so requests past ``rounds * active_budget`` spill off the end of
    the (overprovisioned) round budget and are dropped.
    """
    my = jax.lax.axis_index(axis)
    page_shape = pool_local.shape[1:]
    ids = dest_ids.reshape(-1)
    pay = payload.reshape((-1,) + page_shape)

    def body(carry, _):
        pool, ptr = carry
        sub = jax.lax.dynamic_slice(ids, (ptr,), (budget,))
        data = jax.lax.dynamic_slice(
            pay, (ptr,) + (0,) * len(page_shape), (budget,) + page_shape)
        lane = jnp.arange(budget)
        sub = jnp.where(lane < active_budget, sub, FREE)
        home, slot = table.translate(sub)
        dist = steering.ring_distance(home, my, num_nodes)
        pool = _scatter_local(pool, jnp.where(dist == 0, slot, FREE), data)
        for k, d in enumerate(steering.default_route_schedule(num_nodes)):
            fwd = [(j, (j + d) % num_nodes) for j in range(num_nodes)]
            serve = ((dist == d) & program.live[k]
                     & (program.rank_epoch[k, my] >= 0))
            req = jnp.where(serve, slot, FREE)
            slot_at_home = jax.lax.ppermute(req, axis, perm=fwd)
            data_at_home = jax.lax.ppermute(data, axis, perm=fwd)
            pool = _scatter_local(pool, slot_at_home, data_at_home)
        return (pool, ptr + active_budget), None

    if rounds == 0:
        return pool_local
    ptr0 = _pvary(jnp.int32(0), axis)
    (pool_local, _), _ = jax.lax.scan(body, (pool_local, ptr0), None,
                                      length=rounds)
    return pool_local


# ---------------------------------------------------------------------------
# Public API (shard_map wrappers)
# ---------------------------------------------------------------------------

def _mem_axis_size(mesh: Optional[Mesh], axis: str) -> int:
    if mesh is None or axis not in mesh.axis_names:
        return 1
    return mesh.shape[axis]


def _resolve_program(program: Optional[RouteProgram],
                     num_nodes: int) -> RouteProgram:
    """Default program (full bidirectional coverage) + static shape check."""
    if program is None:
        return steering.bidirectional_program(num_nodes)
    if program.num_slots != num_nodes - 1:
        raise ValueError(
            f"route program has {program.num_slots} slots; a {num_nodes}-node "
            f"ring needs {num_nodes - 1}")
    return program


def _resolve_topology(topology: Optional[Topology],
                      num_nodes: int) -> Topology:
    """Default (flat single-board) fabric + node-count check.

    The topology is **static**: its tables enter the jitted datapath as
    constants, so a deployment's fabric shape never appears in the jit
    cache key — only a topology *change* retraces (as it must: it is a
    different machine).
    """
    if topology is None:
        return Topology.flat(num_nodes)
    if topology.num_nodes != num_nodes:
        raise ValueError(
            f"topology spans {topology.num_nodes} endpoints; the bridge has "
            f"{num_nodes}")
    return topology


def _loopback_telemetry(ids: jax.Array, table: MemPortTable,
                        program: Optional[RouteProgram], tn: int,
                        active_budget, budget: int, rounds: int,
                        topology: Optional[Topology]
                        ) -> _telemetry.BridgeTelemetry:
    """Telemetry for the 1-device path: row i of ``ids`` is logical
    requester i; the whole batch shares ``active_budget``'s first element
    (mirroring the loopback rate limiter)."""
    prog = _resolve_program(program, tn)
    topo = _resolve_topology(topology, tn)
    tt = topo.tables()
    ab = jnp.clip(jnp.asarray(active_budget).reshape(-1)[0], 0, budget)
    rows = ids.reshape((-1, ids.shape[-1]))

    def per_row(row, my):
        return _telemetry.transfer_telemetry(
            row, table, prog, ab, my=my, num_nodes=tn, budget=budget,
            rounds=rounds, topo=tt, num_groups=topo.num_groups)

    return jax.vmap(per_row)(rows, jnp.arange(rows.shape[0]))


def _telemetry_specs(mem_axis: str) -> _telemetry.BridgeTelemetry:
    """shard_map out_specs for per-node telemetry (leading node dim)."""
    return _telemetry.BridgeTelemetry(
        slot_served=P(mem_axis, None), loopback_served=P(mem_axis),
        spilled=P(mem_axis), pruned=P(mem_axis), traffic=P(mem_axis, None),
        epoch_cw=P(mem_axis, None), epoch_ccw=P(mem_axis, None),
        slot_intra=P(mem_axis, None), tier_hops=P(mem_axis, None))


def _loopback_mask(flat: jax.Array, ids: jax.Array, table: MemPortTable,
                   program: Optional[RouteProgram], tn: int) -> jax.Array:
    """Apply a route program on the 1-device (loopback) fast path.

    The loopback circuit still models ``tn`` logical ring nodes: row i of
    ``ids`` is logical requester i, and requests whose logical ring distance
    has no wired circuit are dropped — identical semantics (and oracle) as
    the N-device path.
    """
    if program is None:
        return flat
    _resolve_program(program, tn)
    rows = ids.reshape((-1, ids.shape[-1]))
    served = _ref.served_mask(table, rows, program).reshape(-1)
    return jnp.where(served, flat, FREE)


def pull_pages(pool_pages: jax.Array, want: jax.Array, table: MemPortTable,
               *, mesh: Optional[Mesh], mem_axis: str = "data",
               budget: int = 8, edge_buffer: bool = True,
               overprovision: int = 1,
               active_budget: Optional[jax.Array] = None,
               program: Optional[RouteProgram] = None,
               table_nodes: int = 0, collect_telemetry: bool = False,
               topology: Optional[Topology] = None):
    """Pull logical pages through the bridge.

    Args:
      pool_pages: [num_nodes * pages_per_node, *page_shape], sharded on dim 0
        over ``mem_axis`` (or unsharded when N == 1).
      want: [num_nodes, R] per-node request lists (logical page ids, FREE pad),
        sharded on dim 0.
      table: replicated memport table.
      program: runtime circuit schedule (default: full bidirectional
        coverage).  A **runtime input**: swapping unidirectional /
        bidirectional / pruned programs on a jitted caller never retraces.
      table_nodes: logical node count of the table (0 = mesh size).  On a
        1-device mesh the pool may still model several logical memory nodes
        (loopback circuit); their slots flatten node-major.
      collect_telemetry: also return a per-node
        :class:`~repro.telemetry.counters.BridgeTelemetry` of what this
        transfer served/spilled/pruned.  The counters have static shapes, so
        with collection on, swapping programs / tables / budgets still never
        retraces (the flag itself is static: toggling it changes the output
        structure).
      topology: the static board + rack fabric
        (:class:`~repro.core.topology.Topology`, default: one flat board).
        Classifies each transfer's tier for the telemetry counters; its
        tables are compile-time constants, so flat and hierarchical
        *programs* swap on one trace.
    Returns:
      [num_nodes, R, *page_shape] gathered pages, sharded on dim 0 — or
      ``(pages, telemetry)`` when ``collect_telemetry`` is set.
    """
    n = _mem_axis_size(mesh, mem_axis)
    r = want.shape[-1]
    rounds = steering.num_rounds(r, budget, overprovision)
    pad = rounds * budget - r
    if pad:
        want = jnp.concatenate(
            [want, jnp.full(want.shape[:-1] + (pad,), FREE, want.dtype)], -1)
    if active_budget is None:
        active_budget = jnp.int32(budget)

    if n == 1:
        tn = table_nodes or 1
        ppn = pool_pages.shape[0] // tn
        home, slot = table.translate(want.reshape(-1))
        flat = jnp.where(home >= 0, home * ppn + slot, FREE)
        # Rate-limiter parity with the N-device path: round ``r`` serves
        # request indices [r*ab, (r+1)*ab), so anything past rounds*ab spills
        # off the end of the (overprovisioned) round budget and is dropped.
        ab = jnp.clip(jnp.asarray(active_budget).reshape(-1)[0], 0, budget)
        idx = jnp.arange(want.shape[-1])
        served = jnp.broadcast_to(idx < rounds * ab, want.shape).reshape(-1)
        flat = jnp.where(served, flat, FREE)
        flat = _loopback_mask(flat, want, table, program, tn)
        out = _gather_local(pool_pages, flat)
        out = out.reshape(want.shape + pool_pages.shape[1:])
        # Trim the round padding on the *request* dim (pages may be
        # multi-dimensional, so slice by position, not from the back).
        out = out[(slice(None),) * (want.ndim - 1) + (slice(0, r),)]
        if collect_telemetry:
            return out, _loopback_telemetry(want, table, program, tn,
                                            active_budget, budget, rounds,
                                            topology)
        return out
    if table_nodes and table_nodes != n:
        raise ValueError(f"table has {table_nodes} nodes but mem axis "
                         f"{mem_axis!r} has {n}")
    program = _resolve_program(program, n)
    topo = _resolve_topology(topology, n)

    pages_spec = P(mem_axis, *([None] * (pool_pages.ndim - 1)))
    out_spec = P(mem_axis, *([None] * pool_pages.ndim))
    body = functools.partial(
        _pull_local, axis=mem_axis, num_nodes=n, budget=budget,
        rounds=rounds, edge_buffer=edge_buffer)
    ab_vec = jnp.clip(jnp.broadcast_to(active_budget, (n,)), 0, budget)

    def mapped(pool, want_l, table_l, ab, prog, tt):
        out = body(pool, want_l[0], table_l, ab[0], prog)
        if not collect_telemetry:
            return out[None]
        telem = _telemetry.transfer_telemetry(
            want_l[0], table_l, prog, ab[0],
            my=jax.lax.axis_index(mem_axis), num_nodes=n, budget=budget,
            rounds=rounds, topo=tt, num_groups=topo.num_groups)
        return out[None], jax.tree.map(lambda x: x[None], telem)

    out_specs = ((out_spec, _telemetry_specs(mem_axis))
                 if collect_telemetry else out_spec)
    out = shard_map(
        mapped, mesh,
        in_specs=(pages_spec, P(mem_axis, None), P(), P(mem_axis), P(),
                  TopoTables(group=P(), local_rank=P(), group_size=P())),
        out_specs=out_specs, mem_axis=mem_axis,
    )(pool_pages, want, table, ab_vec, program, topo.tables())
    if collect_telemetry:
        return out[0][:, :r], out[1]
    return out[:, :r]


def push_pages(pool_pages: jax.Array, dest: jax.Array, payload: jax.Array,
               table: MemPortTable, *, mesh: Optional[Mesh],
               mem_axis: str = "data", budget: int = 8,
               overprovision: int = 1,
               active_budget: Optional[jax.Array] = None,
               program: Optional[RouteProgram] = None,
               table_nodes: int = 0, collect_telemetry: bool = False,
               topology: Optional[Topology] = None):
    """Write pages to their homes through the bridge (single-writer pages).

    Args:
      pool_pages: as in :func:`pull_pages` (returned updated).
      dest: [num_nodes, R] logical page ids each node writes.
      payload: [num_nodes, R, *page_shape].
      active_budget: runtime rate limiter, same spill semantics as
        :func:`pull_pages`: each round writes only the first
        ``active_budget`` lanes, writes past ``rounds * active_budget``
        spill off the (overprovisioned) round budget and are dropped.
      program: runtime circuit schedule (default: full bidirectional
        coverage), same semantics as in :func:`pull_pages`.
      collect_telemetry: also return per-node write-path counters
        (:class:`~repro.telemetry.counters.BridgeTelemetry`).
    """
    n = _mem_axis_size(mesh, mem_axis)
    r = dest.shape[-1]
    rounds = steering.num_rounds(r, budget, overprovision)
    pad = rounds * budget - r
    if pad:
        dest = jnp.concatenate(
            [dest, jnp.full(dest.shape[:-1] + (pad,), FREE, dest.dtype)], -1)
        zeros = jnp.zeros(payload.shape[:1] + (pad,) + payload.shape[2:],
                          payload.dtype)
        payload = jnp.concatenate([payload, zeros], 1)
    if active_budget is None:
        active_budget = jnp.int32(budget)

    if n == 1:
        tn = table_nodes or 1
        ppn = pool_pages.shape[0] // tn
        home, slot = table.translate(dest.reshape(-1))
        flat = jnp.where(home >= 0, home * ppn + slot, FREE)
        # Rate-limiter parity with the N-device path (see pull_pages).
        ab = jnp.clip(jnp.asarray(active_budget).reshape(-1)[0], 0, budget)
        idx = jnp.arange(dest.shape[-1])
        served = jnp.broadcast_to(idx < rounds * ab, dest.shape).reshape(-1)
        flat = jnp.where(served, flat, FREE)
        flat = _loopback_mask(flat, dest, table, program, tn)
        out = _scatter_local(
            pool_pages, flat, payload.reshape((-1,) + payload.shape[2:]))
        if collect_telemetry:
            return out, _loopback_telemetry(dest, table, program, tn,
                                            active_budget, budget, rounds,
                                            topology)
        return out
    if table_nodes and table_nodes != n:
        raise ValueError(f"table has {table_nodes} nodes but mem axis "
                         f"{mem_axis!r} has {n}")
    program = _resolve_program(program, n)
    topo = _resolve_topology(topology, n)

    pages_spec = P(mem_axis, *([None] * (pool_pages.ndim - 1)))
    body = functools.partial(_push_local, axis=mem_axis, num_nodes=n,
                             budget=budget, rounds=rounds)
    ab_vec = jnp.clip(jnp.broadcast_to(active_budget, (n,)), 0, budget)

    def mapped(pool, dest_l, pay_l, table_l, ab, prog, tt):
        out = body(pool, dest_l[0], pay_l[0], table_l, ab[0], prog)
        if not collect_telemetry:
            return out
        telem = _telemetry.transfer_telemetry(
            dest_l[0], table_l, prog, ab[0],
            my=jax.lax.axis_index(mem_axis), num_nodes=n, budget=budget,
            rounds=rounds, topo=tt, num_groups=topo.num_groups)
        return out, jax.tree.map(lambda x: x[None], telem)

    out_specs = ((pages_spec, _telemetry_specs(mem_axis))
                 if collect_telemetry else pages_spec)
    return shard_map(
        mapped, mesh,
        in_specs=(pages_spec, P(mem_axis, None),
                  P(mem_axis, None, *([None] * (payload.ndim - 2))), P(),
                  P(mem_axis), P(),
                  TopoTables(group=P(), local_rank=P(), group_size=P())),
        out_specs=out_specs, mem_axis=mem_axis,
    )(pool_pages, dest, payload, table, ab_vec, program, topo.tables())
