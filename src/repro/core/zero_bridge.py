"""Disaggregated optimizer state through the bridge (ZeRO-3, paper-style).

At pod scale the optimizer state (fp32 m, v and master weights: 12-16 B per
parameter) dominates HBM next to the KV cache.  The bridge lets it live in
the pooled memory of *memory-rich* nodes — the paper's compute-node /
memory-node split — and stream through the circuit network once per step:

    pull opt-state pages  ->  apply update  ->  push opt-state pages

Tensors are packed into fixed-size pages (the bridge granule) with a
host-side :class:`TreePacker` that records each leaf's page range; the
memport table owns placement, so the control plane can re-home optimizer
shards on node failure without touching the training step.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import bridge
from repro.core.control_plane import ControlPlane
from repro.core.memport import FREE, MemPortTable
from repro.core.steering import RouteProgram
from repro.core.topology import Topology


@dataclass
class TreePacker:
    """Host-side layout: pytree leaves <-> page ranges in one pool."""

    treedef: Any
    shapes: list[tuple[int, ...]]
    dtypes: list[Any]
    offsets: list[int]          # first page of each leaf
    counts: list[int]           # pages per leaf
    page_elems: int
    num_pages: int

    @staticmethod
    def plan(tree: Any, page_elems: int) -> "TreePacker":
        leaves, treedef = jax.tree.flatten(tree)
        shapes = [tuple(l.shape) for l in leaves]
        dtypes = [l.dtype for l in leaves]
        offsets, counts = [], []
        at = 0
        for l in leaves:
            n = -(-max(int(np.prod(l.shape)), 1) // page_elems)
            offsets.append(at)
            counts.append(n)
            at += n
        return TreePacker(treedef, shapes, dtypes, offsets, counts,
                          page_elems, at)

    # -- pure-jnp pack/unpack (jit-friendly) ---------------------------------
    def pack(self, tree: Any, dtype=jnp.float32) -> jax.Array:
        """-> [num_pages, page_elems] page image of the tree."""
        leaves = jax.tree.leaves(tree)
        pages = []
        for l, n in zip(leaves, self.counts):
            flat = l.astype(dtype).reshape(-1)
            pad = n * self.page_elems - flat.shape[0]
            if pad:
                flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
            pages.append(flat.reshape(n, self.page_elems))
        return jnp.concatenate(pages, 0)

    def unpack(self, pages: jax.Array) -> Any:
        leaves = []
        for shape, dt, off, n in zip(self.shapes, self.dtypes,
                                     self.offsets, self.counts):
            flat = pages[off: off + n].reshape(-1)
            size = int(np.prod(shape)) if shape else 1
            leaves.append(flat[:size].reshape(shape).astype(dt))
        return jax.tree.unflatten(self.treedef, leaves)


@dataclass
class BridgeStore:
    """A packed tree resident in a bridge pool."""

    packer: TreePacker
    table: MemPortTable
    pool: jax.Array             # [num_slots, page_elems] sharded over mem axis
    mem_axis: str
    budget: int
    table_nodes: int = 1        # logical memory nodes (== mesh size if > 1)
    program: Optional[RouteProgram] = None  # circuit schedule (None = full)
    topology: Optional[Topology] = None     # board + rack fabric (None = flat)
    channels: int = 1           # pipelined round engine depth (1 = serial)
    tenant_id: int = 0          # telemetry attribution of the store's traffic
    max_tenants: int = 0        # per-tenant histogram width (0 = default)


def create_store(tree: Any, *, mesh: Optional[Mesh], mem_axis: str = "data",
                 page_elems: int = 16_384, budget: int = 8,
                 channels: int = 1, cp: Optional[ControlPlane] = None,
                 policy: str = "striped", dtype=jnp.float32,
                 tenant_id: int = 0, max_tenants: int = 0) -> BridgeStore:
    """Allocate a pooled region for ``tree`` and write its initial image.

    The control plane's topology rides along: on a board + rack fabric the
    store's circuit schedule comes out hierarchical and its telemetry
    carries per-tier occupancy.  ``channels`` is the store's pipelined
    round-engine depth (a static knob, e.g. from
    :meth:`~repro.core.control_plane.ControlPlane.select_channels`).
    ``tenant_id`` tags every transfer of the store in the telemetry's
    per-tenant bins (a training job sharing the pool with serving tenants
    shows up as its own line in the orchestrator's accounting).
    """
    packer = TreePacker.plan(tree, page_elems)
    n = bridge._mem_axis_size(mesh, mem_axis)
    if cp is None:
        # Headroom so elastic remap has spare slots on survivors.
        cp = ControlPlane(n, 2 * -(-packer.num_pages // n), packer.num_pages)
    if n > 1 and cp.num_nodes != n:
        raise ValueError(f"control plane has {cp.num_nodes} nodes, mesh axis "
                         f"{mem_axis!r} has {n}")
    cp.allocate(packer.num_pages, "zero", policy=policy)
    table = cp.table()
    # Pool geometry MUST match the control plane's slot space: remapped
    # slots index the same rows the bridge scatters into.
    pool = jnp.zeros((cp.num_nodes * cp.pages_per_node, page_elems), dtype)
    topo = None if cp.topology.is_flat else cp.topology
    store = BridgeStore(packer, table, pool, mem_axis, budget,
                        table_nodes=cp.num_nodes, program=cp.route_program(),
                        topology=topo, channels=channels,
                        tenant_id=tenant_id, max_tenants=max_tenants)
    return push_tree(store, tree, mesh=mesh)


def _as_node_requests(ids: np.ndarray, n: int) -> np.ndarray:
    """Split a flat page-id list evenly across the n requesting nodes."""
    per = -(-len(ids) // n)
    out = np.full((n, per), FREE, np.int32)
    for i in range(n):
        chunk = ids[i * per: (i + 1) * per]
        out[i, : len(chunk)] = chunk
    return out


def pull_tree(store: BridgeStore, *, mesh: Optional[Mesh],
              collect_telemetry: bool = False) -> Any:
    """Stream the packed tree out of the pool (each node pulls a stripe,
    then stripes all-gather via the output sharding).  With
    ``collect_telemetry`` returns ``(tree, BridgeTelemetry)`` so the
    once-per-step optimizer traffic feeds the aggregator."""
    n = bridge._mem_axis_size(mesh, store.mem_axis)
    want = jnp.asarray(_as_node_requests(
        np.arange(store.packer.num_pages), n))
    got = bridge.pull_pages(store.pool, want, store.table, mesh=mesh,
                            mem_axis=store.mem_axis, budget=store.budget,
                            channels=store.channels, program=store.program,
                            table_nodes=store.table_nodes,
                            collect_telemetry=collect_telemetry,
                            topology=store.topology,
                            tenant_ids=(jnp.full(want.shape, store.tenant_id,
                                                 jnp.int32)
                                        if collect_telemetry else None),
                            max_tenants=store.max_tenants)
    telem = None
    if collect_telemetry:
        got, telem = got
    flat = got.reshape(-1, store.packer.page_elems)[: store.packer.num_pages]
    tree = store.packer.unpack(flat)
    if collect_telemetry:
        return tree, telem
    return tree


def push_tree(store: BridgeStore, tree: Any, *, mesh: Optional[Mesh],
              collect_telemetry: bool = False):
    """Write a new image of the tree through the bridge.

    With ``collect_telemetry`` returns ``(store, BridgeTelemetry)``.
    """
    n = bridge._mem_axis_size(mesh, store.mem_axis)
    pages = store.packer.pack(tree, dtype=store.pool.dtype)
    ids = np.arange(store.packer.num_pages)
    dest = _as_node_requests(ids, n)
    per = dest.shape[1]
    pad = n * per - store.packer.num_pages
    if pad:
        pages = jnp.concatenate(
            [pages, jnp.zeros((pad, store.packer.page_elems),
                              pages.dtype)], 0)
    payload = pages.reshape(n, per, store.packer.page_elems)
    pool = bridge.push_pages(store.pool, jnp.asarray(dest), payload,
                             store.table, mesh=mesh, mem_axis=store.mem_axis,
                             budget=store.budget, channels=store.channels,
                             program=store.program,
                             table_nodes=store.table_nodes,
                             collect_telemetry=collect_telemetry,
                             topology=store.topology,
                             tenant_ids=(jnp.full((n, per), store.tenant_id,
                                                  jnp.int32)
                                         if collect_telemetry else None),
                             max_tenants=store.max_tenants)
    telem = None
    if collect_telemetry:
        pool, telem = pool
    out = dataclasses.replace(store, pool=pool)
    if collect_telemetry:
        return out, telem
    return out


def with_program(store: BridgeStore, program) -> BridgeStore:
    """Swap the store's circuit schedule (a runtime input — e.g. a
    telemetry-compiled ``ControlPlane.route_program(telemetry=...)``)."""
    return dataclasses.replace(store, program=program)


def rehome_after_failure(store: BridgeStore, cp: ControlPlane,
                         failed_node: int, restore_tree: Any, *,
                         mesh: Optional[Mesh]) -> BridgeStore:
    """Elastic remap: re-home the failed node's pages and restore their
    contents from a checkpointed tree image (the data on the node is lost)."""
    cp.fail_node(failed_node)
    table = cp.table()
    # Placement changed: recompile the circuit schedule for the new homes.
    program = cp.route_program() if store.program is not None else None
    store = dataclasses.replace(store, table=table, program=program)
    return push_tree(store, restore_tree, mesh=mesh)
