"""Request preparation & steering (paper Fig. 1, dotted box).

Pure helpers shared by the transfer engine:

* ring distances (which request is served by which circuit),
* round/budget splitting (the software rate limiter),
* **route programs** — runtime-reprogrammable circuit schedules (which ring
  offset is wired at which circuit epoch, and in which direction).

A :class:`RouteProgram` is the software-defined analogue of the paper's
circuit control plane: a *runtime value* (registered pytree, arrays only)
that the orchestrator can swap between steps — unidirectional, bidirectional,
pruned, or link-avoiding — without ever recompiling the jitted datapath.

Key identity the programs exploit: on an N-ring the permutation
``rank -> rank + d (mod N)`` is *the same permutation* as
``rank -> rank - (N - d) (mod N)``.  Slot ``k`` of the datapath (serving
ring distance ``k + 1``) therefore has two physical realisations: a
clockwise circuit of ``k + 1`` hops or a counter-clockwise circuit of
``N - k - 1`` hops.  The program picks, per slot, the signed offset actually
driven (sign = direction, magnitude = hop count / which directed links are
held) and the circuit *epoch* at which the slot is wired.  One epoch can
host one circuit per direction (disjoint wire sets), so a bidirectional
program covers all N-1 distances in ⌊N/2⌋ epochs instead of N-1.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.memport import FREE


def ring_distance(home: jnp.ndarray, my_rank, num_nodes: int) -> jnp.ndarray:
    """Epoch (ring hop count) at which a request to ``home`` is served."""
    d = jnp.mod(home - my_rank, num_nodes)
    return jnp.where(home == FREE, -1, d)


def num_rounds(num_requests: int, budget: int, overprovision: int = 1) -> int:
    """Static round count for ``num_requests`` at ``budget`` pages/round."""
    if num_requests == 0:
        return 0
    return -(-num_requests // max(budget, 1)) * max(overprovision, 1)


def default_route_schedule(num_nodes: int) -> list[int]:
    """Distances wired per slot: one full ring rotation (1 .. N-1).

    Epoch 0 (distance 0) is the local loopback fast path and never uses the
    circuit network, matching the paper's locally-mapped regions.  Kept for
    the datapath's static slot structure; the *runtime* schedule — which
    slot is live, in which direction, at which epoch — is a
    :class:`RouteProgram`.
    """
    return list(range(1, num_nodes))


# ---------------------------------------------------------------------------
# Route programs (runtime circuit schedules)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class RouteProgram:
    """A runtime circuit schedule for an N-node ring bridge.

    All three fields are arrays of static length ``N - 1`` (one entry per
    datapath slot; slot ``k`` serves ring distance ``k + 1``), so swapping
    programs on a jitted step never changes shapes and never retraces —
    exactly like ``active_budget``.

    Attributes:
      offsets: i32[N-1]  signed ring offset driven for slot k.  Must satisfy
        ``offsets[k] % N == k + 1`` when live; sign is the physical ring
        direction (+ = clockwise), ``|offsets[k]|`` the hop count.  0 on
        dead slots.
      epoch:   i32[N-1]  circuit epoch at which slot k's circuit is wired
        (two slots may share an epoch iff they drive opposite directions).
        -1 on dead slots.
      live:    bool[N-1] dead slots carry no traffic: the datapath
        FREE-masks their requests, so their payload work is skipped and the
        oracle drops their pages (pruning / link avoidance).
    """

    offsets: jax.Array
    epoch: jax.Array
    live: jax.Array

    @property
    def num_slots(self) -> int:
        return self.offsets.shape[0]

    @property
    def num_nodes(self) -> int:
        return self.num_slots + 1

    # -- host-side accounting (benchmarks / perfmodel / tests) ---------------
    def num_epochs(self) -> int:
        """Circuit epochs the program occupies (max live epoch + 1)."""
        ep, lv = np.asarray(self.epoch), np.asarray(self.live)
        return int(ep[lv].max()) + 1 if lv.any() else 0

    def live_distances(self) -> np.ndarray:
        """Ring distances with a wired circuit (sorted)."""
        return np.nonzero(np.asarray(self.live))[0] + 1

    def hops(self) -> np.ndarray:
        """Physical hop count per slot (0 on dead slots)."""
        return np.abs(np.asarray(self.offsets))

    def validate(self) -> None:
        """Raise if any live slot's offset is not congruent to its distance."""
        n = self.num_nodes
        off, lv = np.asarray(self.offsets), np.asarray(self.live)
        d = np.arange(1, n)
        bad = lv & ((off % n) != d)
        if bad.any():
            raise ValueError(
                f"slots {np.nonzero(bad)[0].tolist()} drive offsets "
                f"{off[bad].tolist()} incongruent with their distances")


def _program(off: np.ndarray, epoch: np.ndarray, live: np.ndarray
             ) -> RouteProgram:
    return RouteProgram(offsets=jnp.asarray(off, jnp.int32),
                        epoch=jnp.asarray(epoch, jnp.int32),
                        live=jnp.asarray(live, bool))


def unidirectional_program(num_nodes: int, direction: int = 1) -> RouteProgram:
    """One full ring rotation in one direction: N-1 circuit epochs.

    ``direction=+1`` reproduces the historical fixed schedule
    (``default_route_schedule``); ``-1`` drives every circuit the other way
    round (all counter-clockwise links, no clockwise link touched).
    """
    d = np.arange(1, num_nodes)
    off = d if direction >= 0 else -(num_nodes - d)
    hops = np.abs(off)
    return _program(off, hops - 1, np.ones_like(d, bool))


def bidirectional_program(num_nodes: int) -> RouteProgram:
    """Shortest-way routing: distance d drives min(d, N-d) hops.

    Epoch e hosts the (e+1)-hop clockwise circuit and the (e+1)-hop
    counter-clockwise circuit simultaneously (disjoint wire sets), so all
    N-1 distances complete in ⌊N/2⌋ epochs — vs N-1 unidirectionally.
    """
    d = np.arange(1, num_nodes)
    back = num_nodes - d
    off = np.where(d <= back, d, -back)
    return _program(off, np.abs(off) - 1, np.ones_like(d, bool))


def pruned_program(base: RouteProgram, live_distances) -> RouteProgram:
    """Keep only ``live_distances``; compact epochs per direction.

    Dead slots are FREE-masked by the datapath (their pages, if any were
    requested, come back as zeros — callers prune only distances they know
    carry no traffic).  Surviving circuits re-pack into consecutive epochs,
    shortest hop count first, one circuit per direction per epoch.
    """
    n = base.num_nodes
    keep = np.zeros((n - 1,), bool)
    for d in np.asarray(list(live_distances), np.int64).ravel():
        if not 0 < d < n:
            raise ValueError(f"distance {d} out of range for {n} nodes")
        keep[d - 1] = True
    off = np.asarray(base.offsets).copy()
    live = np.asarray(base.live) & keep
    off = np.where(live, off, 0)
    epoch = np.full((n - 1,), -1, np.int64)
    for sign in (1, -1):
        idx = np.nonzero(live & (np.sign(off) == sign))[0]
        order = np.argsort(np.abs(off[idx]), kind="stable")
        epoch[idx[order]] = np.arange(len(idx))
    return _program(off, epoch, live)


def load_balanced_program(num_nodes: int, dist_weight,
                          prune: bool = True) -> RouteProgram:
    """Direction assignment minimizing the bottleneck direction's load.

    ``dist_weight[k]`` is the *measured* traffic (pages or bytes) carried at
    ring distance ``k + 1`` — typically
    :meth:`repro.telemetry.TelemetryAggregator.distance_pages`.  Circuits of
    one direction share that direction's links, so an edge-buffered round
    costs ``max(cw_load, ccw_load)`` wire time (the bottleneck term
    ``perfmodel.predict_round_latency_us`` models): instead of the static
    shortest-way split (min(d, N-d)), distances are partitioned greedily —
    heaviest first, each onto the currently lighter direction (ties prefer
    fewer hops).  Zero-weight distances are pruned (``prune=True``) or kept
    on their shortest-way direction as free riders.  Epochs compact per
    direction, shortest hop count first, one circuit per direction per
    epoch.
    """
    n = num_nodes
    w = np.asarray(dist_weight, float).reshape(-1)
    if w.shape[0] != n - 1:
        raise ValueError(f"dist_weight has {w.shape[0]} entries; a {n}-node "
                         f"ring has {n - 1} distances")
    if (w < 0).any():
        raise ValueError("dist_weight must be non-negative")
    live = (w > 0) if prune else np.ones((n - 1,), bool)
    off = np.zeros((n - 1,), np.int64)
    loads = {1: 0.0, -1: 0.0}
    order = sorted(np.nonzero(live & (w > 0))[0].tolist(),
                   key=lambda k: (-w[k], k))
    for k in order:
        d = k + 1
        if loads[1] < loads[-1]:
            sign = 1
        elif loads[-1] < loads[1]:
            sign = -1
        else:
            sign = 1 if d <= n - d else -1
        off[k] = d if sign == 1 else -(n - d)
        loads[sign] += w[k]
    for k in np.nonzero(live & (w == 0))[0]:
        d = k + 1
        off[k] = d if d <= n - d else -(n - d)
    epoch = np.full((n - 1,), -1, np.int64)
    for sign in (1, -1):
        idx = np.nonzero(live & (np.sign(off) == sign))[0]
        order2 = np.argsort(np.abs(off[idx]), kind="stable")
        epoch[idx[order2]] = np.arange(len(idx))
    return _program(off, epoch, live)


def link_avoiding_program(num_nodes: int, failed_direction: int
                          ) -> RouteProgram:
    """Route every circuit away from a failed directed ring link.

    A d-hop circuit in one direction occupies *every* link of that
    direction (all N rank->rank+1 edges carry flits simultaneously), so a
    single failed directed link takes the whole direction down; the
    surviving direction still reaches every distance.  ``failed_direction``
    is +1 (a clockwise link died) or -1.
    """
    if failed_direction not in (1, -1):
        raise ValueError("failed_direction must be +1 or -1")
    return unidirectional_program(num_nodes, direction=-failed_direction)


def pad_requests(want: np.ndarray, rounds: int, budget: int) -> np.ndarray:
    """Pad a request list to [rounds * budget] with FREE sentinels."""
    out = np.full((rounds * budget,), FREE, dtype=np.int32)
    out[: len(want)] = want
    return out
