"""Request preparation & steering (paper Fig. 1, dotted box).

Pure helpers shared by the transfer engine:

* ring distances (which epoch serves which request),
* round/budget splitting (the software rate limiter),
* route schedules (which ring distance is wired at which epoch — the circuit
  control plane can permute or prune this, e.g. to route around a dead link).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.memport import FREE


def ring_distance(home: jnp.ndarray, my_rank, num_nodes: int) -> jnp.ndarray:
    """Epoch (ring hop count) at which a request to ``home`` is served."""
    d = jnp.mod(home - my_rank, num_nodes)
    return jnp.where(home == FREE, -1, d)


def num_rounds(num_requests: int, budget: int, overprovision: int = 1) -> int:
    """Static round count for ``num_requests`` at ``budget`` pages/round."""
    if num_requests == 0:
        return 0
    return -(-num_requests // max(budget, 1)) * max(overprovision, 1)


def default_route_schedule(num_nodes: int) -> list[int]:
    """Distances wired per epoch: one full ring rotation (1 .. N-1).

    Epoch 0 (distance 0) is the local loopback fast path and never uses the
    circuit network, matching the paper's locally-mapped regions.
    """
    return list(range(1, num_nodes))


def pad_requests(want: np.ndarray, rounds: int, budget: int) -> np.ndarray:
    """Pad a request list to [rounds * budget] with FREE sentinels."""
    out = np.full((rounds * budget,), FREE, dtype=np.int32)
    out[: len(want)] = want
    return out
