"""Request preparation & steering (paper Fig. 1, dotted box).

Pure helpers shared by the transfer engine:

* ring distances (which request is served by which circuit),
* round/budget splitting (the software rate limiter),
* **route programs** — runtime-reprogrammable circuit schedules (which ring
  offset is wired at which circuit epoch, and in which direction).

A :class:`RouteProgram` is the software-defined analogue of the paper's
circuit control plane: a *runtime value* (registered pytree, arrays only)
that the orchestrator can swap between steps — unidirectional, bidirectional,
pruned, link-avoiding, or **hierarchical** for a board + rack fabric
(:func:`hierarchical_program`) — without ever recompiling the jitted
datapath.

Key identity the programs exploit: on an N-ring the permutation
``rank -> rank + d (mod N)`` is *the same permutation* as
``rank -> rank - (N - d) (mod N)``.  Slot ``k`` of the datapath (serving
ring distance ``k + 1``) therefore has two physical realisations: a
clockwise circuit of ``k + 1`` hops or a counter-clockwise circuit of
``N - k - 1`` hops.  The program picks, per slot, the signed offset actually
driven (sign = direction, magnitude = hop count / which directed links are
held) and the circuit *epoch* at which the slot is wired.  One epoch can
host one circuit per direction (disjoint wire sets), so a bidirectional
program covers all N-1 distances in ⌊N/2⌋ epochs instead of N-1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.memport import FREE
from repro.core.topology import Topology


def ring_distance(home: jnp.ndarray, my_rank, num_nodes: int) -> jnp.ndarray:
    """Epoch (ring hop count) at which a request to ``home`` is served."""
    d = jnp.mod(home - my_rank, num_nodes)
    return jnp.where(home == FREE, -1, d)


def num_rounds(num_requests: int, budget: int, overprovision: int = 1) -> int:
    """Static round count for ``num_requests`` at ``budget`` pages/round."""
    if num_requests == 0:
        return 0
    return -(-num_requests // max(budget, 1)) * max(overprovision, 1)


def default_route_schedule(num_nodes: int) -> list[int]:
    """Distances wired per slot: one full ring rotation (1 .. N-1).

    Epoch 0 (distance 0) is the local loopback fast path and never uses the
    circuit network, matching the paper's locally-mapped regions.  Kept for
    the datapath's static slot structure; the *runtime* schedule — which
    slot is live, in which direction, at which epoch — is a
    :class:`RouteProgram`.
    """
    return list(range(1, num_nodes))


# ---------------------------------------------------------------------------
# Route programs (runtime circuit schedules)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class RouteProgram:
    """A runtime circuit schedule for an N-node ring bridge.

    All three fields are arrays of static length ``N - 1`` (one entry per
    datapath slot; slot ``k`` serves ring distance ``k + 1``), so swapping
    programs on a jitted step never changes shapes and never retraces —
    exactly like ``active_budget``.

    Attributes:
      offsets: i32[N-1]  signed ring offset driven for slot k.  Must satisfy
        ``offsets[k] % N == k + 1`` when live; sign is the physical ring
        direction (+ = clockwise), ``|offsets[k]|`` the hop count on a flat
        ring (hierarchical realizations count hops via the Topology).  0 on
        dead slots.
      epoch:   i32[N-1]  base circuit epoch of slot k (the first epoch any
        requester drives it; two slots may share an epoch iff they drive
        opposite directions).  -1 on dead slots.
      live:    bool[N-1] dead slots carry no traffic: the datapath
        FREE-masks their requests, so their payload work is skipped and the
        oracle drops their pages (pruning / link avoidance).
      rank_epoch: i32[N-1, N]  the **group mask**: the epoch at which slot k
        serves requester rank r, or -1 when that (rank, slot) pairing is
        masked off — the datapath FREE-masks exactly those requests.  Flat
        programs broadcast ``epoch`` over the rank axis; hierarchical
        programs split a slot between an intra-board epoch (its same-board
        requesters, concurrent across boards) and a gateway epoch (its
        board-crossing requesters).  Same static shape for every program,
        so swapping flat and hierarchical programs never retraces.
    """

    offsets: jax.Array
    epoch: jax.Array
    live: jax.Array
    rank_epoch: jax.Array

    @property
    def num_slots(self) -> int:
        return self.offsets.shape[0]

    @property
    def num_nodes(self) -> int:
        return self.num_slots + 1

    # -- host-side accounting (benchmarks / perfmodel / tests) ---------------
    def num_epochs(self) -> int:
        """Circuit epochs the program occupies (max served epoch + 1)."""
        served = self.rank_served()
        re = np.asarray(self.rank_epoch)
        return int(re[served].max()) + 1 if served.any() else 0

    def live_distances(self) -> np.ndarray:
        """Ring distances with a wired circuit (sorted)."""
        return np.nonzero(np.asarray(self.live))[0] + 1

    def hops(self) -> np.ndarray:
        """Flat-ring hop count per slot (0 on dead slots)."""
        return np.abs(np.asarray(self.offsets))

    def rank_served(self) -> np.ndarray:
        """bool[N-1, N]: does slot k carry requester rank r's traffic."""
        return (np.asarray(self.live)[:, None]
                & (np.asarray(self.rank_epoch) >= 0))

    def validate(self) -> None:
        """Raise on incongruent offsets or an inconsistent group mask."""
        n = self.num_nodes
        off, lv = np.asarray(self.offsets), np.asarray(self.live)
        d = np.arange(1, n)
        bad = lv & ((off % n) != d)
        if bad.any():
            raise ValueError(
                f"slots {np.nonzero(bad)[0].tolist()} drive offsets "
                f"{off[bad].tolist()} incongruent with their distances")
        re = np.asarray(self.rank_epoch)
        if re.shape != (n - 1, n):
            raise ValueError(f"rank_epoch has shape {re.shape}; expected "
                             f"{(n - 1, n)}")
        ghost = (~lv) & (re >= 0).any(1)
        if ghost.any():
            raise ValueError(f"dead slots {np.nonzero(ghost)[0].tolist()} "
                             "still carry rank epochs")
        idle = lv & ~(re >= 0).any(1)
        if idle.any():
            raise ValueError(f"live slots {np.nonzero(idle)[0].tolist()} "
                             "serve no rank")


def _rank_epoch_from(epoch: np.ndarray, live: np.ndarray) -> np.ndarray:
    """Flat broadcast: slot k serves every rank at its single epoch."""
    n = live.shape[0] + 1
    col = np.where(live, epoch, -1).astype(np.int64)
    return np.repeat(col[:, None], n, axis=1)


def _program(off: np.ndarray, epoch: np.ndarray, live: np.ndarray,
             rank_epoch: Optional[np.ndarray] = None) -> RouteProgram:
    if rank_epoch is None:
        rank_epoch = _rank_epoch_from(np.asarray(epoch, np.int64),
                                      np.asarray(live, bool))
    return RouteProgram(offsets=jnp.asarray(off, jnp.int32),
                        epoch=jnp.asarray(epoch, jnp.int32),
                        live=jnp.asarray(live, bool),
                        rank_epoch=jnp.asarray(rank_epoch, jnp.int32))


def unidirectional_program(num_nodes: int, direction: int = 1) -> RouteProgram:
    """One full ring rotation in one direction: N-1 circuit epochs.

    ``direction=+1`` reproduces the historical fixed schedule
    (``default_route_schedule``); ``-1`` drives every circuit the other way
    round (all counter-clockwise links, no clockwise link touched).
    """
    d = np.arange(1, num_nodes)
    off = d if direction >= 0 else -(num_nodes - d)
    hops = np.abs(off)
    return _program(off, hops - 1, np.ones_like(d, bool))


def bidirectional_program(num_nodes: int) -> RouteProgram:
    """Shortest-way routing: distance d drives min(d, N-d) hops.

    Epoch e hosts the (e+1)-hop clockwise circuit and the (e+1)-hop
    counter-clockwise circuit simultaneously (disjoint wire sets), so all
    N-1 distances complete in ⌊N/2⌋ epochs — vs N-1 unidirectionally.
    """
    d = np.arange(1, num_nodes)
    back = num_nodes - d
    off = np.where(d <= back, d, -back)
    return _program(off, np.abs(off) - 1, np.ones_like(d, bool))


def pruned_program(base: RouteProgram, live_distances) -> RouteProgram:
    """Keep only ``live_distances``; compact epochs per direction.

    Dead slots are FREE-masked by the datapath (their pages, if any were
    requested, come back as zeros — callers prune only distances they know
    carry no traffic).  Surviving flat circuits re-pack into consecutive
    epochs, shortest hop count first, one circuit per direction per epoch.
    A **hierarchical** base keeps its group mask instead: the surviving
    slots retain their per-rank intra/gateway epochs (re-packing them per
    direction would put two board-crossing circuits on one gateway epoch).
    """
    n = base.num_nodes
    keep = np.zeros((n - 1,), bool)
    for d in np.asarray(list(live_distances), np.int64).ravel():
        if not 0 < d < n:
            raise ValueError(f"distance {d} out of range for {n} nodes")
        keep[d - 1] = True
    re = np.asarray(base.rank_epoch)
    flat = (re == re[:, :1]).all()  # every row uniform = no group mask
    if not flat:
        return masked_ranks_program(base, np.broadcast_to(keep[:, None],
                                                          re.shape))
    off = np.asarray(base.offsets).copy()
    live = np.asarray(base.live) & keep
    off = np.where(live, off, 0)
    epoch = np.full((n - 1,), -1, np.int64)
    for sign in (1, -1):
        idx = np.nonzero(live & (np.sign(off) == sign))[0]
        order = np.argsort(np.abs(off[idx]), kind="stable")
        epoch[idx[order]] = np.arange(len(idx))
    return _program(off, epoch, live)


def load_balanced_program(num_nodes: int, dist_weight,
                          prune: bool = True) -> RouteProgram:
    """Direction assignment minimizing the bottleneck direction's load.

    ``dist_weight[k]`` is the *measured* traffic (pages or bytes) carried at
    ring distance ``k + 1`` — typically
    :meth:`repro.telemetry.TelemetryAggregator.distance_pages`.  Circuits of
    one direction share that direction's links, so an edge-buffered round
    costs ``max(cw_load, ccw_load)`` wire time (the bottleneck term
    ``perfmodel.predict_round_latency_us`` models): instead of the static
    shortest-way split (min(d, N-d)), distances are partitioned greedily —
    heaviest first, each onto the currently lighter direction (ties prefer
    fewer hops).  Zero-weight distances are pruned (``prune=True``) or kept
    on their shortest-way direction as free riders.  Epochs compact per
    direction, shortest hop count first, one circuit per direction per
    epoch.
    """
    n = num_nodes
    w = np.asarray(dist_weight, float).reshape(-1)
    if w.shape[0] != n - 1:
        raise ValueError(f"dist_weight has {w.shape[0]} entries; a {n}-node "
                         f"ring has {n - 1} distances")
    if (w < 0).any():
        raise ValueError("dist_weight must be non-negative")
    live = (w > 0) if prune else np.ones((n - 1,), bool)
    off = np.zeros((n - 1,), np.int64)
    loads = {1: 0.0, -1: 0.0}
    order = sorted(np.nonzero(live & (w > 0))[0].tolist(),
                   key=lambda k: (-w[k], k))
    for k in order:
        d = k + 1
        if loads[1] < loads[-1]:
            sign = 1
        elif loads[-1] < loads[1]:
            sign = -1
        else:
            sign = 1 if d <= n - d else -1
        off[k] = d if sign == 1 else -(n - d)
        loads[sign] += w[k]
    for k in np.nonzero(live & (w == 0))[0]:
        d = k + 1
        off[k] = d if d <= n - d else -(n - d)
    epoch = np.full((n - 1,), -1, np.int64)
    for sign in (1, -1):
        idx = np.nonzero(live & (np.sign(off) == sign))[0]
        order2 = np.argsort(np.abs(off[idx]), kind="stable")
        epoch[idx[order2]] = np.arange(len(idx))
    return _program(off, epoch, live)


def link_avoiding_program(num_nodes: int, failed_direction: int
                          ) -> RouteProgram:
    """Route every circuit away from a failed directed ring link.

    A d-hop circuit in one direction occupies *every* link of that
    direction (all N rank->rank+1 edges carry flits simultaneously), so a
    single failed directed link takes the whole direction down; the
    surviving direction still reaches every distance.  ``failed_direction``
    is +1 (a clockwise link died) or -1.
    """
    if failed_direction not in (1, -1):
        raise ValueError("failed_direction must be +1 or -1")
    return unidirectional_program(num_nodes, direction=-failed_direction)


# ---------------------------------------------------------------------------
# Hierarchical programs (board + rack tiers)
# ---------------------------------------------------------------------------

def hierarchical_program(topo: Topology, dist_weight=None, prune: bool = False,
                         live_distances=None,
                         intra_weight=None) -> RouteProgram:
    """Compile a two-tier circuit schedule for a board + rack fabric.

    Per slot (global ring offset d), the fabric realizes two kinds of
    circuits (the :mod:`repro.core.topology` contract):

    * its **intra-board** pairs travel each board's local ring concurrently
      — these are scheduled like a bidirectional flat program, one circuit
      per direction per epoch, ordered by local hop count;
    * its **inter-board** pairs funnel through the gateways — each such
      slot gets an exclusive epoch after the intra phase (a gateway hosts
      one circuit at a time), ordered by rack hop count.

    The split is the program's **group mask**: ``rank_epoch[k, r]`` carries
    the intra epoch for same-board requesters and the gateway epoch for
    board-crossing ones.  Directions are chosen per slot to minimize the
    total latency-weighted hop count over all pairs (board hops at
    ``board_hop_us``, rack hops at ``rack_hop_us``), so e.g. a wrap
    distance that is 3 global hops clockwise but 1 local hop
    counter-clockwise drives the short way.

    On a flat (single-board) topology this degenerates exactly to
    :func:`bidirectional_program`'s schedule.

    Args:
      dist_weight: optional measured per-distance loads ([N-1], e.g.
        ``TelemetryAggregator.distance_pages``); with ``prune=True``,
        zero-weight distances are cut.
      live_distances: explicit distance whitelist (placement
        reachability); overrides the weight-based pruning.
      intra_weight: optional measured intra-board share of ``dist_weight``
        ([N-1], e.g. ``TelemetryAggregator.distance_intra_pages``).  The
        direction vote then weighs each tier by its *measured* pages
        instead of its pair count — under intra-heavy traffic an offset's
        direction follows its loaded board-ring pairs even when most of
        its (idle) pairs cross boards.
    """
    n = topo.num_nodes
    if n < 2:
        raise ValueError("hierarchical programs need at least 2 nodes")
    s = n - 1
    live = np.ones((s,), bool)
    if live_distances is not None:
        live[:] = False
        for d in np.asarray(list(live_distances), np.int64).ravel():
            if not 0 < d < n:
                raise ValueError(f"distance {d} out of range for {n} nodes")
            live[d - 1] = True
    elif dist_weight is not None and prune:
        w = np.asarray(dist_weight, float).reshape(-1)
        if w.shape[0] != s:
            raise ValueError(f"dist_weight has {w.shape[0]} entries; a "
                             f"{n}-node ring has {s} distances")
        if (w < 0).any():
            raise ValueError("dist_weight must be non-negative")
        live = w > 0

    wi = wx = None
    if intra_weight is not None:
        wi = np.asarray(intra_weight, float).reshape(-1)
        if wi.shape[0] != s:
            raise ValueError(f"intra_weight has {wi.shape[0]} entries; a "
                             f"{n}-node ring has {s} distances")
        total = (np.asarray(dist_weight, float).reshape(-1)
                 if dist_weight is not None else wi)
        wx = np.maximum(total - wi, 0.0)

    r = np.arange(n)
    off = np.zeros((s,), np.int64)
    intra_mask = np.zeros((s, n), bool)
    local_hops = np.zeros((s,), np.int64)   # deepest intra circuit per slot
    rack_hops = np.zeros((s,), np.int64)    # deepest rack leg per slot
    for k in np.nonzero(live)[0]:
        d = k + 1
        h = (r + d) % n
        intra = topo.pair_intra(r, h)
        # Tier weights for the direction vote: measured pages when known,
        # pair counts otherwise (so the unmeasured compile's vote is the
        # plain latency-weighted hop sum over every pair).
        w_intra = float(wi[k]) if wi is not None else float(intra.sum())
        w_inter = float(wx[k]) if wx is not None else float((~intra).sum())
        costs = {}
        for sign in (1, -1):
            bh, rh = topo.pair_hops(r, h, sign)
            us = bh * topo.board_hop_us + rh * topo.rack_hop_us
            cost = 0.0
            if intra.any():
                cost += w_intra * float(us[intra].mean())
            if (~intra).any():
                cost += w_inter * float(us[~intra].mean())
            costs[sign] = cost
        if costs[1] < costs[-1]:
            sign = 1
        elif costs[-1] < costs[1]:
            sign = -1
        else:
            sign = 1 if d <= n - d else -1
        off[k] = d if sign == 1 else -(n - d)
        intra_mask[k] = intra
        bh, rh = topo.pair_hops(r, h, sign)
        local_hops[k] = bh[intra].max() if intra.any() else 0
        rack_hops[k] = rh[~intra].max() if (~intra).any() else 0

    # Intra phase: one circuit per direction per epoch, shallow rings first
    # (every board transfers concurrently — no gateway is touched).
    intra_epoch = np.full((s,), -1, np.int64)
    n_intra = 0
    for sign in (1, -1):
        idx = np.nonzero(live & intra_mask.any(1) & (np.sign(off) == sign))[0]
        order = idx[np.argsort(local_hops[idx], kind="stable")]
        intra_epoch[order] = np.arange(len(order))
        n_intra = max(n_intra, len(order))
    # Gateway phase: one board-crossing slot per epoch (gateways are
    # single-ported serdes endpoints), short rack legs first.
    inter_epoch = np.full((s,), -1, np.int64)
    idx = np.nonzero(live & (~intra_mask).any(1))[0]
    order = idx[np.argsort(rack_hops[idx], kind="stable")]
    inter_epoch[order] = n_intra + np.arange(len(order))

    rank_epoch = np.full((s, n), -1, np.int64)
    for k in np.nonzero(live)[0]:
        if intra_epoch[k] >= 0:
            rank_epoch[k, intra_mask[k]] = intra_epoch[k]
        if inter_epoch[k] >= 0:
            rank_epoch[k, ~intra_mask[k]] = inter_epoch[k]
    epoch = np.where(live & (rank_epoch >= 0).any(1),
                     np.where(rank_epoch >= 0, rank_epoch, np.iinfo(np.int64).max
                              ).min(1), -1)
    live = live & (rank_epoch >= 0).any(1)
    off = np.where(live, off, 0)
    return _program(off, epoch, live, rank_epoch)


def masked_ranks_program(base: RouteProgram, rank_live) -> RouteProgram:
    """Group-mask a program: drop the (slot, requester) pairings where
    ``rank_live`` ([N-1, N] bool) is False.

    The datapath FREE-masks exactly the dropped pairings (their pages come
    back as zeros / their writes are dropped), mirroring how
    :func:`pruned_program` drops whole distances — this is the per-rank
    refinement a hierarchical fabric needs (e.g. cut only the
    board-crossing users of an offset).  Slots left serving nobody die
    entirely.
    """
    rank_live = np.asarray(rank_live, bool)
    # int64 up-cast: the stored rank_epoch is int32, and the int64 max
    # sentinel below would wrap to -1 in that dtype, zeroing every
    # surviving slot's base epoch (caught by bridgelint PC106).
    re = np.asarray(base.rank_epoch, np.int64)
    if rank_live.shape != re.shape:
        raise ValueError(f"rank_live has shape {rank_live.shape}; program "
                         f"has {re.shape}")
    re = np.where(rank_live, re, -1)
    live = np.asarray(base.live) & (re >= 0).any(1)
    off = np.where(live, np.asarray(base.offsets), 0)
    epoch = np.where(live,
                     np.where(re >= 0, re, np.iinfo(np.int64).max).min(1), -1)
    return _program(off, epoch, live, re)


def validate_hierarchical(program: RouteProgram, topo: Topology) -> None:
    """Raise unless ``program`` is a sound schedule for ``topo``.

    Beyond :meth:`RouteProgram.validate`: in any epoch at most one slot may
    carry board-crossing traffic (no two slots target one gateway in the
    same epoch), and per direction at most one slot may carry intra-board
    traffic (circuits of one direction share each board ring's links).
    """
    program.validate()
    n = program.num_nodes
    if topo.num_nodes != n:
        raise ValueError(f"topology has {topo.num_nodes} nodes; program has "
                         f"{n}")
    re = np.asarray(program.rank_epoch)
    off = np.asarray(program.offsets)
    served = program.rank_served()
    for e in np.unique(re[served]):
        inter_at_e, intra_cw, intra_ccw = [], [], []
        for k in range(n - 1):
            ranks = np.nonzero(served[k] & (re[k] == e))[0]
            if ranks.size == 0:
                continue
            homes = (ranks + k + 1) % n
            intra = topo.pair_intra(ranks, homes)
            if (~intra).any():
                inter_at_e.append(k)
            if intra.any():
                (intra_cw if off[k] > 0 else intra_ccw).append(k)
        if len(inter_at_e) > 1:
            raise ValueError(
                f"epoch {e}: slots {inter_at_e} all cross boards — they "
                "contend for the gateways")
        for name, group in (("cw", intra_cw), ("ccw", intra_ccw)):
            if len(group) > 1:
                raise ValueError(
                    f"epoch {e}: slots {group} share the {name} board-ring "
                    "links")


def pad_requests(want: np.ndarray, rounds: int, budget: int) -> np.ndarray:
    """Pad a request list to [rounds * budget] with FREE sentinels."""
    out = np.full((rounds * budget,), FREE, dtype=np.int32)
    out[: len(want)] = want
    return out
