"""The software control plane (paper §2: "deep software-defined support").

Host-side orchestrator that owns the logical page space of every pooled
region, programs memport tables at runtime, and reacts to infrastructure
events — exactly the role the paper assigns to "datacenter orchestration
tools":

* region allocation with placement policies (striped / affinity / hashed),
* runtime re-programming with **no recompilation** (tables are step inputs),
* node-failure handling: pages homed on a dead node are re-homed onto
  survivors and a migration plan is emitted (executed by ``repro.ft``),
* straggler mitigation: step-time telemetry drives per-node rate limits
  (the bridge's ``active_budget``),
* pipeline depth: :meth:`ControlPlane.select_channels` picks the bridge's
  multi-channel round overlap (``channels``) from telemetry-measured wire
  occupancy — serial until the wire is demonstrably busy,
* circuit scheduling: :meth:`ControlPlane.route_program` compiles the
  bridge's runtime :class:`~repro.core.steering.RouteProgram` from the live
  placement table — bidirectional by default, pruned to the ring distances
  that actually carry traffic, rerouted around a failed ring link reported
  by ``repro.ft``, and **hierarchical** when the pool spans a board + rack
  :class:`~repro.core.topology.Topology` (placement, overflow and affinity
  migration then prefer intra-board homes).

The **closed control loop** (measure -> aggregate -> recompile): the
datapath's in-band counters (``pull_pages`` / ``push_pages`` with
``collect_telemetry=True``) fold into a
:class:`~repro.telemetry.TelemetryAggregator`, and every policy here can
consume the aggregate instead of steering blind —
:meth:`ControlPlane.route_program` ``(telemetry=...)`` compiles a
load-balanced bidirectional program (each live distance on the direction
minimizing the bottleneck direction's measured bytes) pruned from
*measured* traffic instead of placement reachability;
:meth:`ControlPlane.rate_limits` ``(telemetry=...)`` restores throttled
budgets when observed spill rates show the limiter dropping real work; and
:meth:`ControlPlane.affinity_migration` re-homes hot pages toward their
dominant requester as :class:`MigrationStep` plans.  Every output stays a
*runtime input* to the jitted datapath: one iteration of the loop never
recompiles anything.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional

import numpy as np

from repro.core import steering
from repro.core.memport import FREE, MemPortTable
from repro.core.topology import Topology
from repro.telemetry.aggregate import dominant_requester

Policy = Literal["striped", "hashed", "affinity"]


@dataclass
class Region:
    region_id: int
    name: str
    page_ids: np.ndarray          # logical ids owned by this region
    policy: str


@dataclass
class MigrationStep:
    page_id: int
    old_home: int
    old_slot: int
    new_home: int
    new_slot: int


@dataclass
class NodeState:
    alive: bool = True
    budget: int = 0               # manual rate-limit override; 0 = unlimited
                                  # (use the static/adaptive budget)
    step_times: list = field(default_factory=list)


class ControlPlane:
    """Owns placement for one pool (num_nodes x pages_per_node slots)."""

    def __init__(self, num_nodes: int, pages_per_node: int,
                 num_logical: int, seed: int = 0,
                 topology: Optional[Topology] = None):
        if topology is not None and topology.num_nodes != num_nodes:
            raise ValueError(f"topology spans {topology.num_nodes} "
                             f"endpoints; the pool has {num_nodes}")
        self.num_nodes = num_nodes
        self.topology = topology or Topology.flat(num_nodes)
        self.pages_per_node = pages_per_node
        self.num_logical = num_logical
        self._seed = seed
        self._rng = np.random.default_rng(seed)
        self._free: list[list[int]] = [
            list(range(pages_per_node)) for _ in range(num_nodes)]
        self._home = np.full((num_logical,), FREE, np.int64)
        self._slot = np.full((num_logical,), FREE, np.int64)
        self._next_logical = 0
        self._free_logical: list[int] = []   # released ids, recycled first
        self._regions: dict[int, Region] = {}
        self._next_region = 0
        self.nodes = [NodeState() for _ in range(num_nodes)]
        self._failed_link_direction: Optional[int] = None
        # Optional flight recorder (repro.obs.flight.FlightRecorder);
        # duck-typed so repro.core keeps no import-time obs dependency.
        self.flight = None

    # -- flight journal --------------------------------------------------------
    def attach_flight(self, recorder) -> None:
        """Journal every subsequent decision into ``recorder``.

        Records a ``cp_init`` genesis carrying the constructor arguments
        *and* a full placement-state snapshot (tables, free lists, RNG
        state, live regions), so a journal attached mid-life still
        replays bit-identically from its own first record.
        """
        self.flight = recorder
        topo = self.topology
        recorder.record(
            "cp_init", num_nodes=self.num_nodes,
            pages_per_node=self.pages_per_node,
            num_logical=self.num_logical, seed=self._seed,
            group_sizes=np.asarray(topo.group_sizes).tolist(),
            topo_hw=[topo.board_hop_us, topo.rack_hop_us,
                     topo.board_link_gbps, topo.rack_link_gbps],
            state=self._state_snapshot())

    def _state_snapshot(self) -> dict:
        return {
            "home": self._home.tolist(),
            "slot": self._slot.tolist(),
            "free": [list(f) for f in self._free],
            "free_logical": list(self._free_logical),
            "next_logical": self._next_logical,
            "next_region": self._next_region,
            "alive": [bool(n.alive) for n in self.nodes],
            "failed_link": self._failed_link_direction,
            "rng_state": self._rng.bit_generator.state,
            "regions": {str(rid): {
                "name": r.name, "policy": r.policy,
                "page_ids": np.asarray(r.page_ids).tolist()}
                for rid, r in self._regions.items()},
        }

    def _journal(self, kind: str, **detail) -> None:
        if self.flight is not None:
            self.flight.record(kind, **detail)

    # -- table export ---------------------------------------------------------
    def table(self) -> MemPortTable:
        import jax.numpy as jnp
        return MemPortTable(home=jnp.asarray(self._home, jnp.int32),
                            slot=jnp.asarray(self._slot, jnp.int32))

    def free_slots(self, node: int) -> int:
        return len(self._free[node])

    def free_logical(self) -> int:
        """Unclaimed logical page ids (released-and-recycled + never minted).

        The admission-control side of capacity: an allocation needs this
        many ids free *and* enough physical slots (``free_slots``)."""
        return (len(self._free_logical)
                + self.num_logical - self._next_logical)

    @property
    def alive_nodes(self) -> list[int]:
        return [i for i, n in enumerate(self.nodes) if n.alive]

    # -- allocation -----------------------------------------------------------
    def _take_logical(self, num_pages: int) -> np.ndarray:
        """Claim ``num_pages`` logical ids, recycling released ones first.

        ``_next_logical`` alone is monotonic: allocate/release churn (lease
        turnover in the orchestrator) would exhaust the logical space while
        the pool still has free slots.  Released ids return via
        :meth:`release` and are handed out again (lowest first, for
        deterministic placement) before fresh ids are minted.
        """
        fresh = self.num_logical - self._next_logical
        if num_pages > len(self._free_logical) + fresh:
            raise RuntimeError("logical page space exhausted")
        self._free_logical.sort()
        reuse = self._free_logical[:num_pages]
        del self._free_logical[:num_pages]
        n_new = num_pages - len(reuse)
        ids = np.asarray(
            reuse + list(range(self._next_logical,
                               self._next_logical + n_new)), np.int64)
        self._next_logical += n_new
        return ids

    def allocate(self, num_pages: int, name: str = "",
                 policy: Policy = "striped", affinity: int = 0) -> Region:
        alive = self.alive_nodes
        if not alive:
            raise RuntimeError("no alive nodes")
        if policy == "striped":
            homes = [alive[i % len(alive)] for i in range(num_pages)]
        elif policy == "hashed":
            homes = [alive[int(self._rng.integers(len(alive)))]
                     for _ in range(num_pages)]
        elif policy == "affinity":
            if not 0 <= affinity < self.num_nodes:
                raise ValueError(f"affinity node {affinity} out of range")
            homes = [affinity] * num_pages
        else:
            raise ValueError(policy)
        ids = self._take_logical(num_pages)
        for pid, h in zip(ids, homes):
            # A dead affinity target must not home pages even when its free
            # list still has entries (a monitor may mark a node dead without
            # a fail_node remap — its slots are quarantined, not reusable).
            if not self._free[h] or not self.nodes[h].alive:
                # Topology-aware spill: a full/dead home overflows onto its
                # own board first (board-ring traffic instead of rack-ring),
                # then onto the globally emptiest survivor.
                h = max(alive, key=lambda n: (
                    len(self._free[n]) > 0
                    and self.topology.group[n] == self.topology.group[h],
                    len(self._free[n])))
                if not self._free[h]:
                    # Roll the partial allocation back: slots placed so far
                    # return to their free lists, every claimed id is
                    # recycled.
                    for i in ids:
                        if self._home[i] != FREE:
                            self._free[int(self._home[i])].append(
                                int(self._slot[i]))
                            self._home[i] = FREE
                            self._slot[i] = FREE
                        self._free_logical.append(int(i))
                    raise RuntimeError("pool out of slots")
            s = self._free[h].pop(0)
            self._home[pid] = h
            self._slot[pid] = s
        region = Region(self._next_region, name or f"region{self._next_region}",
                        ids, policy)
        self._regions[region.region_id] = region
        self._next_region += 1
        if self.flight is not None:
            self._journal(
                "allocate", num_pages=num_pages, name=region.name,
                policy=policy, affinity=affinity, region_id=region.region_id,
                page_ids=ids.tolist(),
                homes=[int(self._home[i]) for i in ids],
                slots=[int(self._slot[i]) for i in ids])
        return region

    def release(self, region: Region) -> None:
        if region.region_id not in self._regions:
            # Stale handle: the region was already released.  With logical
            # ids recycled on release, acting on a stale handle would free
            # pages now owned by a *different* region (alias two tenants);
            # idempotence here is what makes recycling safe.
            return
        for pid in region.page_ids:
            h, s = int(self._home[pid]), int(self._slot[pid])
            if h == FREE:
                # Unplaced id (defensive): nothing to free.
                continue
            # Slot quarantine: a dead node's slots must not return to its
            # free list (a monitor may mark a node dead before/without a
            # fail_node remap).  revive_node rebuilds the free list from the
            # table, so slots released while the node was down reappear then.
            if self.nodes[h].alive:
                self._free[h].append(s)
            self._home[pid] = FREE
            self._slot[pid] = FREE
            # Logical ids are recycled (lease churn must not exhaust the
            # monotonic id space while the pool has free slots).
            self._free_logical.append(int(pid))
        self._regions.pop(region.region_id, None)
        if self.flight is not None:
            self._journal("release", region_id=region.region_id,
                          page_ids=np.asarray(region.page_ids).tolist())

    # -- failure handling (elastic remap) --------------------------------------
    def fail_node(self, node: int) -> list[MigrationStep]:
        """Mark ``node`` dead; re-home its pages; return the migration plan.

        The *data* on the failed node is gone — the plan's executor decides
        whether the new slots are refilled from a checkpoint shard, from a
        replica, or recomputed (KV pages: sequence is re-prefetched).
        """
        self.nodes[node].alive = False
        survivors = self.alive_nodes
        if not survivors:
            raise RuntimeError("all nodes dead")
        plan: list[MigrationStep] = []
        victims = np.nonzero(self._home == node)[0]
        for i, pid in enumerate(victims):
            h = survivors[i % len(survivors)]
            if not self._free[h]:
                h = max(survivors, key=lambda n: len(self._free[n]))
                if not self._free[h]:
                    raise RuntimeError("survivors out of slots during remap")
            s = self._free[h].pop(0)
            plan.append(MigrationStep(int(pid), node, int(self._slot[pid]),
                                      int(h), int(s)))
            self._home[pid] = h
            self._slot[pid] = s
        # Failed node's slots return to a quarantine (not reusable).
        self._free[node] = []
        self._journal("fail_node", node=node,
                      plan=[[s.page_id, s.old_home, s.old_slot,
                             s.new_home, s.new_slot] for s in plan])
        return plan

    def revive_node(self, node: int) -> None:
        self.nodes[node].alive = True
        self._free[node] = [s for s in range(self.pages_per_node)
                            if not np.any((self._home == node)
                                          & (self._slot == s))]
        self._journal("revive_node", node=node)

    # -- straggler mitigation ---------------------------------------------------
    def record_step_time(self, node: int, seconds: float) -> None:
        t = self.nodes[node].step_times
        t.append(seconds)
        if len(t) > 32:
            del t[:-32]

    def detect_stragglers(self, threshold: float = 1.5) -> list[int]:
        med = np.median([np.mean(n.step_times) for n in self.nodes
                         if n.alive and n.step_times] or [0.0])
        out = []
        for i, n in enumerate(self.nodes):
            if n.alive and n.step_times and np.mean(n.step_times) > threshold * med:
                out.append(i)
        return out

    def rate_limits(self, static_budget: int, threshold: float = 1.5,
                    factor: float = 0.5, telemetry=None) -> np.ndarray:
        """Per-node ``active_budget`` vector for the bridge (runtime input).

        Three layers, weakest to strongest:

        * straggler throttling from step-time telemetry (the static policy);
        * **measured feedback** (``telemetry``: a
          :class:`~repro.telemetry.TelemetryAggregator`): a node whose
          observed spill rate is positive is having real requests dropped by
          the limiter — its budget is restored to ``static_budget``, so one
          measure -> recompile iteration drives spills to zero;
        * a manual per-node override (:attr:`NodeState.budget` > 0) pinned
          by the operator, which wins over both.
        """
        budgets = np.full((self.num_nodes,), static_budget, np.int32)
        for i in self.detect_stragglers(threshold):
            budgets[i] = max(1, int(static_budget * factor))
        if telemetry is not None:
            # Key on the LAST measurement's raw spills where available: the
            # EWMA rate only decays and would keep overriding the straggler
            # throttle long after the drops stopped.  A bare BridgeTelemetry
            # (one step's counters) works too via its ``spilled`` field.
            spill = np.asarray(
                telemetry.last_spilled if hasattr(telemetry, "last_spilled")
                else telemetry.spilled).reshape(-1)
            for i in range(min(self.num_nodes, spill.shape[0])):
                if spill[i] > 0:
                    budgets[i] = static_budget
        for i, node in enumerate(self.nodes):
            if node.budget > 0:
                budgets[i] = node.budget
        return budgets

    # -- circuit scheduling ------------------------------------------------------
    def report_link_failure(self, direction: int) -> None:
        """Record a failed directed ring link (from ``repro.ft`` telemetry).

        ``direction`` is +1 (a clockwise serdes lane died) or -1.  Any
        circuit in that direction crosses every directed link of the ring,
        so subsequent :meth:`route_program` calls route all traffic the
        other way round.
        """
        if direction not in (1, -1):
            raise ValueError("direction must be +1 or -1")
        self._failed_link_direction = direction
        self._journal("link_failure", direction=direction)

    def clear_link_failure(self) -> None:
        self._failed_link_direction = None
        self._journal("link_clear")

    def live_distances(self, requesters: Optional[list[int]] = None
                       ) -> list[int]:
        """Ring distances that can carry traffic under current placement.

        A distance d is live iff some requester r could address a page homed
        at (r + d) mod N.  ``requesters`` defaults to every mesh rank — a
        failed node loses its *memory*, not its mesh slot: the rank keeps
        issuing bridge requests (the mesh never shrinks), so the distances
        it needs must stay wired or its traffic is silently FREE-masked.
        """
        if requesters is None:
            requesters = range(self.num_nodes)
        homed = set(np.nonzero(self.occupancy() > 0)[0].tolist())
        dists = {(h - r) % self.num_nodes
                 for h in homed for r in requesters}
        return sorted(dists - {0})

    def route_program(self, requesters: Optional[list[int]] = None,
                      bidirectional: bool = True, prune: bool = True,
                      telemetry=None, program: Optional[
                          steering.RouteProgram] = None,
                      verify: bool = True) -> steering.RouteProgram:
        """Compile (or verify-and-install) the bridge's circuit schedule.

        With ``program=None`` the schedule is compiled from placement /
        telemetry (see :meth:`_compile_route_program`); passing a
        hand-constructed :class:`~repro.core.steering.RouteProgram` makes
        this the *install path* for externally built schedules.  Either
        way, ``verify=True`` (the default) runs the static verifier
        (:func:`repro.analysis.program_check.check_program`) against the
        plane's topology and raises
        :class:`~repro.analysis.findings.ProgramVerificationError` — with
        the structured finding list — instead of silently handing the
        datapath a schedule that would drop, double-serve or collide
        traffic.  ``verify=False`` is the escape hatch for callers that
        *want* an unchecked install (benchmarked fault injection).
        """
        compiled = program is None
        if compiled:
            program = self._compile_route_program(
                requesters, bidirectional=bidirectional, prune=prune,
                telemetry=telemetry)
        if verify:
            # Local import: keeps repro.core free of an import-time
            # dependency on the analysis package.
            from repro.analysis.findings import ProgramVerificationError
            from repro.analysis.findings import errors as _errors
            from repro.analysis.program_check import check_program

            bad = _errors(check_program(program, self.topology))
            if bad:
                raise ProgramVerificationError(bad)
        if self.flight is not None:
            from repro.obs import flight as _fl

            snap = (_fl.route_telemetry_snapshot(telemetry)
                    if compiled else None)
            measured = bool(snap is not None and snap["dist"]
                            and sum(snap["dist"]) > 0)
            self._journal(
                "route_program", compiled=compiled,
                requesters=(None if requesters is None
                            else [int(r) for r in requesters]),
                bidirectional=bidirectional, prune=prune, verified=verify,
                variant=_fl.route_variant(
                    compiled=compiled,
                    hierarchical=self.topology.num_groups > 1,
                    failed_link=self._failed_link_direction is not None,
                    bidirectional=bidirectional, measured=measured),
                telemetry=snap, program=_fl.program_to_dict(program),
                digest=_fl.program_digest(program))
        return program

    def _compile_route_program(self, requesters: Optional[list[int]] = None,
                               bidirectional: bool = True, prune: bool = True,
                               telemetry=None) -> steering.RouteProgram:
        """Compile the bridge's runtime circuit schedule (no recompilation).

        Like :meth:`rate_limits`, the result is a *step input*: the
        orchestrator calls this after every placement change / telemetry
        event and feeds the program to ``pull_pages`` / ``push_pages``.
        Combines the policies:

        * bidirectional min(d, N-d) routing (⌊N/2⌋ epochs instead of N-1),
        * pruning of distances with zero homed pages in reach,
        * rerouting around a failed directed ring link (everything drives
          the surviving direction),
        * **measured steering** (``telemetry``: a
          :class:`~repro.telemetry.TelemetryAggregator` or a raw ``[N-1]``
          per-distance load vector): circuit pruning from distances that
          *measurably* carry traffic instead of placement reachability, and
          a load-balanced direction assignment putting each live distance on
          the direction that minimizes the bottleneck direction's bytes
          (``steering.load_balanced_program``).  An empty measurement (no
          traffic observed yet) falls back to the placement-based compile.

        Censorship guard: only served requests are binned by distance, so a
        measurement taken while the limiter spilled (or a previous program
        pruned) requests is blind to the demand it dropped.  While the
        aggregate shows drops, distances are *not* pruned — every distance
        stays wired as a zero-weight free rider of the balanced split —
        and pruning resumes after the first clean (drop-free) measurement.
        """
        n = self.num_nodes
        w = None
        if telemetry is not None:
            w = np.asarray(telemetry.distance_pages()
                           if hasattr(telemetry, "distance_pages")
                           else telemetry, float).reshape(-1)
            if w.sum() <= 0:
                w = None  # nothing measured yet: steer from placement
        # The guard reads the LAST measurement's raw drops (an aggregator's
        # EWMA decays but never reaches zero); a bare BridgeTelemetry's
        # spilled/pruned are per-step already.
        drops = 0.0
        for names in (("last_spilled", "last_pruned"), ("spilled", "pruned")):
            if telemetry is not None and any(hasattr(telemetry, f)
                                             for f in names):
                drops = sum(float(np.asarray(getattr(telemetry, f)).sum())
                            for f in names if hasattr(telemetry, f))
                break
        measured_prune = prune and drops <= 0
        if (self.topology.num_groups > 1 and bidirectional
                and self._failed_link_direction is None):
            # Board + rack fabric: compile the two-tier schedule (intra-board
            # epochs concurrent across boards, exclusive gateway epochs).
            # The censorship guard applies unchanged: a measurement taken
            # while requests were dropped prunes nothing.  A failed ring
            # link falls through to the flat link-avoiding compile (every
            # circuit of one direction is lost on both tiers alike).
            if w is not None:
                wi = (np.asarray(telemetry.distance_intra_pages(),
                                 float).reshape(-1)
                      if hasattr(telemetry, "distance_intra_pages") else None)
                return steering.hierarchical_program(
                    self.topology, dist_weight=w, prune=measured_prune,
                    intra_weight=wi)
            if not prune:
                return steering.hierarchical_program(self.topology)
            return steering.hierarchical_program(
                self.topology, live_distances=self.live_distances(requesters))
        if self._failed_link_direction is not None:
            base = steering.link_avoiding_program(
                n, self._failed_link_direction)
            if not prune:
                return base
            if w is not None:
                live = ((np.nonzero(w > 0)[0] + 1).tolist() if measured_prune
                        else self.live_distances(requesters))
            else:
                live = self.live_distances(requesters)
            return steering.pruned_program(base, live)
        if w is not None and bidirectional:
            return steering.load_balanced_program(n, w, prune=measured_prune)
        if bidirectional:
            base = steering.bidirectional_program(n)
        else:
            # bidirectional=False pins one ring direction: honour it even
            # under measured steering (there is nothing to balance), only
            # the pruning side of the measurement applies.
            base = steering.unidirectional_program(n)
        if not prune:
            return base
        if w is not None and measured_prune:
            return steering.pruned_program(base,
                                           (np.nonzero(w > 0)[0] + 1).tolist())
        return steering.pruned_program(base, self.live_distances(requesters))

    def select_channels(self, budget: int, page_bytes: int, telemetry=None,
                        max_channels: int = 8, program=None,
                        calibrator=None) -> int:
        pick = self._select_channels(budget, page_bytes, telemetry,
                                     max_channels, program, calibrator)
        if self.flight is not None:
            from repro.obs import flight as _fl

            self._journal(
                "select_channels", budget=budget, page_bytes=page_bytes,
                max_channels=max_channels,
                telemetry=_fl.wire_telemetry_snapshot(telemetry),
                calibrator=_fl.calibrator_snapshot(calibrator),
                program=(None if program is None
                         else _fl.program_to_dict(program)),
                pick=pick)
        return pick

    def _select_channels(self, budget: int, page_bytes: int, telemetry=None,
                         max_channels: int = 8, program=None,
                         calibrator=None) -> int:
        """Pick the bridge's pipeline depth from measured wire occupancy.

        The pipelined round engine (``pull_pages``/``push_pages``
        ``channels=``) overlaps chunk g+1's request flits with chunk g's
        data flits, hiding min(wire, RTT) behind max(wire, RTT) with
        1/channels of the hidden term left exposed as pipeline fill/drain
        (``perfmodel._overlap_round_us``).  Doubling the depth halves that
        exposure, so the smallest power-of-two depth leaving under ~10 % of
        the round exposed is chosen, capped at ``max_channels`` and the
        lane ``budget`` (a chunk needs at least one lane).

        ``telemetry`` is a :class:`~repro.telemetry.TelemetryAggregator`
        (or one step's raw :class:`~repro.telemetry.counters.BridgeTelemetry`);
        the measured per-direction wire pages give the round's wire time and
        the deepest measurably-live distance its RTT.  Pass the active
        :class:`~repro.core.steering.RouteProgram` as ``program`` to price
        RTT from the hops each circuit *actually drives*: a unidirectional,
        pruned or load-balanced schedule may route a distance the long way
        round, and the shortest-way fallback would underestimate its RTT —
        keeping the engine serial in exactly the latency-bound regime where
        overlap wins.  With no measurement — or no circuit traffic observed
        — the serial engine (1) is kept: overlap is pure win only once the
        wire is demonstrably busy, and an idle bridge should not pay the
        deeper engine's compiled datapath.

        ``calibrator`` is a fitted :class:`~repro.core.perfmodel.Calibrator`
        (ignored until it has enough samples): the wire/RTT terms are then
        priced with the **fitted** hop latency and payload bandwidth, and
        doubling the depth must also beat the fitted per-chunk dispatch
        overhead — the software cost that made deep pipelines a measured
        loss on fabrics where dispatch dominates flight time (the PR 4
        regression the static model could not see).
        """
        from repro.core import perfmodel
        hw = perfmodel.TPU_HW
        chunk_us = 0.0
        if calibrator is not None and calibrator.fitted:
            hw = calibrator.hw()
            chunk_us = calibrator.chunk_overhead_us
        if telemetry is None or budget < 2:
            return 1
        if hasattr(telemetry, "link_pages"):          # TelemetryAggregator
            lp = telemetry.link_pages()
            cw, ccw = float(lp["cw"]), float(lp["ccw"])
            dist = np.asarray(telemetry.distance_pages(), float)
            served = np.asarray(telemetry.served, float)
        else:                                         # raw BridgeTelemetry
            cw = float(np.asarray(telemetry.epoch_cw).sum())
            ccw = float(np.asarray(telemetry.epoch_ccw).sum())
            s = np.asarray(telemetry.slot_served)
            dist = s.reshape((-1, s.shape[-1])).sum(0).astype(float)
            served = np.asarray(telemetry.served_total(), float).reshape(-1)
        busy = max(cw, ccw)
        if busy <= 0 or not (dist > 0).any():
            return 1
        n = self.num_nodes
        live_d = np.nonzero(dist > 0)[0] + 1
        if program is not None:
            # The schedule's real per-slot hop counts (long-way routes pay
            # their full depth), restricted to measurably-loaded live slots.
            hops = np.abs(np.asarray(program.offsets))
            lv = np.asarray(program.live)
            loaded = [d - 1 for d in live_d if lv[d - 1]]
            deepest = int(hops[loaded].max()) if loaded else 0
        else:
            deepest = max(min(int(d), n - int(d)) for d in live_d)
        if deepest == 0:
            return 1
        rtt_us = 2.0 * deepest * hw.ici_hop_latency_us
        # Per-round wire time on the busier direction: the measurement spans
        # however many rounds the busiest requester needed.
        rounds = max(1.0, float(np.ceil(served.max() / max(budget, 1))))
        wire_us = busy / rounds * page_bytes / (hw.ici_link_gbps * 1e9) * 1e6
        hidden, exposed = min(wire_us, rtt_us), max(wire_us, rtt_us)
        if hidden <= 0:
            return 1
        depth = 1
        while depth < min(max_channels, budget):
            # Doubling the depth recovers half the remaining exposure but
            # dispatches ``depth`` more chunks per round; with a fitted
            # calibrator that software cost is known and must be beaten.
            saved = hidden / depth - hidden / (2 * depth)
            if hidden / depth <= 0.1 * exposed or saved <= chunk_us * depth:
                break
            depth *= 2
        return min(depth, budget, max_channels)

    def affinity_migration(self, telemetry, min_share: float = 0.5,
                           limit: Optional[int] = None
                           ) -> list[MigrationStep]:
        """Re-home hot pages toward their dominant requester (measured).

        For every home node whose measured traffic (the aggregator's EWMA
        requester->home matrix) is dominated by one *remote* requester —
        its share of all pages served from that home exceeds ``min_share``
        — pages homed there migrate into the dominant requester's free
        slots, turning circuit traffic into loopback hits.  On a
        hierarchical fabric the migration is topology-aware: once the
        dominant requester itself is full, pages homed on *another board*
        keep moving into the requester's board mates (rack-ring traffic
        becomes board-ring traffic — the next-best home).  The placement
        table is updated (a runtime reprogram, like :meth:`fail_node`) and
        the plan is returned for the executor to copy page contents.
        ``limit`` caps the total moves per call (migration bandwidth).
        """
        tm = np.asarray(telemetry.traffic_matrix()
                        if hasattr(telemetry, "traffic_matrix")
                        else telemetry, float)
        if tm.shape != (self.num_nodes, self.num_nodes):
            raise ValueError(f"traffic matrix shape {tm.shape} != "
                             f"({self.num_nodes}, {self.num_nodes})")
        plan: list[MigrationStep] = []
        for h in range(self.num_nodes):
            if limit is not None and len(plan) >= limit:
                break
            # Slot quarantine (symmetric to release()): a dead home is no
            # migration source — its data is gone and its vacated slots must
            # not re-enter the free list.  fail_node owns that path.
            if not self.nodes[h].alive:
                continue
            r, share = dominant_requester(tm, h)
            if r == h or share < min_share:
                continue
            if not self.nodes[r].alive:
                continue
            # Intra-board preference: the requester itself first (loopback),
            # then — only when the page currently lives on a different
            # board — the requester's board mates (rack -> board win).
            group = self.topology.group
            targets = [r]
            if group[h] != group[r]:
                targets += sorted(
                    (m for m in self.alive_nodes
                     if m != r and m != h and group[m] == group[r]),
                    key=lambda m: -len(self._free[m]))
            for pid in np.nonzero(self._home == h)[0]:
                if limit is not None and len(plan) >= limit:
                    break
                t = next((m for m in targets if self._free[m]), None)
                if t is None:
                    break
                s = self._free[t].pop(0)
                plan.append(MigrationStep(int(pid), h, int(self._slot[pid]),
                                          t, s))
                self._free[h].append(int(self._slot[pid]))
                self._home[pid] = t
                self._slot[pid] = s
        if self.flight is not None:
            self._journal(
                "migration", traffic=tm.tolist(), min_share=min_share,
                limit=limit, plan=[[s.page_id, s.old_home, s.old_slot,
                                    s.new_home, s.new_slot] for s in plan])
        return plan

    # -- introspection ----------------------------------------------------------
    def occupancy(self) -> np.ndarray:
        occ = np.zeros((self.num_nodes,), np.int64)
        for h in self._home:
            if h != FREE:
                occ[h] += 1
        return occ

    def describe(self) -> str:
        occ = self.occupancy()
        lines = [f"pool: {self.num_nodes} nodes x {self.pages_per_node} slots"]
        for i, n in enumerate(self.nodes):
            lines.append(
                f"  node {i}: {'up ' if n.alive else 'DOWN'} occ={occ[i]}"
                f" free={len(self._free[i])}")
        return "\n".join(lines)
