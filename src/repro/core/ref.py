"""Oracles for the bridge transfer engine (no collectives).

These compute the same results as :mod:`repro.core.bridge` by direct global
gather/scatter through the memport table.  Property tests assert bridge ==
oracle for randomized placements, request lists, budgets and route programs.

:func:`expected_transfer_telemetry` is the oracle for the measurement plane:
a per-request numpy walk (independent of the datapath's masked-sum
implementation) that the bridge's ``collect_telemetry`` counters must match
exactly.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.memport import MemPortTable
from repro.core.steering import RouteProgram
from repro.core.topology import Topology


def flat_index(table: MemPortTable, page_ids: jnp.ndarray,
               pages_per_node: int) -> jnp.ndarray:
    """logical page -> row in the node-major global pool array."""
    home, slot = table.translate(page_ids)
    flat = home * pages_per_node + slot
    return jnp.where((home >= 0) & (slot >= 0), flat, -1)


def served_mask(table: MemPortTable, ids: jnp.ndarray,
                program: Optional[RouteProgram]) -> jnp.ndarray:
    """bool[num_nodes, R]: is this request's ring distance wired?

    Row i of ``ids`` is node i's request list; distance 0 (the loopback
    fast path) is always wired, other distances only if the program's slot
    is live AND the program's group mask wires it for requester i (the
    hierarchical per-rank refinement).  ``program=None`` means full
    coverage (everything served).
    """
    if program is None:
        return jnp.ones(ids.shape, bool)
    n = program.num_nodes
    home, _ = table.translate(ids)
    if n == 1:
        return home >= 0  # only the loopback fast path exists
    me = jnp.arange(ids.shape[0])[:, None]
    dist = jnp.mod(home - me, n)
    slot = (dist - 1).clip(0, n - 2)
    rank = me.clip(0, n - 1)
    wired = program.live[slot] & (program.rank_epoch[slot, rank] >= 0)
    return jnp.where(home >= 0, (dist == 0) | wired, False)


def pull_pages_ref(pool_pages: jnp.ndarray, want: jnp.ndarray,
                   table: MemPortTable, pages_per_node: int,
                   program: Optional[RouteProgram] = None) -> jnp.ndarray:
    """Oracle for :func:`repro.core.bridge.pull_pages`.

    Args:
      pool_pages: [num_nodes * pages_per_node, *page_shape] (global view).
      want: [num_nodes, R] logical ids (FREE-padded).
      program: optional route program; requests whose ring distance has no
        wired circuit come back as zeros (matching the bridge's FREE-mask).
    Returns: [num_nodes, R, *page_shape].
    """
    flat = flat_index(table, want.reshape(-1), pages_per_node)
    flat = jnp.where(served_mask(table, want, program).reshape(-1), flat, -1)
    valid = flat >= 0
    safe = jnp.where(valid, flat, 0)
    out = pool_pages[safe]
    mask = valid.reshape(valid.shape + (1,) * (out.ndim - 1))
    out = jnp.where(mask, out, jnp.zeros_like(out))
    return out.reshape(want.shape + pool_pages.shape[1:])


def rate_limit_mask(num_requests: int, budget: int, active_budget,
                    overprovision: int = 1) -> np.ndarray:
    """bool[num_requests]: which request indices the rate limiter serves.

    Round ``r`` serves indices [r*ab, (r+1)*ab): everything past
    ``rounds * ab`` spills off the (overprovisioned) round budget.  Used to
    build throttled-transfer expectations for both pull and push.
    """
    from repro.core import steering
    rounds = steering.num_rounds(num_requests, budget, overprovision)
    ab = int(np.clip(np.asarray(active_budget).reshape(-1)[0], 0, budget))
    return np.arange(num_requests) < rounds * ab


def expected_transfer_telemetry(ids, table: MemPortTable,
                                program: Optional[RouteProgram], *,
                                num_nodes: int, budget: int,
                                active_budget=None, overprovision: int = 1,
                                topology: Optional[Topology] = None,
                                tenant_ids=None, max_tenants: int = 0):
    """Oracle for ``pull_pages`` / ``push_pages`` ``collect_telemetry``.

    Walks every request of every row (row i = requester i) with plain
    python/numpy — deliberately nothing like the datapath's masked segment
    sums — and bins it the way the bridge must have: rate-limiter spill,
    loopback hit, pruned-circuit drop (whole distance dead or this rank's
    pairing group-masked), or served by its distance's slot at the epoch
    the program assigns *this requester*.  Per-tier counters (intra-board
    pages, board/rack page-hops) follow the :mod:`repro.core.topology`
    realization contract; ``topology=None`` means the flat single-board
    fabric.

    ``active_budget`` may be per-requester ([rows]) for the N-device path or
    a scalar shared by every row (what the loopback path actually applies).
    Returns a :class:`~repro.telemetry.counters.BridgeTelemetry` with
    [rows, ...] leaves.

    ``tenant_ids`` ([rows, r], aligned with ``ids``; None = all tenant 0)
    attributes every outcome to its request's tenant exactly like the
    datapath's tenant lane: ids clip into [0, max_tenants), so the
    per-tenant served/spilled/pruned histograms always sum back to the
    untagged counters.  ``max_tenants=0`` uses the default static width.
    """
    from repro.core import steering
    from repro.telemetry.counters import (BridgeTelemetry,
                                          DEFAULT_MAX_TENANTS,
                                          num_epoch_bins)

    ids = np.asarray(ids)
    rows, r = ids.shape
    n = num_nodes
    if max_tenants <= 0:
        max_tenants = DEFAULT_MAX_TENANTS
    if tenant_ids is None:
        tenant = np.zeros((rows, r), np.int64)
    else:
        tenant = np.asarray(tenant_ids, np.int64).reshape(rows, r)
    tenant = np.clip(tenant, 0, max_tenants - 1)
    rounds = steering.num_rounds(r, budget, overprovision)
    ab = np.broadcast_to(
        np.asarray(budget if active_budget is None else active_budget,
                   np.int64).reshape(-1), (rows,))
    if program is None:
        program = steering.bidirectional_program(n)
    if topology is None:
        topology = Topology.flat(n)
    live = np.asarray(program.live)
    off = np.asarray(program.offsets)
    rank_epoch = np.asarray(program.rank_epoch)
    home_col = np.asarray(table.home)

    s = max(n - 1, 0)
    e = num_epoch_bins(n)
    slot_served = np.zeros((rows, s), np.int32)
    loopback = np.zeros((rows,), np.int32)
    spilled = np.zeros((rows,), np.int32)
    pruned = np.zeros((rows,), np.int32)
    traffic = np.zeros((rows, n), np.int32)
    epoch_cw = np.zeros((rows, e), np.int32)
    epoch_ccw = np.zeros((rows, e), np.int32)
    slot_intra = np.zeros((rows, s), np.int32)
    tier_hops = np.zeros((rows, 2), np.int32)
    tenant_served = np.zeros((rows, max_tenants), np.int32)
    tenant_spilled = np.zeros((rows, max_tenants), np.int32)
    tenant_pruned = np.zeros((rows, max_tenants), np.int32)
    for i in range(rows):
        lim = rounds * int(np.clip(ab[i], 0, budget))
        for j, pid in enumerate(ids[i]):
            if pid < 0 or home_col[pid] < 0:
                continue  # FREE hole or unmapped page: not a live request
            t = int(tenant[i, j])
            if j >= lim:
                spilled[i] += 1
                tenant_spilled[i, t] += 1
                continue
            h = int(home_col[pid])
            d = (h - i) % n
            if d == 0:
                loopback[i] += 1
                traffic[i, h] += 1
                tenant_served[i, t] += 1
                continue
            if not live[d - 1] or rank_epoch[d - 1, i] < 0:
                pruned[i] += 1
                tenant_pruned[i, t] += 1
                continue
            slot_served[i, d - 1] += 1
            traffic[i, h] += 1
            tenant_served[i, t] += 1
            bins = epoch_cw if off[d - 1] > 0 else epoch_ccw
            bins[i, rank_epoch[d - 1, i]] += 1
            sign = 1 if off[d - 1] > 0 else -1
            if topology.pair_intra(i, h):
                slot_intra[i, d - 1] += 1
            bh, rh = topology.pair_hops(i, h, sign)
            tier_hops[i, 0] += int(bh)
            tier_hops[i, 1] += int(rh)
    return BridgeTelemetry(
        slot_served=jnp.asarray(slot_served),
        loopback_served=jnp.asarray(loopback),
        spilled=jnp.asarray(spilled), pruned=jnp.asarray(pruned),
        traffic=jnp.asarray(traffic), epoch_cw=jnp.asarray(epoch_cw),
        epoch_ccw=jnp.asarray(epoch_ccw),
        slot_intra=jnp.asarray(slot_intra),
        tier_hops=jnp.asarray(tier_hops),
        tenant_served=jnp.asarray(tenant_served),
        tenant_spilled=jnp.asarray(tenant_spilled),
        tenant_pruned=jnp.asarray(tenant_pruned))


def push_pages_ref(pool_pages: jnp.ndarray, dest: jnp.ndarray,
                   payload: jnp.ndarray, table: MemPortTable,
                   pages_per_node: int,
                   program: Optional[RouteProgram] = None) -> jnp.ndarray:
    """Oracle for :func:`repro.core.bridge.push_pages`."""
    flat = flat_index(table, dest.reshape(-1), pages_per_node)
    flat = jnp.where(served_mask(table, dest, program).reshape(-1), flat, -1)
    safe = jnp.where(flat >= 0, flat, pool_pages.shape[0])
    pay = payload.reshape((-1,) + payload.shape[2:]).astype(pool_pages.dtype)
    return pool_pages.at[safe].set(pay, mode="drop")


# ---------------------------------------------------------------------------
# Pipelined multi-channel round engine oracles
# ---------------------------------------------------------------------------

def pipeline_schedule(num_requests: int, budget: int, channels: int,
                      active_budget=None,
                      overprovision: int = 1) -> list[np.ndarray]:
    """The multi-channel engine's chunk schedule as logical request indices.

    Walks exactly what ``bridge._pull_local`` / ``_push_local`` execute with
    ``channels`` virtual channels — round windows of ``budget`` lanes
    starting at ``round * active_budget``, split into chunks of
    ``ceil(budget / channels)`` lanes, lanes past the (clamped) live budget
    or the request array masked off — in *drain order* (the order chunk
    outputs retire from the pipeline, one chunk behind their issue).  The
    conformance properties the pipelined datapath must satisfy fall out of
    this schedule alone:

    * concatenated, it is a permutation-free, duplicate-free enumeration of
      the rate limiter's served window (``rate_limit_mask``);
    * it is **independent of results**: any ``channels`` serves the same
      indices, so the pipelined engine is bit-exact vs the serial one.
    """
    from repro.core import steering
    rounds = steering.num_rounds(num_requests, budget, overprovision)
    padded_len = rounds * budget
    ab = int(np.clip(np.asarray(
        budget if active_budget is None else active_budget
    ).reshape(-1)[0], 0, budget))
    cb = -(-budget // max(channels, 1))
    chunks: list[np.ndarray] = []
    for r in range(rounds):
        base = r * ab
        for c in range(max(channels, 1)):
            lanes = c * cb + np.arange(cb)
            idx = base + lanes
            chunks.append(idx[(lanes < ab) & (idx < padded_len)])
    return chunks


def pull_pages_pipelined_ref(pool_pages: jnp.ndarray, want: jnp.ndarray,
                             table: MemPortTable, pages_per_node: int,
                             program: Optional[RouteProgram] = None, *,
                             budget: int, channels: int, active_budget=None,
                             overprovision: int = 1) -> jnp.ndarray:
    """Oracle for the pipelined pull engine (``channels`` virtual channels).

    Simulates the engine's chunk schedule independently of the datapath —
    issue one chunk ahead, drain one chunk behind, epilogue drain — and
    serves each scheduled index through the same translate/steer rules as
    :func:`pull_pages_ref`.  For every ``channels`` (including 1) the
    result must equal the serial oracle under the rate-limiter mask: the
    pipeline reorders wire traffic, never what is served.
    """
    want_np = np.asarray(want)
    rows, r = want_np.reshape((-1, want_np.shape[-1])).shape
    want2 = want_np.reshape((rows, r))
    flat = np.asarray(flat_index(table, jnp.asarray(want2.reshape(-1)),
                                 pages_per_node)).reshape(rows, r)
    smask = np.asarray(served_mask(table, jnp.asarray(want2), program))
    pool = np.asarray(pool_pages)
    out = np.zeros((rows, r) + pool.shape[1:], pool.dtype)
    ab = np.broadcast_to(np.asarray(
        budget if active_budget is None else active_budget,
        np.int64).reshape(-1), (rows,))
    for i in range(rows):
        in_flight: Optional[np.ndarray] = None    # the double buffer
        for chunk in pipeline_schedule(r, budget, channels, ab[i],
                                       overprovision) + [None]:
            drain, in_flight = in_flight, chunk   # issue ahead, drain behind
            if drain is None:
                continue                          # pipeline prologue
            for dest in drain:
                if dest < r and smask[i, dest] and flat[i, dest] >= 0:
                    out[i, dest] = pool[flat[i, dest]]
    return jnp.asarray(out.reshape(want_np.shape + pool.shape[1:]))


def push_pages_pipelined_ref(pool_pages: jnp.ndarray, dest: jnp.ndarray,
                             payload: jnp.ndarray, table: MemPortTable,
                             pages_per_node: int,
                             program: Optional[RouteProgram] = None, *,
                             budget: int, channels: int, active_budget=None,
                             overprovision: int = 1) -> jnp.ndarray:
    """Oracle for the pipelined push engine: commits retire in chunk order.

    Must equal :func:`push_pages_ref` of the rate-limit-masked destination
    list for every ``channels`` (single-writer pages).
    """
    dest_np = np.asarray(dest)
    rows, r = dest_np.reshape((-1, dest_np.shape[-1])).shape
    dest2 = dest_np.reshape((rows, r))
    flat = np.asarray(flat_index(table, jnp.asarray(dest2.reshape(-1)),
                                 pages_per_node)).reshape(rows, r)
    smask = np.asarray(served_mask(table, jnp.asarray(dest2), program))
    pay = np.asarray(payload).reshape((rows, r) + np.asarray(payload).shape[2:])
    pool = np.array(pool_pages)                    # mutable copy
    ab = np.broadcast_to(np.asarray(
        budget if active_budget is None else active_budget,
        np.int64).reshape(-1), (rows,))
    for i in range(rows):
        in_flight: Optional[np.ndarray] = None
        for chunk in pipeline_schedule(r, budget, channels, ab[i],
                                       overprovision) + [None]:
            commit, in_flight = in_flight, chunk
            if commit is None:
                continue
            for d in commit:
                if d < r and smask[i, d] and flat[i, d] >= 0:
                    pool[flat[i, d]] = pay[i, d].astype(pool.dtype)
    return jnp.asarray(pool)
