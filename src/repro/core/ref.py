"""Pure-jnp oracles for the bridge transfer engine (no collectives).

These compute the same results as :mod:`repro.core.bridge` by direct global
gather/scatter through the memport table.  Property tests assert bridge ==
oracle for randomized placements, request lists, budgets and route programs.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.memport import MemPortTable
from repro.core.steering import RouteProgram


def flat_index(table: MemPortTable, page_ids: jnp.ndarray,
               pages_per_node: int) -> jnp.ndarray:
    """logical page -> row in the node-major global pool array."""
    home, slot = table.translate(page_ids)
    flat = home * pages_per_node + slot
    return jnp.where((home >= 0) & (slot >= 0), flat, -1)


def served_mask(table: MemPortTable, ids: jnp.ndarray,
                program: Optional[RouteProgram]) -> jnp.ndarray:
    """bool[num_nodes, R]: is this request's ring distance wired?

    Row i of ``ids`` is node i's request list; distance 0 (the loopback
    fast path) is always wired, other distances only if the program's slot
    is live.  ``program=None`` means full coverage (everything served).
    """
    if program is None:
        return jnp.ones(ids.shape, bool)
    n = program.num_nodes
    home, _ = table.translate(ids)
    me = jnp.arange(ids.shape[0])[:, None]
    dist = jnp.mod(home - me, n)
    wired = jnp.concatenate([jnp.ones((1,), bool), program.live])
    return jnp.where(home >= 0, wired[dist.clip(0, n - 1)], False)


def pull_pages_ref(pool_pages: jnp.ndarray, want: jnp.ndarray,
                   table: MemPortTable, pages_per_node: int,
                   program: Optional[RouteProgram] = None) -> jnp.ndarray:
    """Oracle for :func:`repro.core.bridge.pull_pages`.

    Args:
      pool_pages: [num_nodes * pages_per_node, *page_shape] (global view).
      want: [num_nodes, R] logical ids (FREE-padded).
      program: optional route program; requests whose ring distance has no
        wired circuit come back as zeros (matching the bridge's FREE-mask).
    Returns: [num_nodes, R, *page_shape].
    """
    flat = flat_index(table, want.reshape(-1), pages_per_node)
    flat = jnp.where(served_mask(table, want, program).reshape(-1), flat, -1)
    valid = flat >= 0
    safe = jnp.where(valid, flat, 0)
    out = pool_pages[safe]
    mask = valid.reshape(valid.shape + (1,) * (out.ndim - 1))
    out = jnp.where(mask, out, jnp.zeros_like(out))
    return out.reshape(want.shape + pool_pages.shape[1:])


def push_pages_ref(pool_pages: jnp.ndarray, dest: jnp.ndarray,
                   payload: jnp.ndarray, table: MemPortTable,
                   pages_per_node: int,
                   program: Optional[RouteProgram] = None) -> jnp.ndarray:
    """Oracle for :func:`repro.core.bridge.push_pages`."""
    flat = flat_index(table, dest.reshape(-1), pages_per_node)
    flat = jnp.where(served_mask(table, dest, program).reshape(-1), flat, -1)
    safe = jnp.where(flat >= 0, flat, pool_pages.shape[0])
    pay = payload.reshape((-1,) + payload.shape[2:]).astype(pool_pages.dtype)
    return pool_pages.at[safe].set(pay, mode="drop")
