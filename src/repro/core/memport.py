"""The memport construct (paper Fig. 2), adapted to page-granular pools.

The paper's memport is a per-master, runtime-configurable table that maps
address *regions* to (physical-address offset, target transceiver).  Here a
"region" is a logical page of a pooled tensor, and the table maps

    logical page id  ->  (home node on the mem axis, slot in that node's pool)

The two columns live as device arrays and are **inputs** to the jitted step
functions, never compile-time constants: the control plane can re-program the
table (re-home pages, migrate slots) at runtime without triggering any
recompilation — this is the paper's "software-defined" property.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

FREE = -1  # sentinel for unmapped pages / empty request slots


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class MemPortTable:
    """Steering table: one row per logical page.

    Attributes:
      home:  i32[num_logical]  node id owning the page (FREE if unmapped)
      slot:  i32[num_logical]  slot index within the home node's local pool
    """

    home: jax.Array
    slot: jax.Array

    @property
    def num_logical(self) -> int:
        return self.home.shape[0]

    # -- constructors --------------------------------------------------------
    @staticmethod
    def empty(num_logical: int) -> "MemPortTable":
        return MemPortTable(
            home=jnp.full((num_logical,), FREE, jnp.int32),
            slot=jnp.full((num_logical,), FREE, jnp.int32),
        )

    @staticmethod
    def striped(num_logical: int, num_nodes: int,
                pages_per_node: int) -> "MemPortTable":
        """Round-robin page placement (the default pooled layout)."""
        pages = np.arange(num_logical)
        home = (pages % num_nodes).astype(np.int32)
        slot = (pages // num_nodes).astype(np.int32)
        if num_logical and slot.max() >= pages_per_node:
            raise ValueError(
                f"pool too small: need {slot.max() + 1} slots/node, "
                f"have {pages_per_node}")
        return MemPortTable(home=jnp.asarray(home), slot=jnp.asarray(slot))

    @staticmethod
    def blocked(num_logical: int, num_nodes: int,
                pages_per_node: int) -> "MemPortTable":
        """Contiguous block placement: page p -> (p // ppn, p % ppn), so the
        node-major flat row equals the logical id (identity layout)."""
        pages = np.arange(num_logical)
        home = (pages // pages_per_node).astype(np.int32)
        if num_logical and home.max() >= num_nodes:
            raise ValueError("pool too small for blocked layout")
        slot = (pages % pages_per_node).astype(np.int32)
        return MemPortTable(home=jnp.asarray(home), slot=jnp.asarray(slot))

    # -- translation (the request-preparation unit reads these) --------------
    def translate(self, page_ids: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """logical page ids -> (home node, remote slot); FREE passes through."""
        valid = page_ids >= 0
        safe = jnp.where(valid, page_ids, 0)
        home = jnp.where(valid, self.home[safe], FREE)
        slot = jnp.where(valid, self.slot[safe], FREE)
        return home, slot

    # -- runtime reprogramming (control plane) -------------------------------
    def program(self, page_ids: np.ndarray, homes: np.ndarray,
                slots: np.ndarray) -> "MemPortTable":
        """Return a new table with rows ``page_ids`` rewritten."""
        return MemPortTable(
            home=self.home.at[page_ids].set(jnp.asarray(homes, jnp.int32)),
            slot=self.slot.at[page_ids].set(jnp.asarray(slots, jnp.int32)),
        )

    def rehome(self, old_home: int, new_homes: np.ndarray,
               new_slots: np.ndarray) -> "MemPortTable":
        """Move every page homed at ``old_home`` (node failure path)."""
        mask = np.asarray(self.home) == old_home
        idx = np.nonzero(mask)[0]
        if len(idx) != len(new_homes):
            raise ValueError("rehome plan size mismatch")
        return self.program(idx, new_homes, new_slots)
