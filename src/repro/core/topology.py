"""Two-tier fabric description: boards of endpoints joined by a rack ring.

The paper's bridge is explicitly hierarchical — transceiver circuits hop
chip-to-chip *and* mainboard-to-mainboard to connect "100s of masters and
slaves".  A :class:`Topology` captures that shape for the software-defined
datapath:

* every mesh rank belongs to a **board** (group) and has a local rank on
  that board's ring (the board tier);
* local rank 0 of each board is the board's **gateway**; gateways form a
  rack-level ring (the rack tier);
* the two tiers have asymmetric wire constants (hop latency, link
  bandwidth) — the disaggregation asymmetry that DDC/rack-scale designs
  show is where latency actually bites.

A Topology is **static** per deployment: it is captured as compile-time
constants by the jitted datapath (its arrays are closed over, never traced
arguments), while :class:`~repro.core.steering.RouteProgram`s compiled *for*
a topology remain runtime inputs — swapping flat and hierarchical programs
on the same topology never retraces.

Path realization contract (shared by the datapath telemetry, the ref
oracle and the perfmodel — the single definition of "how many wires does
this transfer hold"):

* an **intra-board** pair (requester and home on the same board) travels
  the board ring in the direction the route program drives its slot:
  ``sign=+1``: ``(l_home - l_req) mod G`` board hops; ``sign=-1`` the
  mirror.  No rack link is touched — boards transfer concurrently;
* an **inter-board** pair routes through the gateways: shortest-way local
  legs ``min(l, G - l)`` on each board, plus the rack ring between the two
  gateways in the program's direction (``(g_home - g_req) mod B`` rack
  hops clockwise, mirror counter-clockwise).

The flat single-board topology (:meth:`Topology.flat`) degenerates to the
PR-1 ring: every pair is intra, the board ring *is* the global ring, and
directed board hops equal the classic ``|offset|`` hop count.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TopoTables:
    """Device-side view of a topology (what the datapath telemetry reads).

    All three are i32[N] indexed by mesh rank; they are captured as
    constants by the jitted transfer (a Topology is static), so they never
    appear in the jit cache key as traced inputs.
    """

    group: jax.Array        # board id of each rank
    local_rank: jax.Array   # rank within its board
    group_size: jax.Array   # size of the rank's board


@dataclass(frozen=True, eq=False)
class Topology:
    """Static two-tier fabric layout + per-tier wire constants.

    Attributes:
      group: i64[N] board id per mesh rank (0 .. num_groups-1).
      local_rank: i64[N] rank within the board (0 .. group size - 1); local
        rank 0 is the board's gateway onto the rack ring.
      group_sizes: i64[B] endpoints per board (boards may be ragged).
      board_hop_us / rack_hop_us: per-hop circuit latency of each tier.
      board_link_gbps / rack_link_gbps: per-direction link bandwidth of
        each tier (GB/s) — rack links are typically the slow tier.
    """

    group: np.ndarray
    local_rank: np.ndarray
    group_sizes: np.ndarray
    board_hop_us: float = 1.5
    rack_hop_us: float = 4.0
    board_link_gbps: float = 50.0
    rack_link_gbps: float = 25.0

    def __post_init__(self):
        g = np.asarray(self.group, np.int64)
        l = np.asarray(self.local_rank, np.int64)
        sizes = np.asarray(self.group_sizes, np.int64)
        object.__setattr__(self, "group", g)
        object.__setattr__(self, "local_rank", l)
        object.__setattr__(self, "group_sizes", sizes)
        if g.shape != l.shape or g.ndim != 1:
            raise ValueError("group / local_rank must be matching 1-D arrays")
        b = sizes.shape[0]
        if g.size and (g.min() < 0 or g.max() >= b):
            raise ValueError(f"group ids must lie in [0, {b})")
        for gid in range(b):
            locs = np.sort(l[g == gid])
            if locs.shape[0] != sizes[gid] or not np.array_equal(
                    locs, np.arange(sizes[gid])):
                raise ValueError(
                    f"board {gid}: local ranks must be exactly "
                    f"0..{int(sizes[gid]) - 1}")

    # -- constructors --------------------------------------------------------
    @staticmethod
    def flat(num_nodes: int, **hw) -> "Topology":
        """One board spanning the whole ring (the PR-1 flat fabric)."""
        return Topology.from_sizes([num_nodes], **hw)

    @staticmethod
    def boards(num_groups: int, group_size: int, **hw) -> "Topology":
        """Contiguous uniform boards: rank = board * size + local rank."""
        return Topology.from_sizes([group_size] * num_groups, **hw)

    @staticmethod
    def from_sizes(sizes: Sequence[int], **hw) -> "Topology":
        """Contiguous boards of the given (possibly ragged) sizes."""
        sizes = np.asarray(list(sizes), np.int64)
        if sizes.size == 0 or (sizes < 1).any():
            raise ValueError("every board needs at least one endpoint")
        group = np.repeat(np.arange(sizes.shape[0]), sizes)
        local = np.concatenate([np.arange(s) for s in sizes])
        return Topology(group=group, local_rank=local, group_sizes=sizes, **hw)

    # -- shape ----------------------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return self.group.shape[0]

    @property
    def num_groups(self) -> int:
        return self.group_sizes.shape[0]

    @property
    def is_flat(self) -> bool:
        return self.num_groups == 1

    def gateway_rank(self, gid: int) -> int:
        """Mesh rank of board ``gid``'s gateway (its local rank 0)."""
        return int(np.nonzero((self.group == gid) & (self.local_rank == 0))[0][0])

    # -- pair classification / hop counting (host-side numpy) ----------------
    def pair_intra(self, req, home) -> np.ndarray:
        """bool: requester and home share a board (element-wise)."""
        return self.group[np.asarray(req)] == self.group[np.asarray(home)]

    def pair_hops(self, req, home, sign) -> Tuple[np.ndarray, np.ndarray]:
        """(board_hops, rack_hops) of each (req, home) pair.

        ``sign`` (+1/-1, broadcastable) is the direction the pair's slot is
        driven — the realization contract in the module docstring.  Pairs
        with ``req == home`` are loopback hits and cost 0 on both tiers.
        """
        req = np.asarray(req)
        home = np.asarray(home)
        sign = np.broadcast_to(np.asarray(sign), req.shape)
        g_r, g_h = self.group[req], self.group[home]
        l_r, l_h = self.local_rank[req], self.local_rank[home]
        size_r = self.group_sizes[g_r]
        size_h = self.group_sizes[g_h]
        intra = g_r == g_h
        b = self.num_groups
        board = np.where(
            intra,
            np.where(sign > 0, (l_h - l_r) % size_r, (l_r - l_h) % size_r),
            np.minimum(l_r, size_r - l_r) + np.minimum(l_h, size_h - l_h))
        rack = np.where(
            intra, 0,
            np.where(sign > 0, (g_h - g_r) % b, (g_r - g_h) % b))
        loop = req == home
        return np.where(loop, 0, board), np.where(loop, 0, rack)

    # -- device-side view -----------------------------------------------------
    def tables(self) -> TopoTables:
        return TopoTables(
            group=jnp.asarray(self.group, jnp.int32),
            local_rank=jnp.asarray(self.local_rank, jnp.int32),
            group_size=jnp.asarray(self.group_sizes[self.group], jnp.int32))

    def describe(self) -> str:
        return (f"topology: {self.num_nodes} endpoints on {self.num_groups} "
                f"board(s) {self.group_sizes.tolist()}; board "
                f"{self.board_hop_us}us/{self.board_link_gbps}GB/s, rack "
                f"{self.rack_hop_us}us/{self.rack_link_gbps}GB/s")


def pair_hops_device(tables: TopoTables, num_groups: int, my, home, sign):
    """jnp mirror of :meth:`Topology.pair_hops` for the datapath telemetry.

    ``my`` is this requester's rank (traced scalar), ``home`` the per-request
    home ranks (FREE entries must be masked by the caller), ``sign`` the
    per-request drive direction.  Returns (intra, board_hops, rack_hops).
    """
    safe = jnp.clip(home, 0, tables.group.shape[0] - 1)
    g_r, l_r = tables.group[my], tables.local_rank[my]
    size_r = tables.group_size[my]
    g_h, l_h = tables.group[safe], tables.local_rank[safe]
    size_h = tables.group_size[safe]
    intra = g_h == g_r
    board = jnp.where(
        intra,
        jnp.where(sign > 0, jnp.mod(l_h - l_r, size_r),
                  jnp.mod(l_r - l_h, size_r)),
        jnp.minimum(l_r, size_r - l_r) + jnp.minimum(l_h, size_h - l_h))
    rack = jnp.where(
        intra, 0,
        jnp.where(sign > 0, jnp.mod(g_h - g_r, num_groups),
                  jnp.mod(g_r - g_h, num_groups)))
    loop = safe == my
    return intra, jnp.where(loop, 0, board), jnp.where(loop, 0, rack)
