"""Disaggregated KV cache through the bridge — the paper's case study, scaled.

The paper demonstrates its bridge by disaggregating *main memory* and letting
unmodified CPU masters run STREAM against it.  The pod-scale analogue of
"main memory" for LM serving is the **KV cache**: at 500 k context it dwarfs
every other tensor and pins the compute:memory ratio the paper wants to break.

Layout.  KV lives in page pools sharded over the *mem* axis (``data``):

    k_pool, v_pool : [num_slots, page_tokens, kv_heads, head_dim]

addressed through one :class:`~repro.core.memport.MemPortTable` shared by all
layers (placement is per (sequence, page); layers stack the pools).  The tail
(partially-filled) page of each sequence stays in a **local write buffer** —
the paper's edge-buffering applied to the write path — and is flushed through
the bridge exactly once when it fills (write-combining; 1/page_tokens of the
naive write-allocate traffic).

Three decode-attention placements:

* ``local``        — dense per-node cache, no bridge (baseline ceiling);
* ``bridge_pull``  — paper-faithful: the master *pulls* KV pages through the
  memport + ring-circuit datapath and computes attention locally, streaming
  page rounds through an online-softmax accumulator (cut-through: a page is
  consumed the moment it lands, never stored — literal under ``fused=True``,
  where each round folds into the flash-decode accumulators *inside* the
  attention grid, :mod:`repro.kernels.bridge_attention`, and the full
  ``[B, max_pages]`` pull buffer never materializes);
* ``bridge_push``  — beyond-paper: the *query* is broadcast to the memory
  nodes, each computes partial flash-decode attention over its resident
  pages, and partials merge with a log-sum-exp reduction.  Collective bytes
  drop from O(seq · kv_heads · head_dim) to O(heads · head_dim) per token.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bridge
from repro.core.memport import FREE, MemPortTable
from repro.core.steering import RouteProgram
from repro.kernels.bridge_attention import stream_decode_accumulate
from repro.telemetry import counters as telemetry_counters

NEG_INF = -1e30


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PagedKVLayer:
    """Per-layer paged KV state (leading dims may be stacked over layers)."""

    k_pool: jax.Array        # [slots, T, kv, hd]  sharded (mem, None, None, None)
    v_pool: jax.Array        # [slots, T, kv, hd]
    tail_k: jax.Array        # [B, T, kv, hd]      batch-sharded write buffer
    tail_v: jax.Array        # [B, T, kv, hd]


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class PagedKVCache:
    """Whole-model paged cache: layers stacked on the leading axis."""

    layers: PagedKVLayer     # leaves: [L, ...]
    table: MemPortTable      # shared logical (b, page) -> (home, slot)
    lengths: jax.Array       # i32[B] tokens already cached
    page_tokens: int
    max_pages: int

    @property
    def batch(self) -> int:
        return self.lengths.shape[0]


def logical_page_ids(batch: int, max_pages: int) -> jnp.ndarray:
    """Logical id of page p of sequence b is b * max_pages + p."""
    return (jnp.arange(batch)[:, None] * max_pages
            + jnp.arange(max_pages)[None, :])


def init_cache(num_layers: int, batch: int, max_len: int, page_tokens: int,
               kv_heads: int, head_dim: int, *, mesh: Optional[Mesh],
               mem_axis: str = "data", dtype=jnp.bfloat16,
               table: Optional[MemPortTable] = None,
               lengths: Optional[jax.Array] = None) -> PagedKVCache:
    max_pages = -(-max_len // page_tokens)
    n = bridge._mem_axis_size(mesh, mem_axis)
    slots_per_node = -(-batch * max_pages // n)
    num_slots = n * slots_per_node
    if table is None:
        table = MemPortTable.striped(batch * max_pages, n, slots_per_node)

    # Sharding (pools over the mem axis) is applied by the caller: serve_step
    # places these with in_shardings / with_sharding_constraint.
    pools = jnp.zeros((num_layers, num_slots, page_tokens, kv_heads, head_dim),
                      dtype)
    tails = jnp.zeros((num_layers, batch, page_tokens, kv_heads, head_dim), dtype)
    layers = PagedKVLayer(k_pool=pools, v_pool=pools, tail_k=tails, tail_v=tails)
    if lengths is None:
        lengths = jnp.zeros((batch,), jnp.int32)
    return PagedKVCache(layers=layers, table=table, lengths=lengths,
                        page_tokens=page_tokens, max_pages=max_pages)


# ---------------------------------------------------------------------------
# Online-softmax helpers (flash-decode accumulators)
# ---------------------------------------------------------------------------

def _merge(m1, l1, o1, m2, l2, o2):
    """Merge two partial-softmax states (m: max, l: denom, o: weighted sum)."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return m, l1 * a1 + l2 * a2, o1 * a1[..., None] + o2 * a2[..., None]


def _page_partial(q, k, v, valid):
    """Partial attention of q [B,H,hd] against one page set.

    k, v: [R, T, kv, hd]; valid: [R, T] bool; pages belong to sequences via
    ``seq_of_page`` handled by the caller (q already gathered per page).
    Returns per-page partials (m [R,H], l [R,H], o [R,H,hd]).
    """
    r, t, kv, hd = k.shape
    h = q.shape[-2]
    g = h // kv
    qf = q.reshape(r, kv, g, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scale = hd ** -0.5
    s = jnp.einsum("rkgd,rtkd->rkgt", qf, kf) * scale        # [R,kv,G,T]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # [R,kv,G]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)                                   # [R,kv,G]
    o = jnp.einsum("rkgt,rtkd->rkgd", p, v.astype(jnp.float32))
    return (m.reshape(r, h), l.reshape(r, h), o.reshape(r, h, hd))


def _segment_combine(m, l, o, seg, num_segments):
    """LSE-combine per-page partials into per-sequence accumulators."""
    seg = jnp.where(seg >= 0, seg, num_segments)
    m_seq = jax.ops.segment_max(m, seg, num_segments=num_segments + 1)[:num_segments]
    m_seq = jnp.maximum(m_seq, NEG_INF)
    a = jnp.exp(m - m_seq[seg.clip(0, num_segments - 1)])
    a = jnp.where((seg < num_segments)[:, None], a, 0.0)
    l_seq = jax.ops.segment_sum(l * a, seg, num_segments=num_segments + 1)[:num_segments]
    o_seq = jax.ops.segment_sum(o * a[..., None], seg,
                                num_segments=num_segments + 1)[:num_segments]
    return m_seq, l_seq, o_seq


def _tail_partial(q, tail_k, tail_v, lengths, page_tokens):
    """Partial attention over the local write buffer (tail page)."""
    b, h, hd = q.shape
    kv = tail_k.shape[-2]
    g = h // kv
    start = (lengths // page_tokens) * page_tokens
    pos = start[:, None] + jnp.arange(page_tokens)[None, :]
    valid = pos < lengths[:, None]                            # [B, T]
    qf = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, tail_k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, tail_v.astype(jnp.float32))
    return m.reshape(b, h), l.reshape(b, h), o.reshape(b, h, hd)


# ---------------------------------------------------------------------------
# Append (write path): edge-buffered write combining
# ---------------------------------------------------------------------------

def append(layer: PagedKVLayer, table: MemPortTable, lengths: jax.Array,
           k_new: jax.Array, v_new: jax.Array, *, page_tokens: int,
           max_pages: int, mesh: Optional[Mesh], mem_axis: str = "data",
           budget: int = 8, edge_buffer: bool = True, channels: int = 1,
           program: Optional[RouteProgram] = None,
           collect_telemetry: bool = False, topology=None,
           tenant_of_seq: Optional[jax.Array] = None, max_tenants: int = 0,
           fused: bool = True):
    """Append one token's (k, v) [B, kv, hd] for one layer.

    Tokens land in the local tail buffer; when a sequence's tail page fills,
    that page is flushed through the bridge to its pooled home (one masked
    ``push_pages`` — sequences not at a boundary contribute FREE slots).
    ``edge_buffer`` / ``channels`` thread to the bridge write path
    (bufferless serialization / the pipelined multi-channel round engine);
    ``fused`` selects the fused Pallas commit datapath (the default — see
    :func:`repro.core.bridge.push_pages`).
    With ``collect_telemetry`` the write-path counters of both pushes (k and
    v pages both cross the wire) come back summed: ``(layer, telemetry)``.
    ``tenant_of_seq`` (i32[B], runtime input) attributes each sequence's
    flush traffic to its tenant in the telemetry's per-tenant bins.
    """
    b = lengths.shape[0]
    off = lengths % page_tokens
    tail_k = layer.tail_k.at[jnp.arange(b), off].set(k_new.astype(layer.tail_k.dtype))
    tail_v = layer.tail_v.at[jnp.arange(b), off].set(v_new.astype(layer.tail_v.dtype))

    page_full = (off == page_tokens - 1)
    page_idx = lengths // page_tokens
    dest = jnp.where(page_full & (page_idx < max_pages),
                     jnp.arange(b) * max_pages + page_idx, FREE)
    n = bridge._mem_axis_size(mesh, mem_axis)
    per_node = -(-b // n)
    pad = n * per_node - b

    def shape_for(x, fill=0):
        if pad:
            x = jnp.concatenate(
                [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], 0)
        return x.reshape((n, per_node) + x.shape[1:])

    # Padding rows (batch not a multiple of the mesh size) must carry FREE
    # destinations — a zero pad would be a live push of zero payloads into
    # logical page 0 (sequence 0's first KV page) every step.
    dest_n = shape_for(jnp.where(dest >= 0, dest, FREE).astype(jnp.int32),
                       fill=FREE)
    tenants_n = None
    if tenant_of_seq is not None:
        tenants_n = shape_for(tenant_of_seq.astype(jnp.int32))
    k_pool = bridge.push_pages(layer.k_pool, dest_n, shape_for(tail_k),
                               table, mesh=mesh, mem_axis=mem_axis,
                               budget=budget, edge_buffer=edge_buffer,
                               channels=channels, program=program,
                               collect_telemetry=collect_telemetry,
                               topology=topology, tenant_ids=tenants_n,
                               max_tenants=max_tenants, fused=fused)
    v_pool = bridge.push_pages(layer.v_pool, dest_n, shape_for(tail_v),
                               table, mesh=mesh, mem_axis=mem_axis,
                               budget=budget, edge_buffer=edge_buffer,
                               channels=channels, program=program,
                               collect_telemetry=collect_telemetry,
                               topology=topology, tenant_ids=tenants_n,
                               max_tenants=max_tenants, fused=fused)
    telem = None
    if collect_telemetry:
        k_pool, telem_k = k_pool
        v_pool, telem_v = v_pool
        telem = telemetry_counters.add(telem_k, telem_v)
    # A flushed tail restarts empty (zeros are fine: positions are masked).
    keep = ~page_full
    keep_m = keep[:, None, None, None]
    tail_k = jnp.where(keep_m, tail_k, jnp.zeros_like(tail_k))
    tail_v = jnp.where(keep_m, tail_v, jnp.zeros_like(tail_v))
    out = replace(layer, k_pool=k_pool, v_pool=v_pool,
                  tail_k=tail_k, tail_v=tail_v)
    if collect_telemetry:
        return out, telem
    return out


# ---------------------------------------------------------------------------
# Decode attention — three placements
# ---------------------------------------------------------------------------

def _finalize(m, l, o):
    l = jnp.maximum(l, 1e-30)
    return (o / l[..., None])


def decode_attention_pull(q: jax.Array, layer: PagedKVLayer,
                          table: MemPortTable, lengths: jax.Array, *,
                          page_tokens: int, max_pages: int,
                          mesh: Optional[Mesh], mem_axis: str = "data",
                          budget: int = 8, edge_buffer: bool = True,
                          channels: int = 1,
                          program: Optional[RouteProgram] = None,
                          collect_telemetry: bool = False, topology=None,
                          tenant_of_seq: Optional[jax.Array] = None,
                          max_tenants: int = 0, fused: bool = True):
    """Paper-faithful: pull pages through the bridge, attend locally.

    q: [B, H, hd] -> out [B, H, hd].  Pages stream through an online-softmax
    accumulator in rounds of ``budget`` pages (cut-through consumption).
    ``program`` is the runtime circuit schedule threaded down to
    :func:`repro.core.bridge.pull_pages`; ``channels`` its pipelined
    multi-channel round overlap.  With ``collect_telemetry`` the summed
    counters of the k and v pulls come back too: ``(out, telemetry)``.
    ``tenant_of_seq`` (i32[B], runtime input) attributes each sequence's
    page pulls to its tenant in the telemetry's per-tenant bins.

    ``fused`` (default ON) makes the cut-through literal: each round of
    landed pages is consumed **inside the attention grid**
    (:func:`repro.kernels.bridge_attention.stream_decode_accumulate` folds
    the round straight into the flash-decode ``(m, l, acc)`` accumulators),
    so the peak pull footprint is one round of pages instead of the full
    ``[B, max_pages]`` buffer pair.  The pulled pages and the telemetry are
    bit-exact vs ``fused=False``; the attention output matches at float
    tolerance (the online accumulation visits pages in landing order).
    """
    b, h, hd = q.shape
    kv = layer.k_pool.shape[-2]
    n = bridge._mem_axis_size(mesh, mem_axis)
    per_node = -(-b // n)
    want_b = logical_page_ids(b, max_pages)                  # [B, P]
    # Only fully-flushed pages live in the pool.
    flushed = lengths // page_tokens                          # [B]
    want_b = jnp.where(jnp.arange(max_pages)[None, :] < flushed[:, None],
                       want_b, FREE).astype(jnp.int32)
    pad = n * per_node - b
    if pad:
        want_b = jnp.concatenate(
            [want_b, jnp.full((pad, max_pages), FREE, jnp.int32)], 0)
    want = want_b.reshape(n, per_node * max_pages)
    tenants = None
    if tenant_of_seq is not None:
        ten_b = jnp.broadcast_to(tenant_of_seq.astype(jnp.int32)[:, None],
                                 (b, max_pages))
        if pad:
            ten_b = jnp.concatenate(
                [ten_b, jnp.zeros((pad, max_pages), jnp.int32)], 0)
        tenants = ten_b.reshape(n, per_node * max_pages)

    pull_kw = dict(mesh=mesh, mem_axis=mem_axis, budget=budget,
                   edge_buffer=edge_buffer, channels=channels,
                   program=program, collect_telemetry=collect_telemetry,
                   topology=topology, max_tenants=max_tenants, fused=fused)
    telem = None
    if fused:
        # Streamed rounds: pull one bridge round of pages at a time and fold
        # it straight into the flash-decode accumulators — the materialized
        # state is (m, l, acc) + one round of pages, never the full pull
        # buffer.  Splitting one R-request transfer into R/budget 1-round
        # transfers moves the same flits through the same per-round
        # collectives (and, with no throttled active_budget, sums to
        # bit-exact telemetry: every round's spill count is zero either
        # way).
        rtot = want.shape[-1]
        rounds = -(-rtot // budget)
        m_s = jnp.full((b, h), NEG_INF, jnp.float32)
        l_s = jnp.zeros((b, h), jnp.float32)
        o_s = jnp.zeros((b, h, hd), jnp.float32)
        for rnd in range(rounds):
            sl = slice(rnd * budget, min((rnd + 1) * budget, rtot))
            want_r = want[:, sl]
            ten_r = tenants[:, sl] if tenants is not None else None
            k_r = bridge.pull_pages(layer.k_pool, want_r, table,
                                    tenant_ids=ten_r, **pull_kw)
            v_r = bridge.pull_pages(layer.v_pool, want_r, table,
                                    tenant_ids=ten_r, **pull_kw)
            if collect_telemetry:
                k_r, telem_k = k_r
                v_r, telem_v = v_r
                round_t = telemetry_counters.add(telem_k, telem_v)
                telem = (round_t if telem is None
                         else telemetry_counters.add(telem, round_t))
            lanes = n * want_r.shape[-1]
            wflat = want_r.reshape(-1)
            live = wflat >= 0
            # Logical page ids encode their sequence: id // max_pages.
            seq = jnp.where(live, wflat // max_pages, -1)
            m_s, l_s, o_s = stream_decode_accumulate(
                q, k_r.reshape(lanes, page_tokens, kv, hd),
                v_r.reshape(lanes, page_tokens, kv, hd), seq, live,
                m_s, l_s, o_s)
    else:
        k_pages = bridge.pull_pages(layer.k_pool, want, table,
                                    tenant_ids=tenants, **pull_kw)
        v_pages = bridge.pull_pages(layer.v_pool, want, table,
                                    tenant_ids=tenants, **pull_kw)
        if collect_telemetry:
            k_pages, telem_k = k_pages
            v_pages, telem_v = v_pages
            telem = telemetry_counters.add(telem_k, telem_v)
        # [n, per_node*max_pages, T, kv, hd] -> [B(+pad), P, T, kv, hd]
        k_pages = k_pages.reshape(n * per_node, max_pages, page_tokens,
                                  kv, hd)[:b]
        v_pages = v_pages.reshape(n * per_node, max_pages, page_tokens,
                                  kv, hd)[:b]

        flat_k = k_pages.reshape(b * max_pages, page_tokens, kv, hd)
        flat_v = v_pages.reshape(b * max_pages, page_tokens, kv, hd)
        seq_of_page = jnp.repeat(jnp.arange(b), max_pages)
        page_of = jnp.tile(jnp.arange(max_pages), b)
        pos = page_of[:, None] * page_tokens + jnp.arange(page_tokens)[None, :]
        valid = (pos < (flushed[seq_of_page] * page_tokens)[:, None])
        q_per_page = q[seq_of_page]
        m_p, l_p, o_p = _page_partial(q_per_page, flat_k, flat_v, valid)
        live = page_of < flushed[seq_of_page]
        seg = jnp.where(live, seq_of_page, -1)
        m_s, l_s, o_s = _segment_combine(m_p, l_p, o_p, seg, b)

    m_t, l_t, o_t = _tail_partial(q, layer.tail_k, layer.tail_v,
                                  lengths, page_tokens)
    m, l, o = _merge(m_s, l_s, o_s, m_t, l_t, o_t)
    out = _finalize(m, l, o).astype(q.dtype)
    if collect_telemetry:
        return out, telem
    return out


def decode_attention_push(q: jax.Array, layer: PagedKVLayer,
                          table: MemPortTable, lengths: jax.Array, *,
                          page_tokens: int, max_pages: int,
                          mesh: Optional[Mesh],
                          mem_axis: str = "data") -> jax.Array:
    """Beyond-paper: broadcast q, compute partial attention at the memory
    nodes, LSE-combine partials (compute-at-memory / distributed flash-decode).
    """
    b, h, hd = q.shape
    kv = layer.k_pool.shape[-2]
    num_slots = layer.k_pool.shape[0]
    n = bridge._mem_axis_size(mesh, mem_axis)
    slots_per_node = num_slots // n
    flushed = lengths // page_tokens

    # Inverse memport map: slot -> logical page (computed once per step).
    logical = jnp.arange(table.num_logical)
    home, slot = table.translate(logical.astype(jnp.int32))
    flat = jnp.where(home >= 0, home * slots_per_node + slot, num_slots)
    inv = jnp.full((num_slots + 1,), FREE, jnp.int32).at[flat].set(
        logical.astype(jnp.int32))[:num_slots]

    def partial_at_node(k_local, v_local, inv_local, q_all, flushed_all,
                        lengths_all):
        # k_local: [slots_local, T, kv, hd]; q_all replicated [B, H, hd].
        sl = inv_local.shape[0]
        seq = jnp.where(inv_local >= 0, inv_local // max_pages, -1)
        pg = jnp.where(inv_local >= 0, inv_local % max_pages, 0)
        live = (seq >= 0) & (pg < flushed_all[seq.clip(0, b - 1)])
        pos = pg[:, None] * page_tokens + jnp.arange(page_tokens)[None, :]
        valid = live[:, None] & (
            pos < (flushed_all[seq.clip(0, b - 1)] * page_tokens)[:, None])
        q_sel = q_all[seq.clip(0, b - 1)]
        m_p, l_p, o_p = _page_partial(q_sel, k_local, v_local, valid)
        seg = jnp.where(live, seq, -1)
        return _segment_combine(m_p, l_p, o_p, seg, b)

    if n == 1:
        m_s, l_s, o_s = partial_at_node(layer.k_pool, layer.v_pool, inv,
                                        q, flushed, lengths)
    else:
        def mapped(k_l, v_l, inv_l, q_all, fl, ln):
            m_l, l_l, o_l = partial_at_node(k_l, v_l, inv_l, q_all, fl, ln)
            # Cross-node LSE combine: pmax for the max, psum for the rest.
            m_g = jax.lax.pmax(m_l, mem_axis)
            a = jnp.exp(jnp.maximum(m_l, NEG_INF) - m_g)
            l_g = jax.lax.psum(l_l * a, mem_axis)
            o_g = jax.lax.psum(o_l * a[..., None], mem_axis)
            return m_g, l_g, o_g

        pool_spec = P(mem_axis, *([None] * 3))
        rep = P()
        m_s, l_s, o_s = bridge.shard_map(
            mapped, mesh,
            in_specs=(pool_spec, pool_spec, P(mem_axis), rep, rep, rep),
            out_specs=(rep, rep, rep), mem_axis=mem_axis,
        )(layer.k_pool, layer.v_pool, inv, q, flushed, lengths)

    m_t, l_t, o_t = _tail_partial(q, layer.tail_k, layer.tail_v,
                                  lengths, page_tokens)
    m, l, o = _merge(m_s, l_s, o_s, m_t, l_t, o_t)
    return _finalize(m, l, o).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         lengths: jax.Array) -> jax.Array:
    """Oracle: dense masked GQA decode attention.

    q: [B, H, hd]; k, v: [B, S, kv, hd]; positions >= lengths masked out.
    """
    b, h, hd = q.shape
    kv = k.shape[-2]
    g = h // kv
    qf = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(k.shape[1])[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)
