"""Shared layers: norms, projections, embeddings, RoPE.

Params are plain nested dicts.  Every constructor returns ``(init_fn,
logical_axes)`` pairs indirectly via the ``Param`` spec helper so the same
description drives initialization, ``jax.eval_shape`` and sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Param:
    """Declarative parameter: shape + logical sharding axes + init scale."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"           # normal | zeros | ones
    scale: float = 1.0
    stack_dims: int = 0            # leading scan-stacked dims (not fan-in)

    def initialize(self, key, dtype) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        body = self.shape[self.stack_dims:]
        fan_in = body[0] if len(body) > 1 else max(body[-1], 1)
        std = self.scale / np.sqrt(fan_in)
        return (jax.random.normal(key, self.shape) * std).astype(dtype)


def init_tree(spec_tree: Any, key: jax.Array, dtype) -> Any:
    """Initialize a pytree of Params with split keys."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, Param))
    keys = jax.random.split(key, len(leaves))
    vals = [p.initialize(k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(spec_tree: Any) -> Any:
    """Logical-axes pytree parallel to the params tree."""
    return jax.tree.map(lambda p: p.axes, spec_tree,
                        is_leaf=lambda x: isinstance(x, Param))


def shapes_tree(spec_tree: Any, dtype) -> Any:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec_tree,
        is_leaf=lambda x: isinstance(x, Param))


def stack_specs(spec_tree: Any, n: int, axis_name: str = "layers") -> Any:
    """Add a stacked leading dim (scan-over-layers) to every Param."""
    return jax.tree.map(
        lambda p: Param((n,) + p.shape, (axis_name,) + p.axes, p.init,
                        p.scale, p.stack_dims + 1),
        spec_tree, is_leaf=lambda x: isinstance(x, Param))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_spec(d: int) -> Param:
    return Param((d,), (None,), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(dt)


def apply_norm(kind: str, x: jax.Array, scale: jax.Array) -> jax.Array:
    return rmsnorm(x, scale) if kind == "rmsnorm" else layernorm(x, scale)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def act_fn(kind: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[kind]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int) -> Param:
    return Param((vocab, d), ("vocab", None), scale=1.0)


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array,
            softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
