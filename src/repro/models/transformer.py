"""Model assembly: all 10 assigned architectures from one block vocabulary.

A model is a stack of blocks drawn from {full/global attention, sliding-window
attention, RG-LRU, mLSTM, sLSTM}, optionally MoE FFNs, optionally an encoder
stack with cross-attention (seamless), optionally embedding-stub inputs
(internvl2 patches / seamless audio frames).

Layers are **scanned by pattern period**: parameters for position *i* of the
period are stacked with a leading ``n_periods`` dim, so HLO size is flat in
depth and remat policy applies per period.  Pattern remainders (e.g.
recurrentgemma's 38 = 12x(r,r,a) + (r,r)) live in an unscanned tail.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import (ATTENTION_KINDS, FULL_ATTN, GLOBAL_ATTN, MLSTM,
                          ModelConfig, RGLRU, SLSTM, SWA_ATTN)
from repro.models import attention, layers, moe, recurrent, xlstm
from repro.models.layers import Param


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def ffn_specs(cfg: ModelConfig) -> dict[str, Param]:
    d, ff = cfg.d_model, cfg.d_ff
    spec = {"wi": Param((d, ff), (None, "ff")),
            "wo": Param((ff, d), ("ff", None))}
    if cfg.glu:
        spec["wg"] = Param((d, ff), (None, "ff"))
    return spec


def block_specs(cfg: ModelConfig, kind: str,
                with_cross: bool = False) -> dict[str, Any]:
    d = cfg.d_model
    spec: dict[str, Any] = {"norm1": layers.norm_spec(d)}
    if kind in ATTENTION_KINDS:
        spec["attn"] = attention.attn_specs(cfg)
    elif kind == RGLRU:
        spec["rglru"] = recurrent.rglru_specs(cfg)
    elif kind == MLSTM:
        spec["mlstm"] = xlstm.mlstm_specs(cfg)
    elif kind == SLSTM:
        spec["slstm"] = xlstm.slstm_specs(cfg)
    else:
        raise ValueError(kind)
    if kind in ATTENTION_KINDS or kind == RGLRU:
        spec["norm2"] = layers.norm_spec(d)
        if cfg.is_moe:
            spec["moe"] = moe.moe_specs(cfg)
        elif cfg.d_ff > 0:
            spec["ffn"] = ffn_specs(cfg)
    if with_cross:
        spec["norm_cross"] = layers.norm_spec(d)
        spec["cross"] = attention.cross_attn_specs(cfg)
    return spec


def _pattern_split(cfg: ModelConfig) -> tuple[tuple[str, ...], int,
                                              tuple[str, ...]]:
    period = tuple(cfg.layer_pattern)
    n_periods = cfg.num_layers // len(period)
    tail = cfg.layers[n_periods * len(period):]
    return period, n_periods, tail


def model_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    period, n_periods, tail = _pattern_split(cfg)
    spec: dict[str, Any] = {
        "embed": layers.embed_spec(cfg.padded_vocab, d),
        "out_norm": layers.norm_spec(d),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = Param((cfg.padded_vocab, d), ("vocab", None))
    if n_periods > 0:
        spec["periods"] = {
            f"pos{i}": layers.stack_specs(
                block_specs(cfg, kind, cfg.cross_attention), n_periods)
            for i, kind in enumerate(period)}
    if tail:
        spec["tail"] = {
            f"layer{i}": block_specs(cfg, kind, cfg.cross_attention)
            for i, kind in enumerate(tail)}
    if cfg.num_encoder_layers > 0:
        spec["encoder"] = {
            "blocks": layers.stack_specs(
                block_specs(cfg, FULL_ATTN), cfg.num_encoder_layers),
            "out_norm": layers.norm_spec(d),
        }
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> Any:
    dtype = jnp.dtype(cfg.dtype)
    return layers.init_tree(model_specs(cfg), key, dtype)


def abstract_params(cfg: ModelConfig) -> Any:
    return layers.shapes_tree(model_specs(cfg), jnp.dtype(cfg.dtype))


def params_logical_axes(cfg: ModelConfig) -> Any:
    return layers.axes_tree(model_specs(cfg))


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------

def apply_ffn(cfg: ModelConfig, bp: dict[str, Any], x: jax.Array,
              aux: dict) -> jax.Array:
    h = layers.apply_norm(cfg.norm, x, bp["norm2"])
    if cfg.is_moe:
        out, metrics = moe.moe_ffn(cfg, bp["moe"], h)
        aux["moe_aux_loss"] = aux.get("moe_aux_loss", 0.0) + metrics[
            "moe_aux_loss"]
    elif cfg.d_ff > 0:
        act = layers.act_fn(cfg.act)
        up = jnp.einsum("bsd,df->bsf", h, bp["ffn"]["wi"])
        if cfg.glu:
            up = act(jnp.einsum("bsd,df->bsf", h, bp["ffn"]["wg"])) * up
        else:
            up = act(up)
        out = jnp.einsum("bsf,fd->bsd", up, bp["ffn"]["wo"])
    else:
        return x
    return x + out


def apply_block(cfg: ModelConfig, kind: str, bp: dict[str, Any],
                x: jax.Array, aux: dict, *, causal: bool = True,
                enc_out: Optional[jax.Array] = None,
                attn_impl: str = "xla") -> jax.Array:
    h = layers.apply_norm(cfg.norm, x, bp["norm1"])
    if kind in ATTENTION_KINDS:
        q, k, v = attention.qkv(cfg, bp["attn"], h)
        att = attention.attend_train(cfg, kind, q, k, v, causal=causal,
                                     impl=attn_impl)
        x = x + attention.project_out(cfg, bp["attn"], att)
        if "cross" in bp and enc_out is not None:
            hc = layers.apply_norm(cfg.norm, x, bp["norm_cross"])
            x = x + attention.cross_attend(cfg, bp["cross"], hc, enc_out)
        x = apply_ffn(cfg, bp, x, aux)
    elif kind == RGLRU:
        out, _ = recurrent.rglru_seq(cfg, bp["rglru"], h)
        x = x + out
        x = apply_ffn(cfg, bp, x, aux)
    elif kind == MLSTM:
        x = x + xlstm.mlstm_seq(cfg, bp["mlstm"], h)
    elif kind == SLSTM:
        x = x + xlstm.slstm_seq(cfg, bp["slstm"], h)
    else:
        raise ValueError(kind)
    return x


def _embed_inputs(cfg: ModelConfig, params: Any, batch: dict) -> jax.Array:
    if "embeds" in batch and batch["embeds"] is not None:
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    x = layers.embed(batch["tokens"], params["embed"])
    return (x * jnp.asarray(cfg.d_model ** 0.5, x.dtype))


def encoder_forward(cfg: ModelConfig, params: Any,
                    enc_embeds: jax.Array, remat: str = "block") -> jax.Array:
    """Bidirectional encoder over stub frame/patch embeddings."""
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    aux: dict = {}

    def body(carry, bp):
        return apply_block(cfg, FULL_ATTN, bp, carry, aux,
                           causal=False), None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return layers.apply_norm(cfg.norm, x, params["encoder"]["out_norm"])


def forward(cfg: ModelConfig, params: Any, batch: dict,
            remat: str = "block",
            attn_impl: str = "xla") -> tuple[jax.Array, dict]:
    """-> (logits [B, S, V] fp32, aux metrics)."""
    period, n_periods, tail = _pattern_split(cfg)
    x = _embed_inputs(cfg, params, batch)
    enc_out = None
    if cfg.num_encoder_layers > 0:
        enc_out = encoder_forward(cfg, params, batch["enc_embeds"], remat)
    aux: dict = {}

    if n_periods > 0:
        def body(carry, period_params):
            h, aux_moe = carry
            a: dict = {}
            for i, kind in enumerate(period):
                h = apply_block(cfg, kind, period_params[f"pos{i}"], h, a,
                                enc_out=enc_out, attn_impl=attn_impl)
            aux_moe = aux_moe + a.get("moe_aux_loss", 0.0)
            return (h, aux_moe), None

        if remat != "none":
            body = jax.checkpoint(body)
        (x, aux_moe), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["periods"])
        if cfg.is_moe:
            aux["moe_aux_loss"] = aux_moe
    for i, kind in enumerate(tail):
        x = apply_block(cfg, kind, params["tail"][f"layer{i}"], x, aux,
                        enc_out=enc_out, attn_impl=attn_impl)

    x = layers.apply_norm(cfg.norm, x, params["out_norm"])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    return logits, aux


def loss_fn(cfg: ModelConfig, params: Any, batch: dict,
            remat: str = "block") -> tuple[jax.Array, dict]:
    logits, aux = forward(cfg, params, batch, remat)
    labels = batch["labels"]
    valid = (labels >= 0)
    safe = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll * valid) / denom
    metrics = {"loss": loss, "tokens": denom}
    if "moe_aux_loss" in aux:
        loss = loss + 0.01 * aux["moe_aux_loss"]
        metrics["moe_aux_loss"] = aux["moe_aux_loss"]
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def block_state_init(cfg: ModelConfig, kind: str, batch: int,
                     cache_ops) -> Any:
    if kind in ATTENTION_KINDS:
        window = cfg.window_size if kind == SWA_ATTN else 0
        return cache_ops.init_layer(cfg, batch, window=window)
    if kind == RGLRU:
        return recurrent.rglru_init_state(cfg, batch)
    if kind == MLSTM:
        return xlstm.mlstm_init_state(cfg, batch)
    if kind == SLSTM:
        return xlstm.slstm_init_state(cfg, batch)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, cache_ops,
                      enc_out: Optional[jax.Array] = None,
                      stacked: bool = True) -> dict:
    """Whole-model decode state.

    stacked=True: period states stack on a leading dim and the step scans
    them (small HLO).  stacked=False: one pytree per period under
    ``period_list`` and the step unrolls — no per-layer slice/copy of the
    large KV pools (the memory-term win for bridge decode at long context).
    """
    period, n_periods, tail = _pattern_split(cfg)
    state: dict[str, Any] = {"lengths": jnp.zeros((batch,), jnp.int32)}
    if n_periods > 0 and not stacked:
        state["period_list"] = [
            {f"pos{i}": block_state_init(cfg, k, batch, cache_ops)
             for i, k in enumerate(period)}
            for _ in range(n_periods)]
    elif n_periods > 0:
        def stack(kind):
            one = block_state_init(cfg, kind, batch, cache_ops)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape), one)
        state["periods"] = {f"pos{i}": stack(k)
                            for i, k in enumerate(period)}
    if tail:
        state["tail"] = {f"layer{i}": block_state_init(cfg, k, batch,
                                                       cache_ops)
                         for i, k in enumerate(tail)}
    if cfg.cross_attention and enc_out is not None:
        state["enc_out"] = enc_out
    shared = cache_ops.init_shared(cfg, batch)
    if shared is not None:
        state["kv_shared"] = shared
    return state


def apply_block_step(cfg: ModelConfig, kind: str, bp: dict, x: jax.Array,
                     st: Any, lengths: jax.Array, cache_ops,
                     enc_out: Optional[jax.Array],
                     shared: Any = None) -> tuple[jax.Array, Any]:
    h = layers.apply_norm(cfg.norm, x, bp["norm1"])
    aux: dict = {}
    if kind in ATTENTION_KINDS:
        q, k_new, v_new = attention.qkv_step(cfg, bp["attn"], h, lengths)
        window = cfg.window_size if kind == SWA_ATTN else 0
        att, st = cache_ops.append_and_attend(cfg, st, shared, lengths, q,
                                              k_new, v_new, window=window)
        x = x + attention.project_out_step(cfg, bp["attn"], att)
        if "cross" in bp and enc_out is not None:
            hc = layers.apply_norm(cfg.norm, x, bp["norm_cross"])
            ek, ev = attention.encode_cross_kv(cfg, bp["cross"], enc_out)
            x = x + attention.cross_attend_step(cfg, bp["cross"], hc, ek, ev)
        x2 = apply_ffn_step(cfg, bp, x, aux)
        return x2, st
    if kind == RGLRU:
        out, st = recurrent.rglru_step(cfg, bp["rglru"], h, st)
        x = x + out
        return apply_ffn_step(cfg, bp, x, aux), st
    if kind == MLSTM:
        out, st = xlstm.mlstm_step(cfg, bp["mlstm"], h, st)
        return x + out, st
    if kind == SLSTM:
        out, st = xlstm.slstm_step(cfg, bp["slstm"], h, st)
        return x + out, st
    raise ValueError(kind)


def apply_ffn_step(cfg: ModelConfig, bp: dict, x: jax.Array,
                   aux: dict) -> jax.Array:
    if not (cfg.is_moe or cfg.d_ff > 0):
        return x
    x3 = apply_ffn(cfg, bp, x[:, None, :], aux)
    return x3[:, 0, :]


def decode_step(cfg: ModelConfig, params: Any, state: dict,
                tokens: jax.Array, cache_ops) -> tuple[jax.Array, dict]:
    """One decode step. tokens: [B] -> (logits [B, V], new state)."""
    period, n_periods, tail = _pattern_split(cfg)
    x = layers.embed(tokens, params["embed"])
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    lengths = state["lengths"]
    enc_out = state.get("enc_out")
    shared = state.get("kv_shared")
    new_state: dict[str, Any] = dict(state)

    if "period_list" in state:
        # unrolled layout: per-period pytrees updated in place (no slicing)
        new_list = []
        for pi, ps in enumerate(state["period_list"]):
            pp = jax.tree.map(lambda a, pi=pi: a[pi], params["periods"])
            new_ps = {}
            for i, kind in enumerate(period):
                x, new_ps[f"pos{i}"] = apply_block_step(
                    cfg, kind, pp[f"pos{i}"], x, ps[f"pos{i}"], lengths,
                    cache_ops, enc_out, shared)
            new_list.append(new_ps)
        new_state["period_list"] = new_list
    elif n_periods > 0:
        def body(carry, xs):
            h = carry
            pp, ps = xs
            new_ps = {}
            for i, kind in enumerate(period):
                h, new_ps[f"pos{i}"] = apply_block_step(
                    cfg, kind, pp[f"pos{i}"], h, ps[f"pos{i}"], lengths,
                    cache_ops, enc_out, shared)
            return h, new_ps

        x, new_periods = jax.lax.scan(
            body, x, (params["periods"], state["periods"]))
        new_state["periods"] = new_periods
    if tail:
        new_tail = {}
        for i, kind in enumerate(tail):
            x, new_tail[f"layer{i}"] = apply_block_step(
                cfg, kind, params["tail"][f"layer{i}"], x,
                state["tail"][f"layer{i}"], lengths, cache_ops, enc_out,
                shared)
        new_state["tail"] = new_tail

    x = layers.apply_norm(cfg.norm, x, params["out_norm"])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = layers.unembed(x, head, cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        logits = logits[..., : cfg.vocab_size]
    new_state["lengths"] = lengths + 1
    return logits, new_state


# ---------------------------------------------------------------------------
# Dense (local) KV cache ops — the no-bridge baseline
# ---------------------------------------------------------------------------

class DenseCacheOps:
    """Per-layer state: {k, v: [B, S_max, kv, hd]} on the batch shard.

    SWA layers allocate only ``window`` slots (ring buffer semantics come
    from masking by absolute position; the dense baseline keeps it simple
    with a full-size buffer unless window < max_len).
    """

    def __init__(self, max_len: int, dtype=jnp.bfloat16):
        self.max_len = max_len
        self.dtype = dtype

    def init_shared(self, cfg: ModelConfig, batch: int):
        return None

    def init_layer(self, cfg: ModelConfig, batch: int, window: int = 0):
        shape = (batch, self.max_len, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, self.dtype),
                "v": jnp.zeros(shape, self.dtype)}

    def append_and_attend(self, cfg, st, shared, lengths, q, k_new, v_new, *,
                          window: int = 0):
        b = q.shape[0]
        idx = jnp.arange(b)
        k = st["k"].at[idx, lengths].set(k_new.astype(self.dtype))
        v = st["v"].at[idx, lengths].set(v_new.astype(self.dtype))
        visible = lengths + 1
        if window > 0:
            # sliding window: mask out positions older than window
            lo = jnp.maximum(visible - window, 0)
            pos = jnp.arange(self.max_len)[None, :]
            mask = (pos >= lo[:, None]) & (pos < visible[:, None])
            att = _masked_decode_attention(q, k, v, mask)
        else:
            from repro.core.kvbridge import decode_attention_ref
            att = decode_attention_ref(q, k, v, visible)
        return att, {"k": k, "v": v}


def _masked_decode_attention(q, k, v, mask):
    b, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32)) * hd ** -0.5
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)
