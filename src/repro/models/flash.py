"""Chunked (flash-style) attention in pure JAX with a custom VJP.

Full-sequence attention materializes [B, H, S, S] scores — 17 GB/device at a
32 k prefill — so every attention layer routes through this chunked
implementation: the forward scans KV chunks through an online-softmax
accumulator, and the backward recomputes per-chunk scores from the saved
(q, k, v, out, lse) — the flash-attention recipe, expressed in XLA ops.
The Pallas kernel (repro.kernels.flash_attention) implements the same
contract with explicit VMEM tiling; this module doubles as its oracle.

Supports GQA (kv_heads <= heads), causal and sliding-window masks, and
bidirectional (encoder) attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(q_pos, k_pos, causal: bool, window: int):
    """[Sq, Sk] boolean visibility mask."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def _chunk_scores(q, k_chunk, scale, mask_chunk):
    # q: [B, Sq, kv, G, hd]; k_chunk: [B, C, kv, hd] -> s: [B, kv, G, Sq, C]
    # dot inputs stay in their storage dtype (bf16 on the MXU) with f32
    # accumulation; only the scores are f32.
    s = jnp.einsum("bqkgd,bckd->bkgqc", q, k_chunk,
                   preferred_element_type=jnp.float32) * scale
    return jnp.where(mask_chunk[None, None, None], s, NEG_INF)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    chunk: int = 512, q_offset: int = 0) -> jax.Array:
    """q: [B, Sq, H, hd]; k, v: [B, Sk, kv, hd] -> [B, Sq, H, hd].

    ``q_offset``: absolute position of q[0] (prefill continuation); masks are
    computed from absolute positions.
    """
    out, _ = _flash_fwd_inner(q, k, v, causal, window, chunk, q_offset)
    return out


def _live_chunk_range(q_lo: int, q_hi: int, sk: int, chunk: int,
                      causal: bool, window: int,
                      q_offset: int) -> tuple[int, int]:
    """Static [k_chunk_lo, k_chunk_hi) with any unmasked position for the
    query block [q_lo, q_hi) — causal blocks above the diagonal and windowed
    blocks below q_lo - window are skipped entirely."""
    hi = sk
    if causal:
        hi = min(hi, q_hi + q_offset)
    lo = 0
    if window > 0:
        lo = max(0, q_lo + q_offset - window + 1)
    c_lo = lo // chunk
    c_hi = -(-hi // chunk) if hi > 0 else 0
    return c_lo, max(c_hi, c_lo)


def _flash_fwd_inner(q, k, v, causal, window, chunk, q_offset,
                     q_block: int = 4096):
    """Query-blocked, chunk-skipping online-softmax attention.

    The outer (unrolled) loop walks q blocks; the inner scan walks only the
    k chunks a block can see (causal upper triangle and sliding-window lower
    band are skipped statically), halving causal traffic and reducing
    windowed layers to O(window) per block.  The accumulator keeps the
    [B, kv, G, q, d] layout so no big per-chunk transposes appear.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    nchunks = -(-sk // chunk)
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, chunk, kv, hd)
    vc = v.reshape(b, nchunks, chunk, kv, hd)

    q_block = min(q_block, sq)
    outs, lses = [], []
    for q_lo in range(0, sq, q_block):
        q_hi = min(q_lo + q_block, sq)
        bq = q_hi - q_lo
        qg = q[:, q_lo:q_hi].reshape(b, bq, kv, g, hd)
        q_pos = jnp.arange(q_lo, q_hi) + q_offset
        c_lo, c_hi = _live_chunk_range(q_lo, q_hi, sk, chunk, causal,
                                       window, q_offset)
        if c_hi == c_lo:
            outs.append(jnp.zeros((b, bq, h, hd), q.dtype))
            lses.append(jnp.full((b, kv, g, bq), NEG_INF, jnp.float32))
            continue

        # Chunks fully inside the visible band skip masking entirely —
        # boundary chunks (causal diagonal, window edge, seq padding) get
        # the masked body.  exp(s_masked - m) underflows to 0, so no second
        # select is needed after the exp.
        def full_live(c):
            if (c + 1) * chunk > sk:
                return False
            if causal and (c + 1) * chunk - 1 > q_lo + q_offset:
                return False
            if window > 0 and c * chunk < q_hi - 1 + q_offset - window + 1:
                return False
            return True

        def body(carry, xs, masked, q_pos=q_pos, qg=qg):
            m_prev, l_prev, acc = carry
            k_ch, v_ch, ci = xs
            if masked:
                k_pos = ci * chunk + jnp.arange(chunk)
                mask = _mask(q_pos, k_pos, causal, window) \
                    & (k_pos < sk)[None]
                s = _chunk_scores(qg, k_ch, scale, mask)  # [B,kv,G,bq,C]
            else:
                s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_ch,
                               preferred_element_type=jnp.float32) * scale
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            # keep [B,kv,G,q,d] layout end-to-end (no score transposes)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_ch.dtype), v_ch,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        carry = (jnp.full((b, kv, g, bq), NEG_INF, jnp.float32),
                 jnp.zeros((b, kv, g, bq), jnp.float32),
                 jnp.zeros((b, kv, g, bq, hd), jnp.float32))
        # segment the chunk range into maximal masked/unmasked runs
        runs: list[tuple[bool, int, int]] = []
        for c in range(c_lo, c_hi):
            m_flag = not full_live(c)
            if runs and runs[-1][0] == m_flag and runs[-1][2] == c:
                runs[-1] = (m_flag, runs[-1][1], c + 1)
            else:
                runs.append((m_flag, c, c + 1))
        for masked, r_lo, r_hi in runs:
            xs = (jnp.moveaxis(kc[:, r_lo:r_hi], 1, 0),
                  jnp.moveaxis(vc[:, r_lo:r_hi], 1, 0),
                  jnp.arange(r_lo, r_hi))
            carry, _ = jax.lax.scan(
                lambda c_, x_, mk=masked: body(c_, x_, mk), carry, xs)
        m, l, acc = carry
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]                     # [B,kv,G,bq,hd]
        out = jnp.moveaxis(out, 3, 1).reshape(b, bq, h, hd)
        outs.append(out.astype(q.dtype))
        lses.append(m + jnp.log(l_safe))
    out = jnp.concatenate(outs, axis=1)
    lse = jnp.concatenate(lses, axis=-1)                  # [B,kv,G,Sq]
    return out, lse


def _flash_fwd(q, k, v, causal, window, chunk, q_offset):
    out, lse = _flash_fwd_inner(q, k, v, causal, window, chunk, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, chunk, q_offset, res, dout):
    """Query-blocked, chunk-skipping flash backward (mirrors the forward):
    per q-block, only the statically-live k chunks are recomputed, and dk/dv
    accumulate into full buffers with dynamic-update-slices."""
    q, k, v, out, lse = res
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    nchunks = -(-sk // chunk)
    pad = nchunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, chunk, kv, hd)
    vc = v.reshape(b, nchunks, chunk, kv, hd)
    dk = jnp.zeros((b, nchunks, chunk, kv, hd), jnp.float32)
    dv = jnp.zeros((b, nchunks, chunk, kv, hd), jnp.float32)

    q_block = min(4096, sq)
    dqs = []
    for q_lo in range(0, sq, q_block):
        q_hi = min(q_lo + q_block, sq)
        bq = q_hi - q_lo
        qg = q[:, q_lo:q_hi].reshape(b, bq, kv, g, hd)
        og = out[:, q_lo:q_hi].reshape(b, bq, kv, g, hd).astype(jnp.float32)
        dog = dout[:, q_lo:q_hi].reshape(b, bq, kv, g,
                                         hd).astype(jnp.float32)
        lse_b = lse[..., q_lo:q_hi]
        delta = jnp.sum(og * dog, axis=-1).transpose(0, 2, 3, 1)  # [B,kv,G,bq]
        q_pos = jnp.arange(q_lo, q_hi) + q_offset
        c_lo, c_hi = _live_chunk_range(q_lo, q_hi, sk, chunk, causal,
                                       window, q_offset)
        if c_hi == c_lo:
            dqs.append(jnp.zeros((b, bq, h, hd), q.dtype))
            continue

        def body(carry, xs, q_pos=q_pos, qg=qg, dog=dog, lse_b=lse_b,
                 delta=delta):
            dq_acc, dk_b, dv_b = carry
            k_ch, v_ch, ci = xs
            k_pos = ci * chunk + jnp.arange(chunk)
            mask = _mask(q_pos, k_pos, causal, window) & (k_pos < sk)[None]
            s = _chunk_scores(qg, k_ch, scale, mask)      # [B,kv,G,bq,C]
            p = jnp.exp(s - lse_b[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            pb = p.astype(v_ch.dtype)
            dv_ch = jnp.einsum("bkgqc,bqkgd->bckd", pb, dog.astype(pb.dtype),
                               preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqkgd,bckd->bkgqc", dog.astype(v_ch.dtype),
                            v_ch, preferred_element_type=jnp.float32)
            ds = p * (dp - delta[..., None]) * scale
            dsb = ds.astype(k_ch.dtype)
            dq_acc = dq_acc + jnp.einsum(
                "bkgqc,bckd->bqkgd", dsb, k_ch,
                preferred_element_type=jnp.float32)
            dk_ch = jnp.einsum("bkgqc,bqkgd->bckd", dsb,
                               qg.astype(dsb.dtype),
                               preferred_element_type=jnp.float32)
            dk_b = jax.lax.dynamic_update_index_in_dim(
                dk_b, jax.lax.dynamic_index_in_dim(
                    dk_b, ci, 1, keepdims=False) + dk_ch, ci, 1)
            dv_b = jax.lax.dynamic_update_index_in_dim(
                dv_b, jax.lax.dynamic_index_in_dim(
                    dv_b, ci, 1, keepdims=False) + dv_ch, ci, 1)
            return (dq_acc, dk_b, dv_b), None

        dq0 = jnp.zeros((b, bq, kv, g, hd), jnp.float32)
        xs = (jnp.moveaxis(kc[:, c_lo:c_hi], 1, 0),
              jnp.moveaxis(vc[:, c_lo:c_hi], 1, 0),
              jnp.arange(c_lo, c_hi))
        (dq_b, dk, dv), _ = jax.lax.scan(body, (dq0, dk, dv), xs)
        dqs.append(dq_b.reshape(b, bq, h, hd).astype(q.dtype))

    dq = jnp.concatenate(dqs, axis=1)
    dk = dk.reshape(b, nchunks * chunk, kv, hd)[:, :sk]
    dv = dv.reshape(b, nchunks * chunk, kv, hd)[:, :sk]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# Dense reference (for tests and tiny shapes)
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, causal=True, window=0, q_offset=0):
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s * hd ** -0.5
    mask = _mask(jnp.arange(sq) + q_offset, jnp.arange(k.shape[1]),
                 causal, window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None, None], p, 0.0)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)
