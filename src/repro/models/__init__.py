from repro.models.transformer import (  # noqa: F401
    DenseCacheOps,
    abstract_params,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    model_specs,
    params_logical_axes,
)
