"""GQA attention blocks: projections + RoPE + flash, train & decode paths."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SWA_ATTN
from repro.models import flash, layers
from repro.models.layers import Param


def attn_specs(cfg: ModelConfig) -> dict[str, Param]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": Param((d, h * hd), (None, "heads")),
        "wk": Param((d, kv * hd), (None, "kv_heads")),
        "wv": Param((d, kv * hd), (None, "kv_heads")),
        "wo": Param((h * hd, d), ("heads", None)),
    }


def qkv(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
        positions: Optional[jax.Array] = None, use_rope: bool = True):
    """x: [B, S, d] -> q [B,S,H,hd], k,v [B,S,kv,hd] (RoPE applied)."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, kvh, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, kvh, hd)
    if use_rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = layers.rope(q, positions, cfg.rope_theta)
        k = layers.rope(k, positions, cfg.rope_theta)
    return q, k, v


def attend_train(cfg: ModelConfig, kind: str, q, k, v, *,
                 causal: bool = True, chunk: int = 512,
                 impl: str = "xla") -> jax.Array:
    """Sequence attention by layer kind (full/global vs sliding-window).

    impl="xla": chunked flash in XLA ops (custom VJP, trains).
    impl="pallas": the Pallas TPU kernel (forward; serving/prefill path).
    """
    window = cfg.window_size if kind == SWA_ATTN else 0
    s = q.shape[1]
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if s <= chunk:  # tiny sequences: dense reference path is cheaper
        return flash.attention_ref(q, k, v, causal=causal, window=window)
    return flash.flash_attention(q, k, v, causal, window, chunk, 0)


def project_out(cfg: ModelConfig, p: dict[str, jax.Array],
                attn_out: jax.Array) -> jax.Array:
    b, s = attn_out.shape[:2]
    flat = attn_out.reshape(b, s, cfg.num_heads * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", flat, p["wo"])


# -- decode ------------------------------------------------------------------

def qkv_step(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
             position: jax.Array, use_rope: bool = True):
    """x: [B, d], position: [B] -> q [B,H,hd], k,v [B,kv,hd]."""
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bd,de->be", x, p["wq"]).reshape(b, 1, h, hd)
    k = jnp.einsum("bd,de->be", x, p["wk"]).reshape(b, 1, kvh, hd)
    v = jnp.einsum("bd,de->be", x, p["wv"]).reshape(b, 1, kvh, hd)
    if use_rope:
        q = layers.rope(q, position[:, None], cfg.rope_theta)
        k = layers.rope(k, position[:, None], cfg.rope_theta)
    return q[:, 0], k[:, 0], v[:, 0]


def project_out_step(cfg: ModelConfig, p: dict[str, jax.Array],
                     attn_out: jax.Array) -> jax.Array:
    flat = attn_out.reshape(attn_out.shape[0], cfg.num_heads * cfg.head_dim)
    return jnp.einsum("be,ed->bd", flat, p["wo"])


# -- cross attention (enc-dec) -------------------------------------------------

def cross_attn_specs(cfg: ModelConfig) -> dict[str, Param]:
    return attn_specs(cfg)


def cross_attend(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    """x: [B, S, d] attends enc_out [B, Se, d] bidirectionally."""
    b, s, _ = x.shape
    se = enc_out.shape[1]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"]).reshape(b, se, kvh, hd)
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"]).reshape(b, se, kvh, hd)
    out = attend_train(cfg, "full", q, k, v, causal=False)
    return project_out(cfg, p, out)


def cross_attend_step(cfg: ModelConfig, p: dict[str, jax.Array],
                      x: jax.Array, enc_k: jax.Array,
                      enc_v: jax.Array) -> jax.Array:
    """Decode-time cross attention against precomputed enc K/V [B,Se,kv,hd]."""
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bd,de->be", x, p["wq"]).reshape(b, h, hd)
    from repro.core.kvbridge import decode_attention_ref
    lengths = jnp.full((b,), enc_k.shape[1], jnp.int32)
    out = decode_attention_ref(q, enc_k, enc_v, lengths)
    return project_out_step(cfg, p, out)


def encode_cross_kv(cfg: ModelConfig, p: dict[str, jax.Array],
                    enc_out: jax.Array):
    b, se, _ = enc_out.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"]).reshape(b, se, kvh, hd)
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"]).reshape(b, se, kvh, hd)
    return k, v
