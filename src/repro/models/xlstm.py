"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Faithful to arXiv:2405.04517 semantics with exponential gating + stabilizer
state.  Both blocks run as ``lax.scan`` over time (exact recurrent form; the
sLSTM is inherently sequential because h_{t-1} feeds its gates, and the
mLSTM uses the same body so train == decode bit-for-bit).  Decode exposes a
single-token step with O(1) state — this is what makes xlstm-125m a
``long_500k``-capable architecture.

State sizes per layer:
  mLSTM: C [B, H, hd, hd], n [B, H, hd], m [B, H]   (+ conv buffer)
  sLSTM: c, n, h [B, d], m [B, d]
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers
from repro.models.layers import Param


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    heads = cfg.num_heads
    hd = inner // heads
    return inner, heads, hd


def mlstm_specs(cfg: ModelConfig) -> dict[str, Param]:
    d = cfg.d_model
    inner, h, hd = _mlstm_dims(cfg)
    return {
        "up_x": Param((d, inner), (None, "ff")),
        "up_z": Param((d, inner), (None, "ff")),
        "conv_w": Param((cfg.conv_width, inner), (None, "ff"), scale=0.5),
        "conv_b": Param((inner,), ("ff",), init="zeros"),
        "wq": Param((inner, inner), ("ff", None)),
        "wk": Param((inner, inner), ("ff", None)),
        "wv": Param((inner, inner), ("ff", None)),
        "wi": Param((inner, h), ("ff", None)),
        "wf": Param((inner, h), ("ff", None)),
        "wo_gate": Param((inner, inner), ("ff", None)),
        "down": Param((inner, d), ("ff", None)),
    }


def mlstm_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    inner, h, hd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, inner), jnp.bfloat16),
    }


def _mlstm_cell(q, k, v, i_raw, f_raw, state):
    """One stabilized mLSTM cell step.  q,k,v: [B,H,hd]; gates [B,H]."""
    hd = q.shape[-1]
    m_prev = state["m"]
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + m_prev, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + m_prev - m_new)
    k_s = k / (hd ** 0.5)
    c_new = (f_g[..., None, None] * state["C"]
             + i_g[..., None, None] * v[..., :, None] * k_s[..., None, :])
    n_new = f_g[..., None] * state["n"] + i_g[..., None] * k_s
    num = jnp.einsum("bhij,bhj->bhi", c_new, q)
    den = jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q))
    den = jnp.maximum(den, jnp.exp(-m_new))
    h_t = num / den[..., None]
    return h_t, {"C": c_new, "n": n_new, "m": m_new}


def _mlstm_qkvif(cfg, p, x_in, conv_buf):
    """Projections shared by seq and step paths. x_in: [B, S, inner]."""
    xc, new_buf = _conv_step_or_seq(p, x_in, conv_buf)
    xc = jax.nn.silu(xc)
    inner, h, hd = _mlstm_dims(cfg)
    b, s = x_in.shape[:2]
    q = jnp.einsum("bsi,ij->bsj", xc, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsi,ij->bsj", xc, p["wk"]).reshape(b, s, h, hd)
    v = jnp.einsum("bsi,ij->bsj", x_in, p["wv"]).reshape(b, s, h, hd)
    i_raw = jnp.einsum("bsi,ih->bsh", x_in, p["wi"]).astype(jnp.float32)
    f_raw = jnp.einsum("bsi,ih->bsh", x_in, p["wf"]).astype(jnp.float32)
    return q.astype(jnp.float32), k.astype(jnp.float32), \
        v.astype(jnp.float32), i_raw, f_raw, new_buf


def _conv_step_or_seq(p, x, buf):
    cw = p["conv_w"].shape[0]
    if buf is None:
        buf = jnp.zeros(x.shape[:1] + (cw - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([buf.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i: i + x.shape[1]] * p["conv_w"][i]
              for i in range(cw)) + p["conv_b"]
    return out.astype(x.dtype), xp[:, -(cw - 1):] if cw > 1 else buf


def mlstm_seq(cfg: ModelConfig, p: dict[str, jax.Array],
              x: jax.Array) -> jax.Array:
    """Training path. x: [B, S, d] -> [B, S, d] (state starts at zero)."""
    x_in = jnp.einsum("bsd,di->bsi", x, p["up_x"])
    z = jnp.einsum("bsd,di->bsi", x, p["up_z"])
    q, k, v, i_raw, f_raw, _ = _mlstm_qkvif(cfg, p, x_in, None)
    state = mlstm_init_state(cfg, x.shape[0])
    state.pop("conv")

    def step(st, xs):
        q_t, k_t, v_t, i_t, f_t = xs
        h_t, st = _mlstm_cell(q_t, k_t, v_t, i_t, f_t, st)
        return st, h_t

    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0),
                      (q, k, v, i_raw, f_raw))
    _, hs = jax.lax.scan(step, state, xs)
    inner, h, hd = _mlstm_dims(cfg)
    hs = jnp.moveaxis(hs, 0, 1).reshape(x.shape[0], x.shape[1], inner)
    out = hs.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", out, p["down"])


def mlstm_step(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
               state: dict[str, jax.Array]) -> tuple[jax.Array, dict]:
    """Decode step. x: [B, d]."""
    x_in = jnp.einsum("bd,di->bi", x, p["up_x"])[:, None]
    z = jnp.einsum("bd,di->bi", x, p["up_z"])
    q, k, v, i_raw, f_raw, new_buf = _mlstm_qkvif(
        cfg, p, x_in, state["conv"])
    cell = {k2: state[k2] for k2 in ("C", "n", "m")}
    h_t, cell = _mlstm_cell(q[:, 0], k[:, 0], v[:, 0],
                            i_raw[:, 0], f_raw[:, 0], cell)
    inner, h, hd = _mlstm_dims(cfg)
    out = h_t.reshape(x.shape[0], inner).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", out, p["down"])
    return out, {**cell, "conv": new_buf}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _round64(n: int) -> int:
    return ((n + 63) // 64) * 64


def slstm_specs(cfg: ModelConfig) -> dict[str, Param]:
    d = cfg.d_model
    ff = _round64(int(d * cfg.slstm_proj_factor))  # shardable over TP=16
    spec = {}
    for g in ("i", "f", "z", "o"):
        spec[f"w_{g}"] = Param((d, d), (None, "ff"))
        spec[f"r_{g}"] = Param((d, d), (None, "ff"), scale=0.5)
        spec[f"b_{g}"] = Param((d,), ("ff",), init="zeros")
    spec["ffn_up"] = Param((d, ff), (None, "ff"))
    spec["ffn_down"] = Param((ff, d), ("ff", None))
    spec["ffn_norm"] = layers.norm_spec(d)
    return spec


def slstm_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30,
                                                  jnp.float32)}


def _slstm_cell(p, x_t, st):
    """x_t: [B, d] (fp32)."""
    h_prev = st["h"]

    def gate(g):
        return (jnp.einsum("bd,de->be", x_t, p[f"w_{g}"].astype(jnp.float32))
                + jnp.einsum("bd,de->be", h_prev,
                             p[f"r_{g}"].astype(jnp.float32))
                + p[f"b_{g}"].astype(jnp.float32))

    i_raw, f_raw, z_raw, o_raw = gate("i"), gate("f"), gate("z"), gate("o")
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + st["m"], i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + st["m"] - m_new)
    c_new = f_g * st["c"] + i_g * jnp.tanh(z_raw)
    n_new = jnp.maximum(f_g * st["n"] + i_g, 1e-6)
    h_new = jax.nn.sigmoid(o_raw) * c_new / n_new
    return h_new, {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_seq(cfg: ModelConfig, p: dict[str, jax.Array],
              x: jax.Array) -> jax.Array:
    """x: [B, S, d] -> [B, S, d]."""
    xf = x.astype(jnp.float32)

    def step(st, x_t):
        h, st = _slstm_cell(p, x_t, st)
        return st, h

    _, hs = jax.lax.scan(step, slstm_init_state(cfg, x.shape[0]),
                         jnp.moveaxis(xf, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = layers.rmsnorm(h, p["ffn_norm"])
    up = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["ffn_up"]))
    return jnp.einsum("bsf,fd->bsd", up, p["ffn_down"])


def slstm_step(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
               state: dict[str, jax.Array]) -> tuple[jax.Array, dict]:
    h, state = _slstm_cell(p, x.astype(jnp.float32), state)
    h = layers.rmsnorm(h.astype(x.dtype), p["ffn_norm"])
    up = jax.nn.gelu(jnp.einsum("bd,df->bf", h, p["ffn_up"]))
    return jnp.einsum("bf,fd->bd", up, p["ffn_down"]), state
