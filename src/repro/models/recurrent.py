"""RG-LRU recurrent block (RecurrentGemma / Griffin) — train scan + decode step.

Block structure (Griffin "recurrent block"):

    x -> { W_x -> causal conv1d(w=4) -> RG-LRU }  *  { W_y -> GeLU }  -> W_out

RG-LRU (per channel):
    r_t = sigmoid(W_r x_t),  i_t = sigmoid(W_i x_t)
    log a_t = -c * softplus(Lambda) * r_t          (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence form is a linear recurrence -> associative scan for training;
decode keeps (h, conv buffer) as state.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers
from repro.models.layers import Param

C_RGLRU = 8.0


def rglru_specs(cfg: ModelConfig) -> dict[str, Param]:
    d, w = cfg.d_model, cfg.lru_width
    return {
        "wx": Param((d, w), (None, "ff")),
        "wy": Param((d, w), (None, "ff")),
        "conv_w": Param((cfg.conv_width, w), (None, "ff"), scale=0.5),
        "conv_b": Param((w,), ("ff",), init="zeros"),
        "wr": Param((w, w), ("ff", None)),
        "wi": Param((w, w), ("ff", None)),
        "lam": Param((w,), ("ff",), init="normal", scale=4.0),
        "wo": Param((w, d), ("ff", None)),
    }


def _gates(p, x):
    r = jax.nn.sigmoid(jnp.einsum("...w,wu->...u", x, p["wr"]))
    i = jax.nn.sigmoid(jnp.einsum("...w,wu->...u", x, p["wi"]))
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-9)) * (i * x)
    return a, gated_x


def _conv_causal(p, x_seq, buf=None):
    """Depthwise causal conv. x_seq: [B, S, W]; buf: [B, cw-1, W] history."""
    cw = p["conv_w"].shape[0]
    if buf is None:
        buf = jnp.zeros(x_seq.shape[:1] + (cw - 1,) + x_seq.shape[2:],
                        x_seq.dtype)
    xp = jnp.concatenate([buf, x_seq], axis=1)
    out = sum(xp[:, i: i + x_seq.shape[1]] * p["conv_w"][i]
              for i in range(cw)) + p["conv_b"]
    new_buf = xp[:, -(cw - 1):] if cw > 1 else buf
    return out.astype(x_seq.dtype), new_buf


def rglru_seq(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
              h0=None) -> tuple[jax.Array, jax.Array]:
    """Training/prefill path. x: [B, S, d] -> (out [B, S, d], h_last)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    yb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wy"]))
    xc, _ = _conv_causal(p, xb)
    a, gx = _gates(p, xc.astype(jnp.float32))
    if h0 is None:
        h0 = jnp.zeros(gx.shape[:1] + gx.shape[2:], jnp.float32)

    # h_t = a_t h_{t-1} + gx_t  ==  associative scan on (a, gx) pairs.
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, b_s = jax.lax.associative_scan(combine, (a, gx), axis=1)
    h = a_s * h0[:, None] + b_s                            # [B, S, W]
    out = jnp.einsum("bsw,wd->bsd", (h.astype(x.dtype) * yb), p["wo"])
    return out, h[:, -1]


def rglru_step(cfg: ModelConfig, p: dict[str, jax.Array], x: jax.Array,
               state: dict[str, jax.Array]) -> tuple[jax.Array, dict]:
    """Decode step. x: [B, d]; state: {h: [B,W], conv: [B,cw-1,W]}."""
    xb = jnp.einsum("bd,dw->bw", x, p["wx"])
    yb = jax.nn.gelu(jnp.einsum("bd,dw->bw", x, p["wy"]))
    xc, new_conv = _conv_causal(p, xb[:, None], state["conv"])
    xc = xc[:, 0]
    a, gx = _gates(p, xc.astype(jnp.float32))
    h = a * state["h"] + gx
    out = jnp.einsum("bw,wd->bd", (h.astype(x.dtype) * yb), p["wo"])
    return out, {"h": h, "conv": new_conv}


def rglru_init_state(cfg: ModelConfig, batch: int) -> dict[str, jax.Array]:
    w = cfg.lru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.bfloat16)}


def rglru_seq_ref(cfg: ModelConfig, p, x):
    """Oracle: plain lax.scan over time (no associative scan)."""
    xb = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    yb = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wy"]))
    xc, _ = _conv_causal(p, xb)
    a, gx = _gates(p, xc.astype(jnp.float32))

    def step(h, xs):
        a_t, g_t = xs
        h = a_t * h + g_t
        return h, h

    h0 = jnp.zeros((x.shape[0], cfg.lru_width), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a.transpose(1, 0, 2), gx.transpose(1, 0, 2)))
    h = hs.transpose(1, 0, 2)
    return jnp.einsum("bsw,wd->bsd", (h.astype(x.dtype) * yb), p["wo"])
