"""Mixture-of-Experts FFN: top-k routing with capacity (GShard-style).

Dispatch avoids the [T, E, C] one-hot tensor: tokens scatter into per-expert
buffers via position-in-expert (cumsum), experts run as one batched einsum
(sharded over the expert axis = EP), and outputs gather back weighted by the
router gates.  Tokens beyond an expert's capacity are dropped (standard
capacity-factor semantics) and reported in metrics.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers
from repro.models.layers import Param


def moe_specs(cfg: ModelConfig) -> dict[str, Param]:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    # EP: experts shard over the model axis; per-expert ff stays unsharded
    # (sharding both would double-bind the mesh axis).
    spec = {
        "router": Param((d, e), (None, None)),
        "wi": Param((e, d, ff), ("experts", None, None)),
        "wo": Param((e, ff, d), ("experts", None, None)),
    }
    if cfg.glu:
        spec["wg"] = Param((e, d, ff), ("experts", None, None))
    return spec


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(num_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(c, cfg.experts_per_token)


def moe_ffn(cfg: ModelConfig, params: dict[str, jax.Array],
            x: jax.Array) -> tuple[jax.Array, dict[str, jax.Array]]:
    """x: [B, S, d] -> (out [B, S, d], metrics)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    cap = capacity(cfg, t)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # Position of each (token, choice) within its expert, token-ordered.
    # int8 one-hot: this tensor crosses the wire when GSPMD replicates the
    # (inherently sequential) cumsum — 4x fewer bytes than s32.
    flat_e = expert_idx.reshape(-1)                          # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int8)       # [T*k, E]
    pos = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1   # [T*k, E]
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], 1)[:, 0]
    keep = pos_in_e < cap
    slot = jnp.where(keep, flat_e * cap + pos_in_e, e * cap)  # drop -> OOB

    # Dispatch: [E*C, d] buffers.
    tok_of = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(
        xt[tok_of], mode="drop")[: e * cap]
    h = buf.reshape(e, cap, d)

    # Expert FFN (einsum batched over E; EP shards the leading dim).
    act = layers.act_fn(cfg.act)
    up = jnp.einsum("ecd,edf->ecf", h, params["wi"])
    if cfg.glu:
        gate = jnp.einsum("ecd,edf->ecf", h, params["wg"])
        up = act(gate) * up
    else:
        up = act(up)
    out_e = jnp.einsum("ecf,efd->ecd", up, params["wo"])     # [E, C, d]

    # Combine: gather each (token, choice)'s expert output, weight by gate.
    # Gates cast to the activation dtype: an f32 gate would promote the
    # whole [T*k, d] combine payload to f32 on the wire (2x collective
    # bytes); the scatter-add still accumulates in f32.
    flat_out = out_e.reshape(e * cap, d)
    safe_slot = jnp.where(keep, slot, 0)
    gathered = flat_out[safe_slot] * keep[:, None].astype(flat_out.dtype)
    gates_cast = gate_vals.reshape(-1)[:, None].astype(flat_out.dtype)
    weighted = gathered * gates_cast
    out = jnp.zeros((t, d), jnp.float32).at[tok_of].add(
        weighted.astype(jnp.float32))

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    metrics = {"moe_aux_loss": aux, "moe_drop_frac": dropped}
    return out.reshape(b, s, d).astype(x.dtype), metrics


def moe_ffn_ref(cfg: ModelConfig, params: dict[str, jax.Array],
                x: jax.Array) -> jax.Array:
    """Oracle: dense per-token expert evaluation, no capacity dropping.

    Matches moe_ffn when capacity is not exceeded.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    act = layers.act_fn(cfg.act)
    # all experts on all tokens
    up = jnp.einsum("td,edf->tef", xt, params["wi"])
    if cfg.glu:
        up = act(jnp.einsum("td,edf->tef", xt, params["wg"])) * up
    else:
        up = act(up)
    all_out = jnp.einsum("tef,efd->ted", up, params["wo"])   # [T, E, d]
    sel = jnp.take_along_axis(all_out, expert_idx[..., None], axis=1)
    out = jnp.sum(sel * gate_vals[..., None], axis=1)
    return out.reshape(b, s, d).astype(x.dtype)
