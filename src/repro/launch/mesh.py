"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device counts are locked at first jax init, and
only launch/dryrun.py (or the real pod launcher) sets them.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2):
    """Small mesh for the 8-virtual-device subprocess tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_board_mesh(num_boards: int = 2, board_size: int = 4, **topo_hw):
    """1-D mem-axis mesh over a board + rack fabric.

    Returns ``(mesh, topology)``: the mesh's ``data`` axis enumerates the
    fabric's endpoints board-major (rank = board * board_size + local
    rank), and the :class:`~repro.core.topology.Topology` describes the
    two tiers for the bridge's steering / telemetry / perfmodel.
    ``topo_hw`` forwards per-tier wire constants (``rack_link_gbps`` etc.).
    """
    from repro.core.topology import Topology
    mesh = jax.make_mesh((num_boards * board_size,), ("data",))
    return mesh, Topology.boards(num_boards, board_size, **topo_hw)


def make_production_board_mesh(*, num_boards: int = 16,
                               board_size: int = 16, **topo_hw):
    """Rack-scale fabric: 16 boards x 16 endpoints (256 chips) by default."""
    return make_board_mesh(num_boards, board_size, **topo_hw)
