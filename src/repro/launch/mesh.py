"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — device counts are locked at first jax init, and
only launch/dryrun.py (or the real pod launcher) sets them.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 4, model: int = 2):
    """Small mesh for the 8-virtual-device subprocess tests."""
    return jax.make_mesh((data, model), ("data", "model"))
