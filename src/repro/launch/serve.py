"""Serving launcher: batched greedy decode with a selectable KV placement.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --reduced \
      --kv bridge_pull --batch 4 --steps 32

``--traffic`` switches from one fixed batch to request-level serving: a
seeded Poisson arrival stream (two tenants, interactive + batch QoS)
drives the continuous batcher over the same jitted decode step — slots
admit from per-tenant queues as sequences retire, KV pages lease from an
orchestrated pool, and the run reports per-QoS p50/p99 round latencies:

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --reduced --traffic --batch 8 --traffic-steps 24
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.clock import MonotonicClock

from repro import configs
from repro.config import RunConfig, ShapeConfig
from repro.serve import step as serve_step_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--kv", default="local",
                    choices=["local", "ring", "bridge_pull", "bridge_push"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--telemetry", action="store_true",
                    help="collect in-band bridge counters (bridge_* "
                         "placements) and print the aggregate")
    ap.add_argument("--channels", type=int, default=1,
                    help="pipelined bridge round-engine depth (1=serial)")
    ap.add_argument("--no-fused", action="store_true",
                    help="escape hatch: run the unfused ppermute-chain "
                         "bridge engines instead of the fused Pallas "
                         "datapath (bit-exact either way)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="serve the batch as K tenants (sequence b belongs "
                         "to tenant b %% K); with --telemetry the bridge "
                         "counters attribute traffic per tenant")
    ap.add_argument("--metrics", action="store_true",
                    help="trace every decode step as a fenced span, print "
                         "the metrics registry snapshot (per-step latency "
                         "p50/p99, bridge counter families) and, with "
                         "--trace-out, write the Perfetto trace JSON")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="with --metrics: write the Chrome-trace/Perfetto "
                         "JSON of the decode loop to PATH")
    ap.add_argument("--traffic", action="store_true",
                    help="request-level serving: continuous batching over "
                         "a seeded two-tenant Poisson arrival stream "
                         "(--batch sets the decode slot count)")
    ap.add_argument("--traffic-steps", type=int, default=32,
                    help="arrival steps to offer load for (the loop then "
                         "drains in-flight sequences)")
    ap.add_argument("--traffic-rate", type=float, default=0.5,
                    help="expected arrivals per step per tenant")
    ap.add_argument("--traffic-seed", type=int, default=0)
    ap.add_argument("--policy", default="qos", choices=["qos", "naive"],
                    help="slot admission: QoS-aware weighted-fair windows "
                         "or a single global FIFO (the noisy-neighbour "
                         "baseline)")
    ap.add_argument("--debug-bundle", default=None, metavar="PATH",
                    help="with --traffic: write a postmortem zip (flight "
                         "journal, Perfetto trace, metrics text, "
                         "describe()) to PATH after the run")
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    shape = ShapeConfig("cli", args.max_len, args.batch, "decode")
    from repro.config import BridgeConfig
    run = RunConfig(model=cfg, shape=shape, kv_placement=args.kv,
                    bridge=BridgeConfig(channels=args.channels,
                                        fused=not args.no_fused))

    from repro.models import transformer
    params = transformer.init_params(cfg, jax.random.key(0))
    if args.traffic:
        _traffic_mode(run, cfg, params, args)
        return
    collect = args.telemetry and args.kv in ("bridge_pull", "bridge_push")
    if args.tenants < 1:
        ap.error("--tenants must be >= 1")
    tenant_of_seq = (np.arange(args.batch) % args.tenants
                     if args.tenants > 1 else None)
    cache_ops = serve_step_mod.make_cache_ops(
        run, mesh=None, max_len=args.max_len, page_tokens=args.page_tokens,
        collect_telemetry=collect, tenant_of_seq=tenant_of_seq,
        max_tenants=args.tenants if args.tenants > 1 else 0,
        dtype=jnp.dtype(cfg.dtype))
    enc_out = None
    if cfg.cross_attention:
        enc_out = jnp.asarray(np.random.default_rng(0).normal(
            size=(args.batch, 16, cfg.d_model)), jnp.dtype(cfg.dtype))
    state = serve_step_mod.init_serve_state(run, args.batch, cache_ops,
                                            enc_out=enc_out)
    step = jax.jit(serve_step_mod.build_serve_step(run, cache_ops),
                   donate_argnums=(1,))

    # --metrics wraps every decode step in a fenced span: the per-step
    # fence changes the loop's async-dispatch overlap, so it is opt-in —
    # the untraced path stays exactly as before.
    recorder = registry = None
    if args.metrics:
        from repro.obs import MetricsRegistry, TraceRecorder
        recorder = TraceRecorder(process_name=f"serve:{args.arch}")
        registry = MetricsRegistry()

    tokens = jnp.ones((args.batch,), jnp.int32)
    wall = MonotonicClock()
    t0 = wall.now_us()
    emitted = []
    for i in range(args.steps):
        if recorder is not None:
            with recorder.span("decode_step", "round", step=i) as sp:
                tokens, state = step(params, state, tokens)
                recorder.fence(tokens)
            registry.observe_span(sp)
        else:
            tokens, state = step(params, state, tokens)
        emitted.append(np.asarray(tokens))
    dt = (wall.now_us() - t0) / 1e6
    print(f"arch={cfg.name} kv={args.kv} batch={args.batch} "
          f"steps={args.steps}")
    print(f"tokens/s={args.batch*args.steps/dt:.1f} "
          f"({dt/args.steps*1e3:.1f} ms/step)")
    print("sample:", np.stack(emitted, 1)[0][:16])
    if collect:
        from repro.core.control_plane import ControlPlane
        from repro.telemetry import TelemetryAggregator
        telem = serve_step_mod.collect_state_telemetry(state)
        if telem is not None:
            agg = TelemetryAggregator(telem.num_nodes,
                                      max_tenants=telem.max_tenants)
            agg.update(telem)
            print(agg.describe())
            if args.tenants > 1:
                served = np.asarray(telem.tenant_served).sum(0)
                spilled = np.asarray(telem.tenant_spilled).sum(0)
                for t in range(args.tenants):
                    print(f"tenant {t}: served={int(served[t])} pages "
                          f"spilled={int(spilled[t])}")
            # The closed loop's pipeline-depth pick from measured occupancy
            # (what --channels should be next run).
            cp = ControlPlane(telem.num_nodes, 1, 1)
            page_bytes = (args.page_tokens * cfg.num_kv_heads * cfg.head_dim
                          * jnp.dtype(cfg.dtype).itemsize)
            pick = cp.select_channels(run.bridge.epoch_budget, page_bytes,
                                      telemetry=agg)
            print(f"control plane channels pick: {pick} "
                  f"(running with {args.channels})")
            if registry is not None:
                registry.observe_telemetry(telem)
                registry.observe_aggregator(agg)
    if registry is not None:
        print("metrics:")
        for line in registry.to_text().splitlines():
            print(" ", line)
        if args.trace_out:
            recorder.write(args.trace_out)
            print(f"trace: {args.trace_out} "
                  f"({len(recorder.spans)} spans; open at "
                  f"https://ui.perfetto.dev)")


def _traffic_mode(run, cfg, params, args) -> None:
    """Request-level serving over the real jitted decode step."""
    from repro.core.control_plane import ControlPlane
    from repro.orchestrator import Orchestrator, TenantSpec
    from repro.serve.batcher import (ContinuousBatcher, ModelDecodeEngine,
                                     serve_loop)
    from repro.serve.traffic import TenantTraffic, TrafficGenerator

    slots = args.batch
    pages_per_seq = -(-args.max_len // args.page_tokens)
    # Pool sized for the slot count (plus headroom so admission, not raw
    # capacity, is the governing control).
    cp = ControlPlane(4, slots * pages_per_seq,
                      num_logical=4 * slots * pages_per_seq,
                      seed=args.traffic_seed)
    orc = Orchestrator(cp, budget=run.bridge.epoch_budget,
                       control_period=4, migrate=False)
    orc.register(TenantSpec(1, "chat", qos="interactive", share=3.0))
    orc.register(TenantSpec(2, "crawl", qos="batch", share=1.0))
    batcher = ContinuousBatcher(orc, num_slots=slots,
                                page_tokens=args.page_tokens,
                                policy=args.policy)
    engine = ModelDecodeEngine(run, params, batch=slots,
                               max_len=args.max_len, mesh=None,
                               page_tokens=args.page_tokens,
                               dtype=jnp.dtype(cfg.dtype))
    # Lengths cap: a sequence's prompt + output must fit max_len.
    pmax = max(args.max_len // 2, 2)
    omax = max(args.max_len - pmax, 1)
    traffic = TrafficGenerator([
        TenantTraffic(1, rate=args.traffic_rate, prompt_mean=pmax // 4 or 1,
                      output_mean=omax // 4 or 1, prompt_max=pmax,
                      output_max=omax, vocab=cfg.vocab_size),
        TenantTraffic(2, rate=args.traffic_rate,
                      prompt_mean=pmax // 2 or 1, output_mean=omax // 2 or 1,
                      prompt_max=pmax, output_max=omax,
                      vocab=cfg.vocab_size),
    ], seed=args.traffic_seed)

    wall = MonotonicClock()
    t0 = wall.now_us()
    result = serve_loop(batcher, engine, traffic, steps=args.traffic_steps)
    dt = (wall.now_us() - t0) / 1e6
    print(f"arch={cfg.name} kv={args.kv} slots={slots} "
          f"policy={args.policy}")
    print(batcher.describe())
    print(f"{result['completed']}/{result['submitted']} requests, "
          f"{result['tokens']} tokens in {result['steps']} decode steps "
          f"({dt:.1f}s wall, {result['tokens']/dt:.1f} tokens/s)")
    for qos, lat in batcher.registry.family_quantiles(
            "serve_request_steps").items():
        print(f"  {qos}: {lat['count']} requests, round latency p50="
              f"{lat['p50']:.0f} p99={lat['p99']:.0f} steps")
    print(orc.admission.describe())
    if args.debug_bundle:
        path = orc.dump_debug_bundle(args.debug_bundle,
                                     trace=batcher.recorder)
        print(f"debug bundle: {path} "
              f"({len(orc.flight)} decision records)")


if __name__ == "__main__":
    main()
