import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  * proof the distribution config is coherent (compile succeeds),
  * ``memory_analysis()``  — bytes per device (fits-in-HBM evidence),
  * ``cost_analysis()``    — XLA's flop/byte counts (per-while-body-once),
  * trip-count-corrected FLOPs / HBM bytes / collective bytes from the
    HLO-text analyzer (benchmarks/hlo_analysis.py),
  * the derived three-term roofline (compute / memory / collective seconds).

Results are cached as JSON under results/dryrun/ — one file per cell — so
the full sweep is resumable and the roofline table is assembled offline.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--kv bridge_pull]
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.core import bridge  # noqa: E402
from repro.config import (SHAPES, BridgeConfig, RunConfig,  # noqa: E402
                          ShardingConfig)
from repro.data.pipeline import make_batch_specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.obs.trace import CAT_COMPILE, TraceRecorder  # noqa: E402
from repro.models import transformer  # noqa: E402
from repro.parallel.sharding import make_rules  # noqa: E402
from repro.serve import step as serve_step_mod  # noqa: E402
from repro.train import step as train_step_mod  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parents[3]
sys.path.insert(0, str(REPO))
from benchmarks import hlo_analysis  # noqa: E402

RESULTS = REPO / "results" / "dryrun"

# TPU v5e-class constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (per direction)

PAGE_TOKENS = 512


def cell_skip_reason(arch: str, shape_name: str) -> str | None:
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return "skip(full-attn): 500k decode needs bounded per-token state"
    if shape.is_decode and cfg.num_layers == 0:
        return "skip(encoder-only)"
    return None


def default_kv_placement(arch: str) -> str:
    cfg = configs.get_config(arch)
    kinds = set(cfg.layers)
    if kinds <= {"rglru", "mlstm", "slstm", "swa"}:
        return "local"       # bounded state everywhere: ring/recurrent
    return "bridge_pull"     # paper-faithful baseline


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               kv_placement: str | None = None,
               bridge_budget: int = 8, edge_buffer: bool = True,
               bridge_channels: int = 1, bridge_fused: bool = True,
               microbatch: int = 1, replicate_kv_inner: bool = False,
               scan_decode: bool = True):
    """Returns (lowered, meta) for one cell."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    kv = kv_placement or default_kv_placement(arch)
    run = RunConfig(
        model=cfg, shape=shape,
        bridge=BridgeConfig(epoch_budget=bridge_budget,
                            edge_buffer=edge_buffer,
                            channels=bridge_channels,
                            fused=bridge_fused),
        kv_placement=kv, microbatch=microbatch, scan_layers=scan_decode)
    rules = make_rules(run.sharding, mesh, seq_len=shape.seq_len,
                       global_batch=shape.global_batch,
                       head_dim=0 if replicate_kv_inner else cfg.head_dim,
                       kv_heads=cfg.num_kv_heads,
                       num_heads=cfg.num_heads)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kv_placement": kv if shape.is_decode else None,
            "mode": shape.mode}

    params_abs = transformer.abstract_params(cfg)
    p_shard = jax.tree.map(
        lambda a: NamedSharding(mesh, rules.spec(*a)),
        transformer.params_logical_axes(cfg),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(i, str) or i is None for i in x))

    if shape.mode == "train":
        state_abs = train_step_mod.abstract_train_state(run)
        s_shard = train_step_mod.train_state_shardings(run, mesh, rules)
        batch_abs = make_batch_specs(cfg, shape)
        b_shard = train_step_mod.batch_shardings(run, mesh, rules)
        step = train_step_mod.build_train_step(run, mesh, rules)
        with bridge.use_mesh(mesh):
            lowered = jax.jit(
                step, in_shardings=(s_shard, b_shard),
                donate_argnums=(0,)).lower(state_abs, batch_abs)
        return lowered, meta

    if shape.mode == "prefill":
        batch_abs = make_batch_specs(cfg, shape)
        batch_abs.pop("labels")
        b_shard = train_step_mod.batch_shardings(run, mesh, rules)
        b_shard.pop("labels")

        def prefill(params, batch):
            logits, _ = transformer.forward(cfg, params, batch, run.remat)
            # serving prefill emits only the last position's logits
            return logits[:, -1, :]

        with bridge.use_mesh(mesh):
            lowered = jax.jit(
                prefill, in_shardings=(p_shard, b_shard)).lower(
                    params_abs, batch_abs)
        return lowered, meta

    # decode
    b = shape.global_batch
    cache_ops = serve_step_mod.make_cache_ops(
        run, mesh, max_len=shape.seq_len, page_tokens=PAGE_TOKENS)
    enc_len = 3000 if cfg.cross_attention else 0
    state_abs = serve_step_mod.abstract_serve_state(run, b, cache_ops,
                                                    enc_len=enc_len)
    s_shard = serve_step_mod.decode_state_shardings(run, mesh, rules,
                                                    state_abs)
    step = serve_step_mod.build_serve_step(run, cache_ops)
    tok_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    tok_shard = NamedSharding(mesh, P())
    with bridge.use_mesh(mesh):
        lowered = jax.jit(
            step, in_shardings=(p_shard, s_shard, tok_shard),
            donate_argnums=(1,)).lower(params_abs, state_abs, tok_abs)
    return lowered, meta


def roofline_terms(stats: hlo_analysis.HloStats, num_chips: int,
                   cfg, shape) -> dict:
    """Three-term roofline from the trip-count-corrected HLO stats.

    The compiled module is the SPMD *partitioned* program, so the analyzer's
    FLOPs/bytes are already **per device**; each term divides by one chip's
    peak.  collective_s conservatively assumes one ICI link per transfer.
    """
    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.hbm_bytes / HBM_BW
    collective_s = stats.collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    n_params = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_params * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_params * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_params * tokens
    model_flops_per_device = model_flops / num_chips
    return {
        **terms,
        "dominant": dom,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops_per_device / stats.flops
                               if stats.flops else 0.0),
        "roofline_fraction": (terms["compute_s"] / max(sum(terms.values()),
                                                       1e-30)),
        "step_time_bound_s": max(terms.values()),
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             kv_placement: str | None = None, tag: str = "",
             bridge_budget: int = 8, edge_buffer: bool = True,
             bridge_channels: int = 1, bridge_fused: bool = True,
             microbatch: int = 1, replicate_kv_inner: bool = False,
             scan_decode: bool = True, force: bool = False,
             recorder: TraceRecorder | None = None) -> dict:
    RESULTS.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2pod" if multi_pod else "1pod"
    kv_tag = f"_{kv_placement}" if kv_placement else ""
    name = f"{arch}_{shape_name}_{mesh_tag}{kv_tag}{('_' + tag) if tag else ''}"
    out_path = RESULTS / f"{name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    record: dict = {"cell": name}
    skip = cell_skip_reason(arch, shape_name)
    if skip:
        record.update({"status": skip})
        out_path.write_text(json.dumps(record, indent=1))
        return record

    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    num_chips = 512 if multi_pod else 256
    # Phase timing rides the shared observability clock (monotonic
    # perf_counter, injectable for tests) as proper spans instead of ad-hoc
    # ``time.time()`` deltas; lower_s/compile_s stay in the record for
    # compatibility and the spans land in the cell's trace.
    rec = recorder if recorder is not None else TraceRecorder(
        process_name=f"dryrun:{name}")
    try:
        with rec.span(f"cell:{name}", CAT_COMPILE, cell=name):
            with rec.span("lower", CAT_COMPILE, cell=name) as sp_lower:
                lowered, meta = build_cell(
                    arch, shape_name, multi_pod=multi_pod,
                    kv_placement=kv_placement,
                    bridge_budget=bridge_budget,
                    edge_buffer=edge_buffer,
                    bridge_channels=bridge_channels,
                    bridge_fused=bridge_fused,
                    microbatch=microbatch,
                    replicate_kv_inner=replicate_kv_inner,
                    scan_decode=scan_decode)
            with rec.span("compile", CAT_COMPILE, cell=name) as sp_compile:
                compiled = lowered.compile()
        t_lower = sp_lower.duration_us / 1e6
        t_compile = sp_compile.duration_us / 1e6
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        stats = hlo_analysis.analyze_compiled(compiled)
        record.update(meta)
        record.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
                # buffer-assignment peak of one SPMD partition = HBM high
                # water mark per chip (the fits-in-16GiB evidence)
                "peak_bytes_per_device": getattr(
                    mem, "peak_memory_in_bytes", 0),
            },
            "xla_cost": {"flops": cost.get("flops", 0.0),
                         "bytes_accessed": cost.get("bytes accessed", 0.0)},
            "hlo": stats.as_dict(),
            "roofline": roofline_terms(stats, num_chips, cfg, shape),
            "num_chips": num_chips,
        })
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        record.update({"status": "FAIL",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]})
    out_path.write_text(json.dumps(record, indent=1))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv", default=None,
                    choices=[None, "local", "ring", "bridge_pull",
                             "bridge_push"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--replicate-kv-inner", action="store_true")
    ap.add_argument("--no-scan-decode", action="store_true",
                    help="unroll decode layers (no pool slice/copy)")
    ap.add_argument("--no-edge-buffer", action="store_true")
    ap.add_argument("--channels", type=int, default=1,
                    help="pipelined bridge round-engine depth (1=serial)")
    ap.add_argument("--no-fused", action="store_true",
                    help="unfused ppermute-chain bridge engines (escape "
                         "hatch; fused Pallas datapath is the default)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = configs.lm_archs() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_name, multi_pod=mp,
                               kv_placement=args.kv, tag=args.tag,
                               bridge_budget=args.budget,
                               edge_buffer=not args.no_edge_buffer,
                               bridge_channels=args.channels,
                               bridge_fused=not args.no_fused,
                               microbatch=args.microbatch,
                               replicate_kv_inner=args.replicate_kv_inner,
                               scan_decode=not args.no_scan_decode,
                               force=args.force)
                status = rec.get("status", "?")
                dom = rec.get("roofline", {}).get("dominant", "")
                peak = rec.get("memory", {}).get("peak_bytes_per_device", 0)
                print(f"{rec['cell']:<60s} {status:<12s} "
                      f"{dom:<14s} peak/dev={peak/2**30:.2f}GiB"
                      if status == "ok" else
                      f"{rec['cell']:<60s} {status}",
                      flush=True)
                if status == "FAIL":
                    failures += 1
                    print(rec.get("error", ""), flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
