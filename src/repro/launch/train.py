"""Training launcher: real steps on the local device(s).

On this CPU container it runs reduced configs end-to-end (the full configs
are exercised by launch/dryrun.py); on a real pod the same driver binds the
production mesh.  Composes: config registry -> data pipeline -> train step
-> checkpointing -> elastic/straggler hooks.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b \
      --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro import configs
from repro.obs.clock import MonotonicClock
from repro.checkpoint import CheckpointManager
from repro.config import OptimConfig, RunConfig, ShapeConfig
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.train import step as train_step_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape,
                    optim=OptimConfig(lr=args.lr, warmup_steps=10,
                                      total_steps=max(args.steps, 2)),
                    microbatch=args.microbatch)

    state = train_step_mod.make_train_state(run, jax.random.key(run.seed))
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        state.params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, extra = ckpt.restore(state)
        start = int(extra.get("step", 0))
        print(f"resumed from step {start}")

    step_fn = jax.jit(train_step_mod.build_train_step(run),
                      donate_argnums=(0,))
    data = SyntheticLM(cfg, args.batch, args.seq, seed=run.seed)
    it = Prefetcher(data.iterate(start), depth=2)

    wall = MonotonicClock()
    t0 = wall.now_us()
    for i in range(start, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            dt = (wall.now_us() - t0) / 1e6 / max(i + 1 - start, 1)
            print(f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f} ms/step",
                  flush=True)
        if ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, state, extra={"step": i + 1})
    it.close()
    if ckpt:
        ckpt.save(args.steps, state, extra={"step": args.steps})
        print(f"checkpointed at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
