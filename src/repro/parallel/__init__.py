from repro.parallel.pipeline import pipeline_apply  # noqa: F401
from repro.parallel.sharding import (  # noqa: F401
    ShardingRules,
    logical_to_physical,
    make_rules,
    shard_constraint,
)
