"""GPipe-style pipeline parallelism over a mesh axis.

The pipeline is one more circuit on the pod fabric: stage s holds its
layer block's parameters; activations travel stage -> stage+1 over a static
``ppermute`` route (the same circuit-epoch primitive as the bridge), and
microbatches fill the pipe GPipe-fashion: at tick t, stage s processes
microbatch t - s, for M + S - 1 ticks.

Differentiable end-to-end: the schedule is plain traced JAX (scan over
ticks inside a partial-manual shard_map over the stage axis), so jax.grad
drives the backward pipe in reverse automatically.

Usage (see tests/distributed/run_pipeline_8dev.py):

    y = pipeline_apply(stage_fn, params_staged, x_mb, mesh=mesh,
                       stage_axis="stage")

  * ``stage_fn(stage_params, x) -> x`` applies ONE stage's layers;
  * ``params_staged`` leaves have a leading [num_stages] dim (sharded over
    the stage axis);
  * ``x_mb``: [num_micro, mb, ...] microbatched input (replicated);
  * returns [num_micro, mb, ...] pipeline output (replicated).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import bridge


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   params_staged: Any, x_mb: jax.Array, *, mesh: Mesh,
                   stage_axis: str = "stage") -> jax.Array:
    """Run the GPipe schedule; see module docstring."""
    s = mesh.shape[stage_axis]
    m = x_mb.shape[0]
    fwd = [(j, (j + 1) % s) for j in range(s)]

    def body(params_local, x_local):
        # params_local: [1, ...] leaves (this stage); x_local: [M, mb, ...]
        my = jax.lax.axis_index(stage_axis)
        p_mine = jax.tree.map(lambda a: a[0], params_local)
        ticks = m + s - 1

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t; others use the incoming buffer
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jnp.where((my == 0) & (t < m), 1.0, 0.0)
            x_in = inject * x_local[mb_idx] + (1.0 - inject) * buf
            y = stage_fn(p_mine, x_in)
            # last stage banks finished microbatch t - (S - 1)
            done_idx = jnp.clip(t - (s - 1), 0, m - 1)
            bank = (my == s - 1) & (t - (s - 1) >= 0)
            cur = outs[done_idx]
            outs = outs.at[done_idx].set(jnp.where(bank, y, cur))
            # circuit epoch: activations advance one stage
            buf_next = jax.lax.ppermute(y, stage_axis, perm=fwd)
            return (buf_next, outs), None

        buf0 = bridge._pvary(jnp.zeros_like(x_local[0]), stage_axis)
        outs0 = bridge._pvary(jnp.zeros_like(x_local), stage_axis)
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks))
        # replicate the last stage's banked outputs to every stage
        outs = jax.lax.psum(
            jnp.where(my == s - 1, outs, jnp.zeros_like(outs)), stage_axis)
        return outs

    staged_spec = jax.tree.map(
        lambda _: P(stage_axis), params_staged,
        is_leaf=lambda x: hasattr(x, "shape"))
    return bridge.shard_map(
        body, mesh,
        in_specs=(staged_spec, P()), out_specs=P(),
        mem_axis=stage_axis,
    )(params_staged, x_mb)


def split_microbatches(x: jax.Array, num_micro: int) -> jax.Array:
    b = x.shape[0]
    assert b % num_micro == 0, (b, num_micro)
    return x.reshape(num_micro, b // num_micro, *x.shape[1:])


def merge_microbatches(x_mb: jax.Array) -> jax.Array:
    return x_mb.reshape(-1, *x_mb.shape[2:])
