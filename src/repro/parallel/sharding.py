"""Logical-axis sharding rules (MaxText-style) for the repro framework.

Model code annotates tensors with *logical* axis names ("batch", "seq",
"d_model", "heads", "kv_heads", "ff", "vocab", "experts", "layers", "pages",
...).  :func:`make_rules` binds those names to mesh axes according to the
:class:`repro.config.ShardingConfig`, and :func:`shard_constraint` applies a
``with_sharding_constraint`` only for axes that exist on the current mesh —
the same model code runs on a single CPU device, an 8-device test mesh, a
(16,16) pod and a (2,16,16) multi-pod mesh without edits.
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ShardingConfig

Rule = Optional[tuple[str, ...]]  # mesh axes for one logical axis (None = replicate)


class ShardingRules(dict):
    """Mapping: logical axis name -> tuple of mesh axis names (or None)."""

    def spec(self, *logical_axes: Optional[str]) -> P:
        parts = []
        for ax in logical_axes:
            if ax is None:
                parts.append(None)
            else:
                axes = self.get(ax)
                parts.append(axes if axes else None)
        return P(*parts)


def make_rules(cfg: ShardingConfig, mesh: Mesh, *, seq_len: int = 0,
               global_batch: int = 0, head_dim: int = 0,
               kv_heads: int = 0, num_heads: int = 0) -> ShardingRules:
    """Bind logical axes to the axes that actually exist on ``mesh``.

    Divisibility-aware: batch axes are dropped when the global batch does
    not divide by them (decode with tiny batches); the KV-cache inner dim
    binds head_dim or kv_heads to the model axis only when divisible.
    """
    present = set(mesh.axis_names)

    def only(axes: Sequence[str]) -> Optional[tuple[str, ...]]:
        kept = tuple(a for a in axes if a in present and mesh.shape[a] > 1)
        return kept or None

    batch = only(cfg.batch_axes)
    if batch and global_batch:
        n = 1
        for a in batch:
            n *= mesh.shape[a]
        if global_batch % n != 0:
            # try dropping outer axes until divisible
            while batch and global_batch % n != 0:
                n //= mesh.shape[batch[0]]
                batch = batch[1:] or None
                if batch is None:
                    break
    model = only((cfg.model_axis,))
    model_size = mesh.shape[cfg.model_axis] if model else 1
    shard_seq = seq_len >= cfg.shard_seq_threshold
    rules = ShardingRules(
        batch=batch,
        seq=only((cfg.seq_axis,)) if shard_seq else None,
        one=None,
        d_model=None,
        heads=model,
        kv_heads=model if (kv_heads and kv_heads % model_size == 0) else None,
        head_dim=model if (head_dim and head_dim % model_size == 0) else None,
        state_heads=model if (num_heads and num_heads % model_size == 0)
        else None,
        ff=model,
        vocab=model,
        experts=only((cfg.expert_axis,)),
        expert_cap=None,
        layers=None,
        # bridge / pooled memory axes
        pages=only((cfg.kv_pages_axis,)),
        kv_seq=only((cfg.kv_pages_axis,)),
        zero=only((cfg.zero_axis,)) if cfg.enable_zero else None,
    )
    return rules


def logical_to_physical(rules: ShardingRules, mesh: Mesh,
                        *logical_axes: Optional[str]) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(*logical_axes))


def shard_constraint(x: jax.Array, rules: ShardingRules,
                     *logical_axes: Optional[str]) -> jax.Array:
    """``with_sharding_constraint`` via logical names; no-op off-mesh."""
    spec = rules.spec(*logical_axes)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        # Outside a mesh context (plain CPU tests) constraints are identity.
        return x


def tree_shardings(rules: ShardingRules, mesh: Mesh, logical_tree):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda axes: logical_to_physical(rules, mesh, *axes),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, str) or a is None for a in x),
    )
