"""In-band datapath counters for the bridge (the measurement plane).

The paper's control plane "prepares and steers" transactions at runtime but
the prototype measures nothing in-band; real disaggregated orchestration
needs link-level telemetry feeding allocation and routing.  This module is
the datapath half of that loop: a :class:`BridgeTelemetry` pytree of masked
integer sums computed from the very masks the transfer engine already
materializes (request liveness, rate-limiter window, ring distance, route
program liveness), so collecting it

* costs only a handful of masked ``segment-sum`` reductions,
* has **static shapes** (fixed ``N-1`` slot / ``N`` node axes), so swapping
  programs, tables or budgets with collection on never retraces,
* is bit-deterministic (pure integer arithmetic, no atomics), identical
  between ``edge_buffer`` modes, and exactly reproducible by the oracle
  (:func:`repro.core.ref.expected_transfer_telemetry`).

Counter semantics for one requester's (padded) request list:

* a request is **live** if its id is non-FREE and its page is mapped;
* live requests past the rate-limiter window (``rounds * active_budget``
  round lanes) are **spilled** (the software rate limiter dropped them);
* in-window live requests at ring distance 0 are **loopback** hits;
* remote requests whose distance has no wired circuit are **pruned** drops;
* everything else is **served** by its circuit slot, contributing to the
  per-slot counts, the requester->home traffic-matrix row, and the per-epoch
  cw/ccw wire occupancy (direction = sign of the program's slot offset).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.memport import MemPortTable
from repro.core.steering import RouteProgram


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BridgeTelemetry:
    """Per-requester bridge counters (one transfer's worth).

    All leaves are ``i32`` with static trailing shapes for an N-node ring
    (``N-1`` circuit slots, ``N`` homes); leading dims identify the
    requester (``[N, ...]`` from the N-device path, ``[rows, ...]`` from the
    loopback path).  Counts are pages; bytes are ``count * page_bytes`` with
    a static page size, so only counts are carried on device.

    Attributes:
      slot_served:      pages served per circuit slot (slot k = distance k+1).
      loopback_served:  distance-0 fast-path hits (no circuit traffic).
      spilled:          live requests dropped by the rate limiter.
      pruned:           live requests dropped because their ring distance has
                        no wired circuit in the route program.
      traffic:          requester->home served pages (one traffic-matrix row,
                        loopback included on the diagonal).
      epoch_cw:         clockwise wire occupancy (pages) per circuit epoch.
      epoch_ccw:        counter-clockwise wire occupancy per circuit epoch.
    """

    slot_served: jax.Array      # i32[..., N-1]
    loopback_served: jax.Array  # i32[...]
    spilled: jax.Array          # i32[...]
    pruned: jax.Array           # i32[...]
    traffic: jax.Array          # i32[..., N]
    epoch_cw: jax.Array         # i32[..., N-1]
    epoch_ccw: jax.Array        # i32[..., N-1]

    @property
    def num_nodes(self) -> int:
        return self.traffic.shape[-1]

    def served_total(self) -> jax.Array:
        """Pages served per requester (loopback + all circuit slots)."""
        return self.loopback_served + self.slot_served.sum(-1)

    def wire_pages(self) -> tuple[jax.Array, jax.Array]:
        """(cw, ccw) pages moved over each ring direction per requester."""
        return self.epoch_cw.sum(-1), self.epoch_ccw.sum(-1)

    def slot_bytes(self, page_bytes: int) -> jax.Array:
        """Per-slot wire bytes (static page size x served counts)."""
        return self.slot_served * page_bytes


def zeros(num_nodes: int, leading: tuple[int, ...] = ()) -> BridgeTelemetry:
    """All-zero telemetry for an N-node ring (accumulator seed)."""
    s = max(num_nodes - 1, 0)
    z = lambda *shape: jnp.zeros(leading + shape, jnp.int32)  # noqa: E731
    return BridgeTelemetry(slot_served=z(s), loopback_served=z(),
                           spilled=z(), pruned=z(), traffic=z(num_nodes),
                           epoch_cw=z(s), epoch_ccw=z(s))


def add(a: BridgeTelemetry, b: BridgeTelemetry) -> BridgeTelemetry:
    """Element-wise sum (counters are additive across transfers/steps)."""
    return jax.tree.map(jnp.add, a, b)


def transfer_telemetry(ids: jax.Array, table: MemPortTable,
                       program: RouteProgram, active_budget: jax.Array, *,
                       my, num_nodes: int, budget: int,
                       rounds: int) -> BridgeTelemetry:
    """Counters for one requester's padded request list (pull or push).

    Pure jnp — runs inside the ``shard_map`` body (``my`` = axis index) and,
    vmapped over logical requesters, on the 1-device loopback path.  The
    masks recompute exactly the datapath's serve conditions, so the counts
    are what the transfer engine actually moved.

    Args:
      ids: [rounds * budget] request ids (FREE-padded).
      active_budget: live lanes per round (the runtime rate limiter).
      my: this requester's ring rank (traced or static).
      rounds: static round count the transfer was compiled for.
    """
    ids = ids.reshape(-1)
    home, _ = table.translate(ids)
    live = (ids >= 0) & (home >= 0)
    ab = jnp.clip(jnp.asarray(active_budget), 0, budget)
    in_window = jnp.arange(ids.shape[0]) < rounds * ab
    spilled = jnp.sum(live & ~in_window).astype(jnp.int32)

    cand = live & in_window
    dist = jnp.mod(home - my, num_nodes)
    is_loop = cand & (dist == 0)
    loopback_served = jnp.sum(is_loop).astype(jnp.int32)

    nslots = num_nodes - 1
    if nslots == 0:
        empty = jnp.zeros((0,), jnp.int32)
        traffic = jnp.zeros((num_nodes,), jnp.int32).at[
            jnp.where(is_loop, home, num_nodes)].add(1, mode="drop")
        return BridgeTelemetry(slot_served=empty,
                               loopback_served=loopback_served,
                               spilled=spilled,
                               pruned=jnp.int32(0), traffic=traffic,
                               epoch_cw=empty, epoch_ccw=empty)

    slot = jnp.clip(dist - 1, 0, nslots - 1)
    remote = cand & (dist > 0)
    wired = remote & program.live[slot]
    pruned = jnp.sum(remote & ~program.live[slot]).astype(jnp.int32)
    slot_served = jnp.zeros((nslots,), jnp.int32).at[
        jnp.where(wired, slot, nslots)].add(1, mode="drop")
    served = is_loop | wired
    traffic = jnp.zeros((num_nodes,), jnp.int32).at[
        jnp.where(served, home, num_nodes)].add(1, mode="drop")
    # Wire occupancy: slot k's pages land at its program epoch, on the ring
    # direction its signed offset drives.
    ep = jnp.clip(program.epoch, 0, nslots - 1)
    cw = program.live & (program.offsets > 0)
    ccw = program.live & (program.offsets < 0)
    epoch_cw = jnp.zeros((nslots,), jnp.int32).at[
        jnp.where(cw, ep, nslots)].add(slot_served, mode="drop")
    epoch_ccw = jnp.zeros((nslots,), jnp.int32).at[
        jnp.where(ccw, ep, nslots)].add(slot_served, mode="drop")
    return BridgeTelemetry(slot_served=slot_served,
                           loopback_served=loopback_served, spilled=spilled,
                           pruned=pruned, traffic=traffic,
                           epoch_cw=epoch_cw, epoch_ccw=epoch_ccw)
