"""In-band datapath counters for the bridge (the measurement plane).

The paper's control plane "prepares and steers" transactions at runtime but
the prototype measures nothing in-band; real disaggregated orchestration
needs link-level telemetry feeding allocation and routing.  This module is
the datapath half of that loop: a :class:`BridgeTelemetry` pytree of masked
integer sums computed from the very masks the transfer engine already
materializes (request liveness, rate-limiter window, ring distance, route
program liveness, the program's per-rank group mask), so collecting it

* costs only a handful of masked ``segment-sum`` reductions,
* has **static shapes** (fixed ``N-1`` slot / ``N`` node / ``2(N-1)``
  epoch axes), so swapping programs — flat or hierarchical — tables or
  budgets with collection on never retraces,
* is bit-deterministic (pure integer arithmetic, no atomics), identical
  between ``edge_buffer`` modes, and exactly reproducible by the oracle
  (:func:`repro.core.ref.expected_transfer_telemetry`).

Counter semantics for one requester's (padded) request list:

* a request is **live** if its id is non-FREE and its page is mapped;
* live requests past the rate-limiter window (``rounds * active_budget``
  round lanes) are **spilled** (the software rate limiter dropped them);
* in-window live requests at ring distance 0 are **loopback** hits;
* remote requests whose distance has no wired circuit — or whose
  (rank, slot) pairing the program's group mask cut — are **pruned** drops;
* everything else is **served** by its circuit slot, contributing to the
  per-slot counts, the requester->home traffic-matrix row, the per-epoch
  cw/ccw wire occupancy (direction = sign of the program's slot offset, at
  the epoch the program assigns *this requester*), and the **per-tier**
  occupancy: intra-board pages per slot plus board / rack page-hops under
  the :mod:`repro.core.topology` realization contract.

**Tenant attribution** (the orchestration plane): every request may carry a
tenant id in a parallel lane (``pull_pages`` / ``push_pages``
``tenant_ids=``), and each counter outcome — served, spilled, pruned — is
additionally binned per tenant into static ``[max_tenants]`` histograms.
The lane is a *runtime input* with the same shape as the request list, so
swapping tenant shares / window compositions between steps never retraces;
no lane means every request belongs to tenant 0, which keeps the per-tenant
sums reconciling exactly with the untagged counters in all cases:

    tenant_served.sum(-1) == served_total()
    tenant_spilled.sum(-1) == spilled;  tenant_pruned.sum(-1) == pruned
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.memport import MemPortTable
from repro.core.steering import RouteProgram
from repro.core.topology import TopoTables, pair_hops_device


def num_epoch_bins(num_nodes: int) -> int:
    """Static epoch-histogram length: a hierarchical schedule uses at most
    (G-1) intra epochs + (N-1) gateway epochs <= 2(N-1)."""
    return 2 * max(num_nodes - 1, 0)


#: Default static width of the per-tenant attribution histograms.  Like
#: ``budget`` this is a compile-time knob: deployments expecting more
#: concurrent tenants pass a larger ``max_tenants`` once; *which* tenant
#: owns which request stays a runtime lane value.
DEFAULT_MAX_TENANTS = 4


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BridgeTelemetry:
    """Per-requester bridge counters (one transfer's worth).

    All leaves are ``i32`` with static trailing shapes for an N-node ring
    (``N-1`` circuit slots, ``N`` homes, ``2(N-1)`` epochs); leading dims
    identify the requester (``[N, ...]`` from the N-device path,
    ``[rows, ...]`` from the loopback path).  Counts are pages; bytes are
    ``count * page_bytes`` with a static page size, so only counts are
    carried on device.

    Attributes:
      slot_served:      pages served per circuit slot (slot k = distance k+1).
      loopback_served:  distance-0 fast-path hits (no circuit traffic).
      spilled:          live requests dropped by the rate limiter.
      pruned:           live requests dropped because their ring distance has
                        no wired circuit — or the program's group mask cut
                        their (rank, slot) pairing.
      traffic:          requester->home served pages (one traffic-matrix row,
                        loopback included on the diagonal).
      epoch_cw:         clockwise wire occupancy (pages) per circuit epoch.
      epoch_ccw:        counter-clockwise wire occupancy per circuit epoch.
      slot_intra:       the intra-board share of ``slot_served`` (requester
                        and home on one board; inter = served - intra).
      tier_hops:        [..., 2] page-hops per tier (board, rack) under the
                        topology's path realization — per-tier wire
                        occupancy.
      tenant_served:    pages served per tenant (loopback + circuit; the
                        tenant-id request lane bins them, absent lane = all
                        tenant 0).
      tenant_spilled:   rate-limiter drops per tenant.
      tenant_pruned:    pruned-circuit drops per tenant.
    """

    slot_served: jax.Array      # i32[..., N-1]
    loopback_served: jax.Array  # i32[...]
    spilled: jax.Array          # i32[...]
    pruned: jax.Array           # i32[...]
    traffic: jax.Array          # i32[..., N]
    epoch_cw: jax.Array         # i32[..., 2(N-1)]
    epoch_ccw: jax.Array        # i32[..., 2(N-1)]
    slot_intra: jax.Array       # i32[..., N-1]
    tier_hops: jax.Array        # i32[..., 2]
    tenant_served: jax.Array    # i32[..., max_tenants]
    tenant_spilled: jax.Array   # i32[..., max_tenants]
    tenant_pruned: jax.Array    # i32[..., max_tenants]

    @property
    def num_nodes(self) -> int:
        return self.traffic.shape[-1]

    @property
    def max_tenants(self) -> int:
        return self.tenant_served.shape[-1]

    def served_total(self) -> jax.Array:
        """Pages served per requester (loopback + all circuit slots)."""
        return self.loopback_served + self.slot_served.sum(-1)

    def wire_pages(self) -> tuple[jax.Array, jax.Array]:
        """(cw, ccw) pages moved over each ring direction per requester."""
        return self.epoch_cw.sum(-1), self.epoch_ccw.sum(-1)

    def slot_bytes(self, page_bytes: int) -> jax.Array:
        """Per-slot wire bytes (static page size x served counts)."""
        return self.slot_served * page_bytes

    def tier_pages(self) -> tuple[jax.Array, jax.Array]:
        """(intra-board, inter-board) circuit pages per requester."""
        intra = self.slot_intra.sum(-1)
        return intra, self.slot_served.sum(-1) - intra

    def tenant_bytes(self, page_bytes: int) -> jax.Array:
        """Per-tenant wire+loopback bytes (static page size x served)."""
        return self.tenant_served * page_bytes


def zeros(num_nodes: int, leading: tuple[int, ...] = (),
          max_tenants: int = DEFAULT_MAX_TENANTS) -> BridgeTelemetry:
    """All-zero telemetry for an N-node ring (accumulator seed)."""
    s = max(num_nodes - 1, 0)
    e = num_epoch_bins(num_nodes)
    z = lambda *shape: jnp.zeros(leading + shape, jnp.int32)  # noqa: E731
    return BridgeTelemetry(slot_served=z(s), loopback_served=z(),
                           spilled=z(), pruned=z(), traffic=z(num_nodes),
                           epoch_cw=z(e), epoch_ccw=z(e), slot_intra=z(s),
                           tier_hops=z(2), tenant_served=z(max_tenants),
                           tenant_spilled=z(max_tenants),
                           tenant_pruned=z(max_tenants))


def add(a: BridgeTelemetry, b: BridgeTelemetry) -> BridgeTelemetry:
    """Element-wise sum (counters are additive across transfers/steps)."""
    return jax.tree.map(jnp.add, a, b)


def _tenant_bins(tenant: jax.Array, mask: jax.Array,
                 max_tenants: int) -> jax.Array:
    """i32[max_tenants]: count of ``mask`` requests per (clipped) tenant."""
    return jnp.zeros((max_tenants,), jnp.int32).at[
        jnp.where(mask, tenant, max_tenants)].add(1, mode="drop")


def transfer_telemetry(ids: jax.Array, table: MemPortTable,
                       program: RouteProgram, active_budget: jax.Array, *,
                       my, num_nodes: int, budget: int, rounds: int,
                       topo: TopoTables, num_groups: int,
                       tenant_ids: Optional[jax.Array] = None,
                       max_tenants: int = DEFAULT_MAX_TENANTS
                       ) -> BridgeTelemetry:
    """Counters for one requester's padded request list (pull or push).

    Pure jnp — runs inside the ``shard_map`` body (``my`` = axis index) and,
    vmapped over logical requesters, on the 1-device loopback path.  The
    masks recompute exactly the datapath's serve conditions, so the counts
    are what the transfer engine actually moved.

    Args:
      ids: [rounds * budget] request ids (FREE-padded).
      active_budget: live lanes per round (the runtime rate limiter).
      my: this requester's ring rank (traced or static).
      rounds: static round count the transfer was compiled for.
      topo: the (static) topology tables classifying each pair's tier and
        hop counts; ``num_groups`` the rack-ring length.
      tenant_ids: [rounds * budget] tenant-id lane aligned with ``ids``
        (None = all tenant 0); ids clip into [0, max_tenants) so every
        counted request is attributed somewhere and the per-tenant sums
        reconcile with the untagged counters.
      max_tenants: static width of the tenant histograms.
    """
    ids = ids.reshape(-1)
    if tenant_ids is None:
        tenant_ids = jnp.zeros_like(ids)
    tenant = jnp.clip(tenant_ids.reshape(-1), 0, max_tenants - 1)
    home, _ = table.translate(ids)
    live = (ids >= 0) & (home >= 0)
    ab = jnp.clip(jnp.asarray(active_budget), 0, budget)
    in_window = jnp.arange(ids.shape[0]) < rounds * ab
    spill_mask = live & ~in_window
    spilled = jnp.sum(spill_mask).astype(jnp.int32)
    tenant_spilled = _tenant_bins(tenant, spill_mask, max_tenants)

    cand = live & in_window
    dist = jnp.mod(home - my, num_nodes)
    is_loop = cand & (dist == 0)
    loopback_served = jnp.sum(is_loop).astype(jnp.int32)

    nslots = num_nodes - 1
    if nslots == 0:
        empty = jnp.zeros((0,), jnp.int32)
        traffic = jnp.zeros((num_nodes,), jnp.int32).at[
            jnp.where(is_loop, home, num_nodes)].add(1, mode="drop")
        return BridgeTelemetry(slot_served=empty,
                               loopback_served=loopback_served,
                               spilled=spilled,
                               pruned=jnp.int32(0), traffic=traffic,
                               epoch_cw=empty, epoch_ccw=empty,
                               slot_intra=empty,
                               tier_hops=jnp.zeros((2,), jnp.int32),
                               tenant_served=_tenant_bins(
                                   tenant, is_loop, max_tenants),
                               tenant_spilled=tenant_spilled,
                               tenant_pruned=jnp.zeros((max_tenants,),
                                                       jnp.int32))

    slot = jnp.clip(dist - 1, 0, nslots - 1)
    remote = cand & (dist > 0)
    # The serve condition mirrors the datapath: the slot must be live AND
    # the program's group mask must wire it for THIS requester rank.
    rank_wired = program.live & (program.rank_epoch[:, my] >= 0)
    wired = remote & rank_wired[slot]
    prune_mask = remote & ~rank_wired[slot]
    pruned = jnp.sum(prune_mask).astype(jnp.int32)
    slot_served = jnp.zeros((nslots,), jnp.int32).at[
        jnp.where(wired, slot, nslots)].add(1, mode="drop")
    served = is_loop | wired
    traffic = jnp.zeros((num_nodes,), jnp.int32).at[
        jnp.where(served, home, num_nodes)].add(1, mode="drop")
    # Wire occupancy: a served page lands at the epoch the program assigns
    # this requester on its slot, on the ring direction the slot drives.
    nbins = num_epoch_bins(num_nodes)
    ep = jnp.clip(program.rank_epoch[:, my], 0, nbins - 1)
    cw = rank_wired & (program.offsets > 0)
    ccw = rank_wired & (program.offsets < 0)
    epoch_cw = jnp.zeros((nbins,), jnp.int32).at[
        jnp.where(cw, ep, nbins)].add(slot_served, mode="drop")
    epoch_ccw = jnp.zeros((nbins,), jnp.int32).at[
        jnp.where(ccw, ep, nbins)].add(slot_served, mode="drop")
    # Per-tier occupancy under the topology's path realization.
    sign = jnp.sign(program.offsets)[slot]
    intra, board_hops, rack_hops = pair_hops_device(
        topo, num_groups, my, home, sign)
    slot_intra = jnp.zeros((nslots,), jnp.int32).at[
        jnp.where(wired & intra, slot, nslots)].add(1, mode="drop")
    tier_hops = jnp.stack([
        jnp.sum(jnp.where(wired, board_hops, 0)).astype(jnp.int32),
        jnp.sum(jnp.where(wired, rack_hops, 0)).astype(jnp.int32)])
    return BridgeTelemetry(slot_served=slot_served,
                           loopback_served=loopback_served, spilled=spilled,
                           pruned=pruned, traffic=traffic,
                           epoch_cw=epoch_cw, epoch_ccw=epoch_ccw,
                           slot_intra=slot_intra, tier_hops=tier_hops,
                           tenant_served=_tenant_bins(tenant, served,
                                                      max_tenants),
                           tenant_spilled=tenant_spilled,
                           tenant_pruned=_tenant_bins(tenant, prune_mask,
                                                      max_tenants))
