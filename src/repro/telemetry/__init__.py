"""In-band traffic telemetry: the bridge's measurement plane.

  counters   — BridgeTelemetry pytree + masked-sum datapath collection
  aggregate  — host-side EWMA aggregation feeding the control plane

The closed loop:  pull/push(collect_telemetry=True) -> BridgeTelemetry ->
TelemetryAggregator.update -> ControlPlane.route_program(telemetry=...) /
rate_limits(telemetry=...) / affinity_migration -> next step's runtime
inputs (no recompilation at any point).
"""
from repro.telemetry.counters import (BridgeTelemetry, add,  # noqa: F401
                                      transfer_telemetry, zeros)
from repro.telemetry.aggregate import TelemetryAggregator  # noqa: F401
