"""Host-side telemetry aggregation (the measure half of the control loop).

The datapath emits one :class:`~repro.telemetry.counters.BridgeTelemetry`
per transfer; the orchestrator folds them into exponentially-weighted moving
averages here and the control plane reads the aggregate to recompile route
programs, adapt rate limits and plan affinity migrations:

    datapath counters -> TelemetryAggregator -> ControlPlane.route_program /
                                                rate_limits / affinity_migration

Everything is plain numpy on the host — telemetry crosses the device
boundary once per step (a few hundred int32s) and never touches the jitted
datapath.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.telemetry.counters import (BridgeTelemetry, DEFAULT_MAX_TENANTS,
                                      num_epoch_bins)


def dominant_requester(traffic: np.ndarray, home: int) -> tuple[int, float]:
    """(remote requester moving the most pages from ``home``, its share of
    all traffic homed there) for a raw ``[N, N]`` requester->home matrix.
    Share is 0 when the home is idle.  The single definition of "dominant"
    shared by the aggregator and ``ControlPlane.affinity_migration``."""
    col = np.asarray(traffic, float)[:, home].copy()
    total = col.sum()
    col[home] = -1.0
    r = int(col.argmax())
    share = float(traffic[r][home] / total) if total > 0 else 0.0
    return r, share


class TelemetryAggregator:
    """EWMA aggregation of bridge counters across steps.

    Keeps, per step (EWMA with factor ``alpha``; the first update seeds the
    averages directly):

    * the ``[N, N]`` requester->home **traffic matrix** (pages),
    * the per-ring-distance **load histogram** (pages over all requesters),
    * per-direction / per-epoch **wire occupancy** (link utilization),
    * per-node **drop counters**: rate-limiter spills and pruned-circuit
      drops, plus served totals to turn them into rates,
    * per-**tenant** served/spill/prune histograms (summed over requesters)
      — the orchestrator's QoS scheduler re-fits budget shares from the
      measured per-tenant demand.

    ``update`` accepts telemetry whose leading dim is the requester: row i
    is ring node i (N-device path) or logical requester i (loopback path).
    """

    def __init__(self, num_nodes: int, page_bytes: int = 0,
                 alpha: float = 0.25,
                 max_tenants: int = DEFAULT_MAX_TENANTS):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.num_nodes = num_nodes
        self.page_bytes = page_bytes
        self.alpha = alpha
        self.max_tenants = max_tenants
        self.steps = 0
        n, s = num_nodes, max(num_nodes - 1, 0)
        e = num_epoch_bins(n)
        self.traffic = np.zeros((n, n))
        self.dist_pages = np.zeros((s,))
        self.dist_intra = np.zeros((s,))
        self.epoch_cw = np.zeros((e,))
        self.epoch_ccw = np.zeros((e,))
        self.tier_hop_pages = np.zeros((2,))   # (board, rack) page-hops/step
        self.loopback = np.zeros((n,))
        self.served = np.zeros((n,))
        self.spilled = np.zeros((n,))
        self.pruned = np.zeros((n,))
        self.tenant_served = np.zeros((max_tenants,))
        self.tenant_spilled = np.zeros((max_tenants,))
        self.tenant_pruned = np.zeros((max_tenants,))
        # Raw drops of the most recent update (not EWMA-smoothed): the
        # control plane's censorship guard needs "was the LAST measurement
        # clean", which a decaying average can never answer with zero.
        self.last_spilled = np.zeros((n,))
        self.last_pruned = np.zeros((n,))
        # Raw per-tenant counters of the most recent update: the scheduler's
        # work-conserving re-fit keys on the LAST step's demand (served +
        # spilled), which the EWMA would smear across share changes.
        self.last_tenant_served = np.zeros((max_tenants,))
        self.last_tenant_spilled = np.zeros((max_tenants,))

    # -- folding --------------------------------------------------------------
    def _fold(self, avg: np.ndarray, new: np.ndarray) -> None:
        if self.steps == 0:
            avg[...] = new
        else:
            avg *= 1.0 - self.alpha
            avg += self.alpha * new

    def update(self, telem: BridgeTelemetry) -> None:
        """Fold one step's telemetry (leading dim = requester) in."""
        rows = np.atleast_1d(np.asarray(telem.loopback_served)).shape[0]
        if rows > self.num_nodes:
            raise ValueError(f"telemetry has {rows} requester rows for a "
                             f"{self.num_nodes}-node aggregator")

        def rowed(x, trailing):
            out = np.zeros((self.num_nodes,) + trailing)
            out[:rows] = np.asarray(x, np.int64).reshape((rows,) + trailing)
            return out

        n, s = self.num_nodes, max(self.num_nodes - 1, 0)
        e = num_epoch_bins(n)
        traffic = rowed(telem.traffic, (telem.traffic.shape[-1],))
        if traffic.shape[1] != n:
            raise ValueError(f"telemetry spans {traffic.shape[1]} homes for "
                             f"a {n}-node aggregator")
        slot = rowed(telem.slot_served, (s,))
        self._fold(self.traffic, traffic)
        self._fold(self.dist_pages, slot.sum(0))
        self._fold(self.dist_intra, rowed(telem.slot_intra, (s,)).sum(0))
        self._fold(self.epoch_cw, rowed(telem.epoch_cw, (e,)).sum(0))
        self._fold(self.epoch_ccw, rowed(telem.epoch_ccw, (e,)).sum(0))
        self._fold(self.tier_hop_pages, rowed(telem.tier_hops, (2,)).sum(0))
        self._fold(self.loopback, rowed(telem.loopback_served, ()))
        self._fold(self.served,
                   rowed(telem.loopback_served, ()) + slot.sum(1))
        self._fold(self.spilled, rowed(telem.spilled, ()))
        self._fold(self.pruned, rowed(telem.pruned, ()))
        t = telem.tenant_served.shape[-1]
        if t != self.max_tenants:
            raise ValueError(f"telemetry attributes {t} tenants for a "
                             f"max_tenants={self.max_tenants} aggregator")
        ten_served = rowed(telem.tenant_served, (t,)).sum(0)
        ten_spilled = rowed(telem.tenant_spilled, (t,)).sum(0)
        self._fold(self.tenant_served, ten_served)
        self._fold(self.tenant_spilled, ten_spilled)
        self._fold(self.tenant_pruned,
                   rowed(telem.tenant_pruned, (t,)).sum(0))
        self.last_tenant_served = ten_served
        self.last_tenant_spilled = ten_spilled
        self.last_spilled = rowed(telem.spilled, ())
        self.last_pruned = rowed(telem.pruned, ())
        self.steps += 1

    # -- views the control plane consumes -------------------------------------
    def traffic_matrix(self) -> np.ndarray:
        """EWMA requester->home pages per step, [N, N]."""
        return self.traffic.copy()

    def traffic_bytes(self) -> np.ndarray:
        return self.traffic * self.page_bytes

    def distance_pages(self) -> np.ndarray:
        """EWMA pages per step carried at each ring distance, [N-1]."""
        return self.dist_pages.copy()

    def distance_bytes(self) -> np.ndarray:
        return self.dist_pages * self.page_bytes

    def live_distances(self) -> list[int]:
        """Ring distances that measurably carried traffic."""
        return (np.nonzero(self.dist_pages > 0)[0] + 1).tolist()

    def link_pages(self) -> Dict[str, float]:
        """EWMA pages per step moved over each ring direction."""
        return {"cw": float(self.epoch_cw.sum()),
                "ccw": float(self.epoch_ccw.sum())}

    def link_utilization(self) -> Dict[str, float]:
        """Each direction's share of circuit-wire pages (0 when idle)."""
        lp = self.link_pages()
        total = lp["cw"] + lp["ccw"]
        if total <= 0:
            return {"cw": 0.0, "ccw": 0.0}
        return {k: v / total for k, v in lp.items()}

    def epoch_occupancy(self) -> tuple[np.ndarray, np.ndarray]:
        """(cw, ccw) EWMA wire pages per circuit epoch."""
        return self.epoch_cw.copy(), self.epoch_ccw.copy()

    # -- the hierarchical (board + rack) views --------------------------------
    def distance_intra_pages(self) -> np.ndarray:
        """EWMA intra-board pages per step at each ring distance, [N-1].

        ``distance_pages() - distance_intra_pages()`` is the board-crossing
        share — the split :func:`repro.core.perfmodel.predict_round_latency_us`
        consumes as ``slot_intra_pages``.
        """
        return self.dist_intra.copy()

    def tier_pages(self) -> Dict[str, float]:
        """EWMA circuit pages per step on each fabric tier."""
        intra = float(self.dist_intra.sum())
        return {"board": intra, "rack": float(self.dist_pages.sum()) - intra}

    def tier_hops(self) -> Dict[str, float]:
        """EWMA page-hops per step over each tier's links (wire occupancy)."""
        return {"board": float(self.tier_hop_pages[0]),
                "rack": float(self.tier_hop_pages[1])}

    def tier_utilization(self) -> Dict[str, float]:
        """Each tier's share of page-hops (0 when idle)."""
        th = self.tier_hops()
        total = th["board"] + th["rack"]
        if total <= 0:
            return {"board": 0.0, "rack": 0.0}
        return {k: v / total for k, v in th.items()}

    # -- the multi-tenant views (orchestration plane) --------------------------
    def tenant_pages(self) -> np.ndarray:
        """EWMA pages served per tenant per step, [max_tenants]."""
        return self.tenant_served.copy()

    def tenant_bytes(self) -> np.ndarray:
        return self.tenant_served * self.page_bytes

    def tenant_demand(self) -> np.ndarray:
        """LAST step's offered load per tenant (served + spilled pages).

        Raw, not EWMA: the scheduler's work-conserving re-fit needs the
        demand under the *current* share split — a smoothed average would
        keep crediting a tenant for traffic it stopped offering.
        """
        return self.last_tenant_served + self.last_tenant_spilled

    def tenant_spill_rate(self) -> np.ndarray:
        """Per-tenant fraction of offered pages the rate limiter dropped."""
        total = self.tenant_served + self.tenant_spilled
        return np.divide(self.tenant_spilled, total,
                         out=np.zeros_like(total), where=total > 0)

    def spill_rate(self) -> np.ndarray:
        """Per-node fraction of live requests the rate limiter dropped."""
        total = self.served + self.spilled
        return np.divide(self.spilled, total, out=np.zeros_like(total),
                         where=total > 0)

    def drop_rate(self) -> np.ndarray:
        """Per-node fraction of live requests dropped (spill + prune)."""
        drops = self.spilled + self.pruned
        total = self.served + drops
        return np.divide(drops, total, out=np.zeros_like(drops),
                         where=total > 0)

    def dominant_requester(self, home: int) -> tuple[int, float]:
        """(remote requester moving the most pages from ``home``, its share
        of all traffic homed there).  Share is 0 when the home is idle."""
        return dominant_requester(self.traffic, home)

    def describe(self) -> str:
        util = self.link_utilization()
        tier = self.tier_utilization()
        lines = [f"telemetry: {self.steps} steps folded "
                 f"(alpha={self.alpha}, page_bytes={self.page_bytes})",
                 f"  wire share: cw={util['cw']:.2f} ccw={util['ccw']:.2f}",
                 f"  tier share: board={tier['board']:.2f} "
                 f"rack={tier['rack']:.2f}",
                 "  dist pages: " + " ".join(
                     f"d{d}={p:.1f}" for d, p in
                     enumerate(self.dist_pages, start=1) if p > 0)]
        if self.tenant_served.sum() + self.tenant_spilled.sum() > 0:
            lines.append("  tenants: " + " ".join(
                f"t{t}={s:.1f}/{sp:.1f}sp" for t, (s, sp) in
                enumerate(zip(self.tenant_served, self.tenant_spilled))
                if s + sp > 0))
        for i in range(self.num_nodes):
            lines.append(
                f"  node {i}: served={self.served[i]:.1f} "
                f"loopback={self.loopback[i]:.1f} "
                f"spilled={self.spilled[i]:.1f} pruned={self.pruned[i]:.1f}")
        return "\n".join(lines)
