"""Synthetic deterministic data pipeline with host-side prefetch.

Deterministic: batch ``i`` is a pure function of (seed, i) — restart-safe
(resume from any step reproduces the stream) and identical across hosts, so
multi-host data loading needs no coordination beyond the step counter.
A background thread keeps a bounded queue of ready batches (host->device
overlap; the CPU analogue of the bridge's edge buffer).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.config import ModelConfig, ShapeConfig


class SyntheticLM:
    """Markov-ish synthetic token stream (not iid: next-token structure
    exists, so training losses actually fall)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, enc_len: int = 64):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.enc_len = enc_len

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq_len
        # structured stream: tok_{t+1} = (a * tok_t + drift) % v with noise
        a = 6364136223846793005
        start = rng.integers(0, v, size=(b, 1))
        drift = rng.integers(1, 97, size=(b, 1))
        idx = np.arange(s + 1)[None, :]
        toks = (start + drift * idx + (a * idx ** 2) % 31) % v
        noise = rng.integers(0, v, size=(b, s + 1))
        flip = rng.random((b, s + 1)) < 0.05
        toks = np.where(flip, noise, toks).astype(np.int32)
        out: dict[str, np.ndarray] = {"labels": toks[:, 1:]}
        if self.cfg.embed_inputs:
            emb_rng = np.random.default_rng((self.seed, step, 7))
            out["embeds"] = emb_rng.normal(
                size=(b, s, self.cfg.d_model)).astype(np.float32)
        else:
            out["tokens"] = toks[:, :-1]
        if self.cfg.num_encoder_layers > 0:
            enc_rng = np.random.default_rng((self.seed, step, 11))
            out["enc_embeds"] = enc_rng.normal(
                size=(b, self.enc_len, self.cfg.d_model)).astype(np.float32)
        return out

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded background prefetch queue over any batch iterator."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                self._q.put(item)
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                     enc_len: int = 3000):
    """ShapeDtypeStructs for one global batch (dry-run input stand-ins)."""
    import jax
    import jax.numpy as jnp
    b, s = shape.global_batch, shape.seq_len
    out = {"labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.embed_inputs:
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                             jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.num_encoder_layers > 0:
        out["enc_embeds"] = jax.ShapeDtypeStruct((b, enc_len, cfg.d_model),
                                                 jnp.bfloat16)
    return out
