from repro.data.pipeline import SyntheticLM, Prefetcher, make_batch_specs  # noqa: F401
