"""Continuous batching: request-level serving over the pooled datapath.

The decode step is a fixed-width jitted function — ``batch`` slots, one
token per slot per step — but real demand is thousands of concurrent
*requests* arriving over time with wildly different lengths.  This module
closes that gap the way production LLM servers do, specialized to this
repo's disaggregated-memory stack:

* **slot map with admit-on-free** — each batch slot serves one sequence
  at a time; when a sequence retires (its output length is reached) the
  slot returns to the free list and the next queued request takes it on
  the following control tick, so the jitted step never re-traces and the
  batch never drains to refill (continuous, not static, batching);
* **prefill/decode separation without a second engine** — a newly
  admitted sequence *prefills in place*: its prompt tokens feed one per
  step into its own slot while every other slot keeps decoding.  Slots
  are numerically independent (the step is elementwise per slot), so
  in-flight decodes are bit-identical to a solo run regardless of what
  their neighbours prefill;
* **pooled KV as leases** — each admitted sequence takes an orchestrator
  lease for its KV pages (``auto_renew=True``: renewal rides the
  orchestrator's background control period); retirement releases the
  lease, returning the pages to the control plane's free list for the
  next admission.  Requests that can *never* fit (quota, whole-pool
  capacity) are shed at submit via ``Orchestrator.can_ever_admit`` —
  they must not livelock the admission loop;
* **QoS-aware slot admission** — the same
  :class:`~repro.orchestrator.scheduler.WeightedFairScheduler` that
  splits the bridge round budget splits the *decode slots*: per-tenant
  slot windows from shares + live queue depths, interactive tenants
  admitted first, unused windows spilling to whoever has backlog (work
  conserving).  ``policy="naive"`` is the ablation: one global FIFO, the
  noisy-neighbour baseline the bench contrasts against.

Fidelity contract: with the :class:`ModelDecodeEngine` (real jitted
model), every retired sequence's tokens are **bit-identical** to
:func:`solo_reference` running the same request alone in a fixed batch —
admitting a slot resets its ``lengths`` to 0, which makes stale KV
invisible (attention masks to ``lengths + 1`` visible positions, and the
cache is overwritten progressively from position 0), so slot reuse
cannot leak state.  The :class:`SimulatedDecodeEngine` keeps the same
step protocol with per-slot host arithmetic for fleet-scale runs.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.clock import Clock, ManualClock
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import CAT_REQUEST, TraceRecorder
from repro.orchestrator.orchestrator import Orchestrator
from repro.orchestrator.scheduler import WeightedFairScheduler
from repro.serve.traffic import Request, TrafficGenerator


@dataclass
class SeqState:
    """One in-flight sequence bound to a decode slot."""

    req: Request
    slot: int
    lease_id: int
    admit_step: int
    arrive_us: float
    admit_us: float
    fed: int = 0                           # tokens fed so far
    out: List[int] = field(default_factory=list)
    first_token_us: Optional[float] = None
    started: bool = False                  # slot reset issued

    def next_feed(self) -> int:
        """The token to feed this step: prompt first, then own output."""
        if self.fed < self.req.prompt_len:
            return self.req.prompt[self.fed]
        return self.out[self.fed - self.req.prompt_len]

    @property
    def done(self) -> bool:
        return len(self.out) >= self.req.output_len


@dataclass
class _Queued:
    req: Request
    arrive_us: float
    attempts: int = 0


class SimulatedDecodeEngine:
    """Per-slot host arithmetic with the real engine's step protocol.

    Each slot carries a rolling hash ``acc``; one step maps the fed token
    to ``(31 * acc + tok + 1) % vocab`` and emits it.  The emission
    depends on the slot's *own* history only — exactly the independence
    property of the jitted model — so continuous-batched output matches
    :func:`solo_reference` iff the batcher feeds the right token at the
    right step AND resets the slot on admit (a forgotten reset leaks the
    previous occupant's ``acc`` into the hash and the tokens diverge).
    """

    def __init__(self, num_slots: int, vocab: int = 32000):
        self.num_slots = num_slots
        self.vocab = vocab
        self.acc = np.zeros((num_slots,), np.int64)

    def step(self, tokens: np.ndarray,
             reset: Sequence[int] = ()) -> np.ndarray:
        if len(reset):
            self.acc[np.asarray(list(reset), np.int64)] = 0
        self.acc = (31 * self.acc + np.asarray(tokens, np.int64) + 1) \
            % self.vocab
        return self.acc.astype(np.int32)


class ModelDecodeEngine:
    """The real jitted serve step behind the batcher's slot protocol.

    ``reset`` slots get ``state["lengths"][slot] = 0`` *before* the step
    consumes their first prompt token: visibility masks to
    ``lengths + 1`` positions and the KV cache is rewritten progressively
    from position 0, so the retiring occupant's state is unreachable —
    the mechanism behind the bit-exactness contract, for the local dense
    cache and the bridge paged placements alike.
    """

    def __init__(self, run, params, *, batch: int, max_len: int,
                 mesh=None, page_tokens: int = 512, dtype=None):
        import jax
        import jax.numpy as jnp

        from repro.serve.step import (build_serve_step, init_serve_state,
                                      make_cache_ops)
        kw = {} if dtype is None else {"dtype": dtype}
        self.num_slots = batch
        self.max_len = max_len
        self.cache_ops = make_cache_ops(run, mesh, max_len,
                                        page_tokens=page_tokens, **kw)
        self.params = params
        self.state = init_serve_state(run, batch, self.cache_ops)
        self._step = jax.jit(build_serve_step(run, self.cache_ops))
        self._jnp = jnp

    def step(self, tokens: np.ndarray,
             reset: Sequence[int] = ()) -> np.ndarray:
        if len(reset):
            idx = np.asarray(list(reset), np.int32)
            self.state["lengths"] = self.state["lengths"].at[idx].set(0)
        out, self.state = self._step(self.params, self.state,
                                     self._jnp.asarray(tokens))
        return np.asarray(out)


SHED_TERMINAL = "terminal"     # can never fit: quota / whole-pool capacity
SHED_ATTEMPTS = "attempts"     # exhausted max_admit_attempts retries


class ContinuousBatcher:
    """Per-tenant request queues feeding a fixed-width decode batch.

    The serve loop drives one cycle per decode step::

        submit(arrivals) -> control() -> step_inputs() -> engine.step()
                                      -> observe(next_tokens)

    ``control()`` advances the orchestrator clock (lease aging /
    auto-renewal / classic admission-queue drain ride
    ``Orchestrator.step``), re-fits the bridge windows from live queue
    depths each control period, and admits queued requests into free
    slots — taking one KV-page lease per sequence.  ``observe()``
    retires finished sequences: lease released, slot freed, per-QoS
    latency/TTFT histograms recorded (and a ``CAT_REQUEST`` trace span,
    when a recorder is attached).
    """

    def __init__(self, orc: Orchestrator, *, num_slots: int,
                 page_tokens: int = 512, policy: str = "qos",
                 max_admit_attempts: int = 0, lease_term: int = 8,
                 registry: Optional[MetricsRegistry] = None,
                 clock: Optional[Clock] = None,
                 recorder: Optional[TraceRecorder] = None):
        if num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {num_slots}")
        if policy not in ("qos", "naive"):
            raise ValueError(f"policy must be 'qos' or 'naive': {policy}")
        self.orc = orc
        self.num_slots = num_slots
        self.page_tokens = page_tokens
        self.policy = policy
        self.max_admit_attempts = max_admit_attempts
        self.lease_term = lease_term
        self.registry = registry if registry is not None else orc.metrics
        self.clock = clock if clock is not None else ManualClock(tick_us=0.0)
        self.recorder = recorder
        self.slot_sched = WeightedFairScheduler(num_slots)
        self.queues: Dict[int, deque] = {}
        self.slots: List[Optional[SeqState]] = [None] * num_slots
        self.free: deque = deque(range(num_slots))
        self._pending_reset: List[int] = []
        self.step_count = 0
        # request accounting (per tenant)
        self.submitted: Dict[int, int] = {}
        self.completed: Dict[int, int] = {}
        self.shed: Dict[int, Dict[str, int]] = {}
        self.tokens_out = 0
        self.peak_in_flight = 0
        self.retired: List[SeqState] = []    # every retired sequence, order

    # -- intake ----------------------------------------------------------------
    def submit(self, req: Request) -> str:
        """Queue one request; returns ``"queued"`` or ``"shed"``.

        Requests no future pool state can admit (tenant quota, whole-pool
        capacity) shed immediately — parking them would retry forever.
        """
        self.submitted[req.tenant_id] = \
            self.submitted.get(req.tenant_id, 0) + 1
        pages = req.num_pages(self.page_tokens)
        if not self.orc.can_ever_admit(req.tenant_id, max(pages, 1)):
            self._shed(req.tenant_id, SHED_TERMINAL)
            return "shed"
        self.queues.setdefault(req.tenant_id, deque()).append(
            _Queued(req=req, arrive_us=self.clock.now_us()))
        return "queued"

    def _shed(self, tenant_id: int, why: str) -> None:
        self.shed.setdefault(tenant_id, {})[why] = \
            self.shed.get(tenant_id, {}).get(why, 0) + 1
        self.registry.counter("serve_requests_shed_total",
                              tenant=str(tenant_id), reason=why).inc()

    # -- views -----------------------------------------------------------------
    def queue_depth(self, tenant_id: Optional[int] = None) -> int:
        if tenant_id is not None:
            return len(self.queues.get(tenant_id, ()))
        return sum(len(q) for q in self.queues.values())

    def active_count(self, tenant_id: Optional[int] = None) -> int:
        return sum(1 for s in self.slots if s is not None
                   and (tenant_id is None or s.req.tenant_id == tenant_id))

    def in_flight(self) -> int:
        """Concurrent sequences the server is responsible for now."""
        return self.queue_depth() + self.active_count()

    def accounting(self) -> Dict[str, Dict[int, int]]:
        """Conservation view: submitted == completed + shed + in flight."""
        return {
            "submitted": dict(self.submitted),
            "completed": dict(self.completed),
            "shed": {t: sum(v.values()) for t, v in self.shed.items()},
            "queued": {t: len(q) for t, q in self.queues.items() if q},
            "active": {t: self.active_count(t)
                       for t in self.submitted if self.active_count(t)},
        }

    # -- the control tick ------------------------------------------------------
    def control(self, telemetry=None,
                measured_round_us: Optional[float] = None
                ) -> List[SeqState]:
        """One background control tick; returns newly admitted sequences.

        Rides :meth:`Orchestrator.step` (lease aging — each sequence's
        KV lease auto-renews here — plus the classic admission-queue
        drain and the periodic telemetry re-fit), then re-fits the bridge
        request windows from the *serving* queue depths, then admits
        queued requests into free decode slots under the slot policy.
        """
        self.step_count += 1
        self.orc.step(telemetry=telemetry,
                      measured_round_us=measured_round_us)
        if self.orc.specs and \
                self.orc.step_count % self.orc.control_period == 0:
            self.orc.refit_windows(self._slot_demand())
        admitted = self._admit()
        self.peak_in_flight = max(self.peak_in_flight, self.in_flight())
        g = self.registry.gauge
        g("serve_slots_active").set(self.active_count())
        g("serve_queue_depth").set(self.queue_depth())
        g("serve_in_flight").set(self.in_flight())
        return admitted

    def _slot_demand(self) -> Dict[int, float]:
        return {tid: float(self.active_count(tid) + self.queue_depth(tid))
                for tid in self.orc.specs}

    def _admission_order(self) -> List[Tuple[int, int]]:
        """(tenant, allowance) pairs for this tick's windowed pass."""
        specs = list(self.orc.specs.values())
        if self.policy == "naive" or not specs:
            # One global FIFO: every tenant may bid for every slot; ties
            # broken by request id (arrival order) in _admit.
            return [(tid, self.num_slots) for tid in self.queues]
        schedule = self.slot_sched.compile(specs, self._slot_demand())
        return [(tid, max(schedule.windows.get(tid, 0)
                          - self.active_count(tid), 0))
                for tid in schedule.order]

    def _admit(self) -> List[SeqState]:
        admitted: List[SeqState] = []
        if not self.free:
            return admitted
        if self.policy == "naive":
            # Strict arrival order across all tenants — the ablation.
            while self.free:
                heads = [q[0] for q in self.queues.values() if q]
                if not heads:
                    break
                req = min(heads, key=lambda c: c.req.req_id)
                if not self._admit_one(self.queues[req.req.tenant_id],
                                       admitted):
                    break   # head of line blocked on capacity: stop
            return admitted
        order = self._admission_order()
        blocked: set = set()   # capacity-blocked this tick: probe once
        for tid, allow in order:            # windowed pass, QoS order
            q = self.queues.get(tid)
            for _ in range(allow):
                if not self.free or not q:
                    break
                if not self._admit_one(q, admitted):
                    blocked.add(tid)        # tenant blocked: next tenant
                    break
        progress = True
        while self.free and progress:       # work-conserving overflow
            progress = False
            for tid, _ in order:
                if tid in blocked:
                    continue
                q = self.queues.get(tid)
                if self.free and q:
                    if self._admit_one(q, admitted):
                        progress = True
                    else:
                        blocked.add(tid)
        return admitted

    def _admit_one(self, q: deque, admitted: List[SeqState]) -> bool:
        """Try the queue's head request; True iff a slot was filled."""
        cand = q.popleft()
        req = cand.req
        pages = max(req.num_pages(self.page_tokens), 1)
        decision, lease = self.orc.request_lease(
            req.tenant_id, pages, term=self.lease_term, auto_renew=True,
            queue=False, request_id=req.req_id)
        if not decision.admitted:
            cand.attempts += 1
            if not self.orc.can_ever_admit(req.tenant_id, pages):
                # Became terminal after submit (e.g. quota shrank by a
                # sibling lease the tenant will never drop): shed now.
                self._shed(req.tenant_id, SHED_TERMINAL)
            elif 0 < self.max_admit_attempts <= cand.attempts:
                self._shed(req.tenant_id, SHED_ATTEMPTS)
            else:
                q.appendleft(cand)          # keep head-of-line order
                return False
            return False
        slot = self.free.popleft()
        seq = SeqState(req=req, slot=slot, lease_id=lease.lease_id,
                       admit_step=self.step_count,
                       arrive_us=cand.arrive_us,
                       admit_us=self.clock.now_us())
        self.slots[slot] = seq
        self._pending_reset.append(slot)
        admitted.append(seq)
        return True

    # -- the decode-step halves ------------------------------------------------
    def step_inputs(self) -> Tuple[np.ndarray, List[int]]:
        """(tokens [num_slots], reset slots) for the engine step.

        Reset slots are the admissions since the last call — the engine
        must zero their ``lengths`` before consuming these tokens.  Free
        slots feed token 0; their output is discarded.
        """
        tokens = np.zeros((self.num_slots,), np.int32)
        for seq in self.slots:
            if seq is not None:
                tokens[seq.slot] = seq.next_feed()
                seq.started = True
        resets, self._pending_reset = self._pending_reset, []
        return tokens, resets

    def observe(self, next_tokens: np.ndarray) -> List[SeqState]:
        """Fold one engine step's emissions; returns retired sequences."""
        out = np.asarray(next_tokens)
        finished: List[SeqState] = []
        for seq in self.slots:
            if seq is None or not seq.started:
                continue
            fed_idx = seq.fed
            seq.fed += 1
            if fed_idx >= seq.req.prompt_len - 1:
                # Feeding the last prompt token (or any later feed) emits
                # a generated token.
                seq.out.append(int(out[seq.slot]))
                if seq.first_token_us is None:
                    seq.first_token_us = self.clock.now_us()
            if seq.done:
                finished.append(seq)
        for seq in finished:
            self._retire(seq)
        return finished

    def _retire(self, seq: SeqState) -> None:
        lease = self.orc.leases.get(seq.lease_id)
        if lease is not None:       # pages back to the pool's free list
            self.orc.release_lease(lease)
        self.slots[seq.slot] = None
        self.free.append(seq.slot)
        tid = seq.req.tenant_id
        self.completed[tid] = self.completed.get(tid, 0) + 1
        self.tokens_out += len(seq.out)
        self.retired.append(seq)
        qos = self.orc.specs[tid].qos if tid in self.orc.specs else "unknown"
        now = self.clock.now_us()
        h = self.registry.histogram
        h("serve_request_latency_us", lo=1.0, qos=qos).record(
            now - seq.arrive_us)
        h("serve_ttft_us", lo=1.0, qos=qos).record(
            (seq.first_token_us if seq.first_token_us is not None else now)
            - seq.arrive_us)
        h("serve_request_steps", lo=1.0, qos=qos).record(
            self.step_count - (seq.req.arrive_step + 1))
        self.registry.counter("serve_tokens_total", qos=qos).inc(
            len(seq.out))
        self.registry.counter("serve_requests_completed_total",
                              tenant=str(tid), qos=qos).inc()
        if self.recorder is not None:
            self.recorder.record_span(
                f"req{seq.req.req_id}", CAT_REQUEST,
                start_us=seq.arrive_us, end_us=now, tenant=tid, qos=qos,
                prompt_len=seq.req.prompt_len, output_len=len(seq.out),
                admit_us=seq.admit_us, req_id=seq.req.req_id,
                lease_id=seq.lease_id)

    def why(self, request_id: int) -> Dict[str, object]:
        """Causal chain behind one request: admission verdicts, lease
        grant/release, the route program it ran under (from the flight
        journal) plus its ``req{id}`` span and the bridge-round spans that
        overlap its in-flight window (from the trace recorder)."""
        out: Dict[str, object] = {
            "request_id": int(request_id),
            "decisions": [r.to_json() for r in
                          self.orc.flight.why(request_id)],
            "spans": [],
        }
        if self.recorder is not None:
            req_span = None
            for s in self.recorder.spans:
                if s.name == f"req{request_id}":
                    req_span = s
                    break
            if req_span is not None:
                lo, hi = req_span.start_us, (req_span.end_us
                                             if req_span.end_us is not None
                                             else float("inf"))
                for s in self.recorder.spans:
                    if s is req_span or (
                            s.end_us is not None and s.end_us >= lo
                            and s.start_us <= hi
                            and s.cat in ("round", "control", CAT_REQUEST)):
                        out["spans"].append({
                            "name": s.name, "cat": s.cat,
                            "start_us": s.start_us, "end_us": s.end_us,
                            "args": dict(s.args)})
        return out

    def describe(self) -> str:
        acc = self.accounting()
        done = sum(acc["completed"].values())
        subd = sum(acc["submitted"].values())
        return (f"batcher[{self.policy}]: step {self.step_count}, "
                f"{self.active_count()}/{self.num_slots} slots, "
                f"{self.queue_depth()} queued, {done}/{subd} completed, "
                f"{self.tokens_out} tokens, "
                f"peak in-flight {self.peak_in_flight}")


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def serve_loop(batcher: ContinuousBatcher, engine,
               traffic: Optional[TrafficGenerator] = None, *,
               steps: int = 0, step_us: float = 0.0, drain: bool = True,
               max_steps: int = 200_000) -> Dict[str, object]:
    """Closed-loop serve simulation: arrivals -> admit -> decode -> retire.

    Runs ``steps`` arrival steps (then stops offering load) and, with
    ``drain=True``, keeps stepping until every queued/active sequence
    retires.  ``step_us`` advances the batcher's clock per decode step
    (the modeled step latency), making the latency histograms
    wall-clock-denominated and deterministic.
    """
    step = 0
    while True:
        if traffic is not None and step < steps:
            for req in traffic.arrivals(step):
                batcher.submit(req)
        batcher.control()
        if batcher.active_count() > 0:
            tokens, resets = batcher.step_inputs()
            batcher.observe(engine.step(tokens, resets))
        if step_us:
            batcher.clock.advance(step_us)
        step += 1
        live = batcher.in_flight() if drain else 0
        if step >= steps and live == 0:
            break
        if step >= max_steps:
            raise RuntimeError(
                f"serve_loop did not drain in {max_steps} steps: "
                f"{batcher.describe()}")
    done = sum(batcher.completed.values())
    sim_s = step * step_us / 1e6 if step_us else 0.0
    return {
        "steps": step,
        "completed": done,
        "submitted": sum(batcher.submitted.values()),
        "shed": sum(sum(v.values()) for v in batcher.shed.values()),
        "tokens": batcher.tokens_out,
        "peak_in_flight": batcher.peak_in_flight,
        "goodput_tokens_per_s": (batcher.tokens_out / sim_s
                                 if sim_s else 0.0),
        "latency_us": batcher.registry.family_quantiles(
            "serve_request_latency_us"),
        "ttft_us": batcher.registry.family_quantiles("serve_ttft_us"),
    }


def solo_reference(engine, req: Request, *, slot: int = 0) -> List[int]:
    """Decode one request alone in a fixed batch — the fidelity oracle.

    Same engine protocol, same batch width, same slot, nothing else
    resident: the continuous batcher's tokens for the request must match
    this bit-for-bit.
    """
    tokens = np.zeros((engine.num_slots,), np.int32)
    out: List[int] = []
    fed = 0
    reset = [slot]
    while len(out) < req.output_len:
        tokens[slot] = (req.prompt[fed] if fed < req.prompt_len
                        else out[fed - req.prompt_len])
        emitted = engine.step(tokens, reset)
        reset = []
        if fed >= req.prompt_len - 1:
            out.append(int(emitted[slot]))
        fed += 1
    return out
