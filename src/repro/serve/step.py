"""Serve-step builder: one batched decode step with a chosen KV placement."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import RunConfig
from repro.models import transformer
from repro.parallel.sharding import ShardingRules
from repro.serve.cache_ops import BridgeCacheOps, RingCacheOps


def make_cache_ops(run: RunConfig, mesh: Optional[Mesh],
                   max_len: int, page_tokens: int = 512,
                   collect_telemetry: bool = False,
                   tenant_of_seq=None, max_tenants: int = 0,
                   dtype=jnp.bfloat16):
    """Build the KV-placement ops for a serve step.

    ``tenant_of_seq`` ([batch] tenant ids) threads multi-tenant telemetry
    attribution into the bridge placements — the per-tenant counters a
    :class:`~repro.orchestrator.Orchestrator` re-fits its QoS schedule
    from.  Ignored by the local/ring placements (no bridge traffic to
    attribute).
    """
    kp = run.kv_placement
    if kp == "local":
        cfgm = run.model
        if all(k != "full" and k != "global" for k in cfgm.layers) \
                and cfgm.window_size > 0:
            return RingCacheOps(max_len, dtype)
        return transformer.DenseCacheOps(max_len, dtype)
    if kp == "ring":
        return RingCacheOps(max_len, dtype)
    if kp in ("bridge_pull", "bridge_push"):
        return BridgeCacheOps(
            mode=kp.split("_")[1], max_len=max_len, page_tokens=page_tokens,
            mesh=mesh, mem_axis=run.bridge.mem_axis,
            budget=run.bridge.epoch_budget,
            edge_buffer=run.bridge.edge_buffer,
            channels=run.bridge.channels,
            fused=run.bridge.fused,
            collect_telemetry=collect_telemetry,
            tenant_of_seq=tenant_of_seq, max_tenants=max_tenants,
            dtype=dtype)
    raise ValueError(kp)


def collect_state_telemetry(state):
    """Sum the cumulative bridge counters carried in a decode state.

    Returns one :class:`~repro.telemetry.counters.BridgeTelemetry` (layers
    summed; stacked/scanned layer dims folded into the per-requester rows)
    or None when the state carries no telemetry (collection off, or a
    non-bridge placement).
    """
    from repro.telemetry import counters as telemetry_counters
    leaves = jax.tree_util.tree_flatten_with_path(
        state, is_leaf=lambda x: isinstance(
            x, telemetry_counters.BridgeTelemetry))[0]
    total = None
    for path, leaf in leaves:
        if not isinstance(leaf, telemetry_counters.BridgeTelemetry):
            continue
        # Stacked (scanned) layers carry extra leading dims: fold them in.
        extra = len(leaf.loopback_served.shape) - 1
        telem = jax.tree.map(
            lambda x: x.sum(axis=tuple(range(extra))) if extra else x, leaf)
        total = telem if total is None else telemetry_counters.add(total,
                                                                   telem)
    return total


def init_serve_state(run: RunConfig, batch: int, cache_ops,
                     enc_out: Optional[jax.Array] = None) -> dict:
    return transformer.init_decode_state(run.model, batch, cache_ops,
                                         enc_out=enc_out,
                                         stacked=run.scan_layers)


def abstract_serve_state(run: RunConfig, batch: int, cache_ops,
                         enc_len: int = 0) -> dict:
    cfg = run.model
    enc = (jax.ShapeDtypeStruct((batch, enc_len, cfg.d_model),
                                jnp.dtype(cfg.dtype))
           if cfg.cross_attention and enc_len else None)

    def build(enc_arr):
        return transformer.init_decode_state(cfg, batch, cache_ops,
                                             enc_out=enc_arr,
                                             stacked=run.scan_layers)
    if enc is not None:
        return jax.eval_shape(build, enc)
    return jax.eval_shape(lambda: build(None))


def build_serve_step(run: RunConfig, cache_ops):
    cfg = run.model

    def serve_step(params, state, tokens):
        logits, state = transformer.decode_step(cfg, params, state, tokens,
                                                cache_ops)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, state

    return serve_step


# ---------------------------------------------------------------------------
# Sharding of the decode state: mirror init_decode_state leaf-for-leaf.
# ---------------------------------------------------------------------------

def decode_state_shardings(run: RunConfig, mesh: Mesh, rules: ShardingRules,
                           state_abstract: dict) -> Any:
    """Derive NamedShardings for every decode-state leaf by name + rank.

    Leaves under ``periods`` carry a leading stacked dim (replicated).
    """

    def logical_axes(path: str, nd: int) -> tuple:
        stacked = "periods" in path
        lead = (None,) if stacked else ()
        body = nd - len(lead)

        def fit(*axes):
            axes = axes[:body] + (None,) * max(0, body - len(axes))
            return lead + axes

        if "k_pool" in path or "v_pool" in path:
            # pool slots shard over the mem axis, page *contents* shard
            # head_dim over the model axis (divisibility-gated in rules)
            return fit("pages", None, None, "head_dim")
        if "telem" in path:
            # per-requester counters: rows live on the mem axis
            return fit("pages")
        if "tail_k" in path or "tail_v" in path:
            return fit("batch", None, None, "head_dim")
        if "table" in path:
            return (None,) * nd
        if "lengths" in path:
            return (None,) * nd
        if "enc_out" in path:
            return ("batch",) + (None,) * (nd - 1)
        if "ring" in path and "pos" in path:
            return fit("batch", None)
        if path.endswith("['k']") or path.endswith("['v']"):
            return fit("batch", None, None, "head_dim")
        if "conv" in path:
            return fit("batch", None, "ff")
        if path.endswith("['C']"):
            return fit("batch", "state_heads", None, None)
        if path.endswith(("['n']", "['m']", "['h']", "['c']")):
            if body == 3:
                return fit("batch", "state_heads", None)
            return fit("batch", None)
        return (None,) * nd

    leaves, treedef = jax.tree_util.tree_flatten_with_path(state_abstract)
    out = []
    for path, leaf in leaves:
        axes = logical_axes(jax.tree_util.keystr(path), len(leaf.shape))
        out.append(NamedSharding(mesh, rules.spec(*axes)))
    return jax.tree_util.tree_unflatten(treedef, out)
