"""Synthetic request-level traffic: seeded arrivals with heavy tails.

The ROADMAP's "millions of users" scenario needs demand the orchestrator
can believe in: requests arrive *over time* (not one fixed batch), per
tenant, with the length statistics real serving fleets see — most prompts
short, a heavy Pareto tail of huge ones, and output lengths with the same
shape.  This module generates that demand deterministically:

* **Poisson arrivals** per tenant per step (``rate`` = expected requests
  per step), optionally windowed (``start_step`` / ``stop_step``) so a
  batch tenant can *flood* the queue mid-run — the noisy-neighbour
  scenario the QoS batcher must survive;
* **bounded-Pareto (Lomax) lengths**: ``mean`` sets the body, ``tail``
  the Pareto shape (smaller = heavier tail), ``max`` the hard cap —
  plus an optional fixed burst of oversized "whale" requests to exercise
  admission shedding;
* **full determinism**: every draw comes from a generator seeded by
  ``(seed, tenant_id, step)``, so the trace for a step is a pure function
  of the config — two runs (or the solo/QoS/naive comparison runs of the
  serve bench) see byte-identical request streams regardless of how many
  other tenants are mixed in.

Requests carry concrete prompt *token ids* so the same stream can drive
the real-model decode engine (bit-exactness fidelity runs) or the
host-side simulation (fleet-scale latency runs) unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence

import numpy as np


@dataclass(frozen=True)
class Request:
    """One serving request: a prompt to prefill, a length to decode."""

    req_id: int
    tenant_id: int
    arrive_step: int
    prompt: tuple            # token ids, length >= 1
    output_len: int          # tokens to generate (>= 1)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + self.output_len

    def num_pages(self, page_tokens: int) -> int:
        """Pooled pages the sequence pins for its whole lifetime."""
        if page_tokens <= 0:
            return 0
        return -(-self.total_tokens // page_tokens)


@dataclass(frozen=True)
class TenantTraffic:
    """One tenant's offered load (all knobs of the synthetic generator).

    Attributes:
      rate: expected arrivals per step (Poisson).
      prompt_mean / output_mean: body of the length distributions.
      tail: Pareto shape of both length tails (> 1; smaller = heavier).
      prompt_max / output_max: hard caps (bounded Pareto).
      start_step / stop_step: arrival window (stop < 0 = never stops) —
        a late ``start_step`` with a huge ``rate`` is a flood.
      vocab: prompt token ids draw uniformly from [1, vocab).
    """

    tenant_id: int
    rate: float
    prompt_mean: int = 32
    output_mean: int = 16
    tail: float = 2.5
    prompt_max: int = 512
    output_max: int = 256
    start_step: int = 0
    stop_step: int = -1
    vocab: int = 32000

    def __post_init__(self) -> None:
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")
        if self.tail <= 1.0:
            raise ValueError(f"tail must be > 1 (finite mean), "
                             f"got {self.tail}")
        if min(self.prompt_mean, self.output_mean) < 1:
            raise ValueError("prompt_mean/output_mean must be >= 1")


def _heavy_len(rng: np.random.Generator, mean: int, tail: float,
               cap: int) -> int:
    """Bounded Lomax draw with expectation ~``mean``: 1 + Pareto body."""
    body = mean * (tail - 1.0) * rng.pareto(tail)
    return int(np.clip(1 + np.floor(body), 1, max(cap, 1)))


class TrafficGenerator:
    """Deterministic per-step arrival stream over a tenant mix.

    ``arrivals(step)`` must be called with non-decreasing steps (request
    ids are minted monotonically); the *content* of a step's arrivals is
    a pure function of ``(seed, tenant_id, step)``.
    """

    def __init__(self, traffic: Sequence[TenantTraffic], seed: int = 0):
        ids = [t.tenant_id for t in traffic]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate tenant ids in traffic mix: {ids}")
        self.traffic = tuple(traffic)
        self.seed = seed
        self._next_req = 0
        self.generated: Dict[int, int] = {t.tenant_id: 0 for t in traffic}

    def _step_rng(self, tenant_id: int, step: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, tenant_id, step])

    def arrivals(self, step: int) -> List[Request]:
        """All requests arriving at ``step``, tenant-id order."""
        out: List[Request] = []
        for t in sorted(self.traffic, key=lambda t: t.tenant_id):
            if step < t.start_step:
                continue
            if 0 <= t.stop_step <= step:
                continue
            rng = self._step_rng(t.tenant_id, step)
            for _ in range(int(rng.poisson(t.rate))):
                plen = _heavy_len(rng, t.prompt_mean, t.tail, t.prompt_max)
                olen = _heavy_len(rng, t.output_mean, t.tail, t.output_max)
                prompt = tuple(
                    int(x) for x in rng.integers(1, t.vocab, size=plen))
                out.append(Request(req_id=self._next_req,
                                   tenant_id=t.tenant_id,
                                   arrive_step=step, prompt=prompt,
                                   output_len=olen))
                self._next_req += 1
                self.generated[t.tenant_id] += 1
        return out

    def total_generated(self) -> int:
        return self._next_req


def make_request(req_id: int, tenant_id: int, *, prompt_len: int,
                 output_len: int, arrive_step: int = 0, seed: int = 0,
                 vocab: int = 32000) -> Request:
    """One explicit request with a seeded prompt (tests, whale requests)."""
    rng = np.random.default_rng([seed, req_id])
    prompt = tuple(int(x) for x in rng.integers(1, vocab,
                                                size=max(prompt_len, 1)))
    return Request(req_id=req_id, tenant_id=tenant_id,
                   arrive_step=arrive_step, prompt=prompt,
                   output_len=max(output_len, 1))
