from repro.serve.cache_ops import BridgeCacheOps, RingCacheOps  # noqa: F401
from repro.serve.step import build_serve_step, init_serve_state  # noqa: F401
from repro.serve.batcher import (ContinuousBatcher,  # noqa: F401
                                 ModelDecodeEngine, SeqState,
                                 SimulatedDecodeEngine, serve_loop,
                                 solo_reference)
from repro.serve.traffic import (Request, TenantTraffic,  # noqa: F401
                                 TrafficGenerator, make_request)
