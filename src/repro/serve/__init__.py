from repro.serve.cache_ops import BridgeCacheOps, RingCacheOps  # noqa: F401
from repro.serve.step import build_serve_step, init_serve_state  # noqa: F401
