"""KV-cache placement strategies for decode (the serve-side bridge client).

Three implementations of the cache-ops protocol used by
``transformer.decode_step``:

* ``DenseCacheOps``  (in repro.models.transformer) — local dense baseline;
* ``RingCacheOps``   — bounded ring buffer for pure-SWA models (window slots
  only: what makes ``long_500k`` feasible for h2o-danube without a bridge);
* ``BridgeCacheOps`` — disaggregated paged KV through the software-defined
  bridge.  Global/full-attention layers page through the pool (``pull`` =
  paper-faithful, ``push`` = compute-at-memory); SWA layers keep a local
  ring buffer (their state is bounded, pooling it would waste circuit
  bandwidth — placement is per layer kind, chosen by the control plane).

The protocol:
    init_shared(cfg, batch) -> pytree | None        (memport table etc.)
    init_layer(cfg, batch, window=0) -> pytree
    append_and_attend(cfg, st, shared, lengths, q, k_new, v_new, *, window)
        -> (att_out [B, H, hd], new_st)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.config import ModelConfig
from repro.core import kvbridge
from repro.core.memport import MemPortTable
from repro.telemetry import counters as telemetry_counters


class RingCacheOps:
    """Bounded sliding-window cache: stores the last ``window`` tokens."""

    def __init__(self, max_len: int, dtype=jnp.bfloat16):
        self.max_len = max_len
        self.dtype = dtype

    def init_shared(self, cfg: ModelConfig, batch: int):
        return None

    def init_layer(self, cfg: ModelConfig, batch: int, window: int = 0):
        size = min(window, self.max_len) if window > 0 else self.max_len
        shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, self.dtype),
                "v": jnp.zeros(shape, self.dtype),
                "pos": jnp.full((batch, size), -1, jnp.int32)}

    def append_and_attend(self, cfg, st, shared, lengths, q, k_new, v_new, *,
                          window: int = 0):
        b = q.shape[0]
        size = st["k"].shape[1]
        idx = jnp.arange(b)
        slot = lengths % size
        k = st["k"].at[idx, slot].set(k_new.astype(self.dtype))
        v = st["v"].at[idx, slot].set(v_new.astype(self.dtype))
        pos = st["pos"].at[idx, slot].set(lengths)
        visible = lengths + 1
        lo = jnp.maximum(visible - window, 0) if window > 0 else 0
        mask = (pos >= (lo[:, None] if window > 0 else 0)) & (pos >= 0) \
            & (pos < visible[:, None])
        att = _masked_gqa_attention(q, k, v, mask)
        return att, {"k": k, "v": v, "pos": pos}


class BridgeCacheOps:
    """Disaggregated paged KV through the bridge (pull or push mode).

    ``collect_telemetry`` carries a cumulative
    :class:`~repro.telemetry.counters.BridgeTelemetry` in each pooled
    layer's decode state (``st["telem"]``), summed over the layer's bridge
    transfers every step — hardware-style monotonic counters the serving
    loop reads off the returned state and feeds to an aggregator.

    **Tenancy**: ``tenant_of_seq`` (i32[batch]) maps each batch slot to the
    tenant that owns its sequence; every page the slot pulls or flushes is
    attributed to that tenant in the telemetry's per-tenant bins, which is
    how a multi-tenant serving loop feeds the orchestrator's QoS scheduler.
    A plain python list/array is fine — it is converted once and enters the
    jitted step as a runtime constant of static shape.
    """

    def __init__(self, *, mode: str, max_len: int, page_tokens: int,
                 mesh: Optional[Mesh], mem_axis: str = "data",
                 budget: int = 8, edge_buffer: bool = True,
                 channels: int = 1, fused: bool = True,
                 collect_telemetry: bool = False,
                 tenant_of_seq=None, max_tenants: int = 0,
                 dtype=jnp.bfloat16):
        assert mode in ("pull", "push"), mode
        self.mode = mode
        self.max_len = max_len
        self.page_tokens = page_tokens
        self.max_pages = -(-max_len // page_tokens)
        self.mesh = mesh
        self.mem_axis = mem_axis
        self.budget = budget
        self.edge_buffer = edge_buffer
        self.channels = channels
        self.fused = fused
        self.collect_telemetry = collect_telemetry
        self.tenant_of_seq = (None if tenant_of_seq is None
                              else jnp.asarray(tenant_of_seq, jnp.int32))
        self.max_tenants = max_tenants
        self.dtype = dtype

    # -- shared state: the memport table (a runtime input, reprogrammable) ---
    def num_nodes(self) -> int:
        from repro.core import bridge
        return bridge._mem_axis_size(self.mesh, self.mem_axis)

    def slots_per_node(self, batch: int) -> int:
        return -(-batch * self.max_pages // self.num_nodes())

    def init_shared(self, cfg: ModelConfig, batch: int):
        table = MemPortTable.striped(batch * self.max_pages,
                                     self.num_nodes(),
                                     self.slots_per_node(batch))
        return {"table": table}

    def init_layer(self, cfg: ModelConfig, batch: int, window: int = 0):
        if window > 0:  # SWA layers stay local (bounded state)
            ring = RingCacheOps(self.max_len, self.dtype)
            return {"ring": ring.init_layer(cfg, batch, window)}
        n = self.num_nodes()
        num_slots = n * self.slots_per_node(batch)
        shape = (num_slots, self.page_tokens, cfg.num_kv_heads, cfg.head_dim)
        tail = (batch, self.page_tokens, cfg.num_kv_heads, cfg.head_dim)
        st = {"paged": kvbridge.PagedKVLayer(
            k_pool=jnp.zeros(shape, self.dtype),
            v_pool=jnp.zeros(shape, self.dtype),
            tail_k=jnp.zeros(tail, self.dtype),
            tail_v=jnp.zeros(tail, self.dtype))}
        if self.collect_telemetry:
            mt = (self.max_tenants
                  or telemetry_counters.DEFAULT_MAX_TENANTS)
            st["telem"] = telemetry_counters.zeros(n, leading=(n,),
                                                   max_tenants=mt)
        return st

    def append_and_attend(self, cfg, st, shared, lengths, q, k_new, v_new, *,
                          window: int = 0):
        if window > 0:
            ring = RingCacheOps(self.max_len, self.dtype)
            att, new_ring = ring.append_and_attend(
                cfg, st["ring"], None, lengths, q, k_new, v_new,
                window=window)
            return att, {"ring": new_ring}
        table = shared["table"]
        collect = self.collect_telemetry
        layer = kvbridge.append(
            st["paged"], table, lengths, k_new, v_new,
            page_tokens=self.page_tokens, max_pages=self.max_pages,
            mesh=self.mesh, mem_axis=self.mem_axis, budget=self.budget,
            edge_buffer=self.edge_buffer, channels=self.channels,
            fused=self.fused, collect_telemetry=collect,
            tenant_of_seq=self.tenant_of_seq,
            max_tenants=self.max_tenants)
        telem = None
        if collect:
            layer, telem = layer
        visible = lengths + 1
        if self.mode == "pull":
            att = kvbridge.decode_attention_pull(
                q, layer, table, visible, page_tokens=self.page_tokens,
                max_pages=self.max_pages, mesh=self.mesh,
                mem_axis=self.mem_axis, budget=self.budget,
                edge_buffer=self.edge_buffer, channels=self.channels,
                fused=self.fused, collect_telemetry=collect,
                tenant_of_seq=self.tenant_of_seq,
                max_tenants=self.max_tenants)
            if collect:
                att, pull_telem = att
                telem = telemetry_counters.add(telem, pull_telem)
        else:
            att = kvbridge.decode_attention_push(
                q, layer, table, visible, page_tokens=self.page_tokens,
                max_pages=self.max_pages, mesh=self.mesh,
                mem_axis=self.mem_axis)
        new_st = {"paged": layer}
        if collect:
            new_st["telem"] = telemetry_counters.add(st["telem"], telem)
        return att, new_st


def _masked_gqa_attention(q, k, v, mask):
    b, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qf, k.astype(jnp.float32)) * hd ** -0.5
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(q.dtype)
