"""Phi-3.5-MoE-instruct: 42B total, 6.6B active, 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.config import FULL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    head_dim=128,
    layer_pattern=(FULL_ATTN,),
    num_experts=16,
    experts_per_token=2,
    norm="layernorm",
    act="silu",
    glu=True,
)
