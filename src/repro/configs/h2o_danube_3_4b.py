"""H2O-Danube3-4B: llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]
"""
from repro.config import ModelConfig, SWA_ATTN

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    head_dim=120,
    layer_pattern=(SWA_ATTN,),
    window_size=4096,
    norm="rmsnorm",
    act="silu",
    glu=True,
)
