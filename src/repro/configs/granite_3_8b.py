"""Granite-3.0-8B base: dense GQA llama-style.

[hf:ibm-granite family; hf]
"""
from repro.config import FULL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    head_dim=128,
    layer_pattern=(FULL_ATTN,),
    norm="rmsnorm",
    act="silu",
    glu=True,
    tie_embeddings=True,
)
