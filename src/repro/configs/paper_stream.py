"""The paper's own case study: STREAM over disaggregated memory.

Not an LM — a bridge workload description consumed by the STREAM benchmarks
and examples (kernel set, array sizes, master counts from the paper §3).
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class StreamCaseStudy:
    array_elems: int = 10_000_000          # paper: 10M elements
    total_mib: float = 228.9               # paper: 228.9 MiB working set
    kernels: tuple = ("copy", "scale", "add", "triad")
    max_masters: int = 4                   # 4 A53 cores
    link_gbps: float = 10.0
    num_links: int = 2


CONFIG = StreamCaseStudy()
