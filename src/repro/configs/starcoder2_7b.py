"""StarCoder2-7B: dense GQA (kv=4), RoPE, 36 heads.

[arXiv:2402.19173; hf]
"""
from repro.config import FULL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    layer_pattern=(FULL_ATTN,),
    norm="layernorm",
    act="gelu",
    glu=False,
    rope_theta=1_000_000.0,
)
