"""Granite 3.0 1B-A400M base: 32-expert top-8 MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.config import FULL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,                   # per-expert FFN width
    vocab_size=49155,
    head_dim=64,
    layer_pattern=(FULL_ATTN,),
    num_experts=32,
    experts_per_token=8,
    norm="rmsnorm",
    act="silu",
    glu=True,
    tie_embeddings=True,
)
