"""SeamlessM4T-medium: encoder-decoder, multimodal (audio frontend stubbed).

[arXiv:2308.11596; hf].  12 encoder + 12 decoder layers, MHA (kv=16),
LayerNorm, GeLU FFN (no GLU).  ``input_specs`` provides precomputed speech
frame embeddings for the encoder.
"""
from repro.config import FULL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,               # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    layer_pattern=(FULL_ATTN,),
    num_encoder_layers=12,
    cross_attention=True,
    embed_inputs=False,          # decoder takes tokens; encoder takes embeds
    norm="layernorm",
    act="gelu",
    glu=False,
    tie_embeddings=True,
)
