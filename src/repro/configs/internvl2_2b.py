"""InternVL2-2B backbone: InternViT frontend (stub) + InternLM2-1.8B LM.

[arXiv:2404.16821; hf].  The vision tower is a STUB: ``input_specs`` feeds
precomputed patch embeddings; the transformer backbone below is the LM.
"""
from repro.config import FULL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    layer_pattern=(FULL_ATTN,),
    norm="rmsnorm",
    act="silu",
    glu=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    embed_inputs=True,          # frontend stub: [B, S, d] patch+text embeds
    num_prefix_embeds=256,      # image tokens prepended in decode shapes
)
