"""Gemma-3-12B: 5 local (w=1024) : 1 global pattern, 128k context, 256k vocab.

[hf:google/gemma-3-1b-pt family; unverified]
"""
from repro.config import GLOBAL_ATTN, ModelConfig, SWA_ATTN

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    layer_pattern=(SWA_ATTN, SWA_ATTN, SWA_ATTN, SWA_ATTN, SWA_ATTN,
                   GLOBAL_ATTN),
    window_size=1024,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    logit_softcap=30.0,
)
