"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 1 attn : 2 recurrent.

[arXiv:2402.19427; unverified].  38 layers = 12 x (rglru, rglru, swa) + 2.
MQA (kv=1), window 2048, GeGLU FFN.
"""
from repro.config import ModelConfig, RGLRU, SWA_ATTN

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    layer_pattern=(RGLRU, RGLRU, SWA_ATTN),
    window_size=2048,
    norm="rmsnorm",
    act="gelu",
    glu=True,
    tie_embeddings=True,
    lru_width=4096,
    logit_softcap=30.0,
)
