"""xLSTM-125M: alternating mLSTM / sLSTM blocks, O(1) decode state.

[arXiv:2405.04517; unverified].  d_ff=0: blocks carry their own projections.
"""
from repro.config import MLSTM, ModelConfig, SLSTM

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=192,
    layer_pattern=(MLSTM, SLSTM),
    norm="layernorm",
    act="gelu",
    glu=False,
    tie_embeddings=True,
)
