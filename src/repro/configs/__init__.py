"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full-size ModelConfig;
``get_reduced(arch_id)`` returns the same-family smoke-test config.
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig, reduced

ARCH_IDS = (
    "internvl2-2b",
    "granite-moe-1b-a400m",
    "phi3_5-moe-42b-a6_6b",
    "recurrentgemma-9b",
    "seamless-m4t-medium",
    "h2o-danube-3-4b",
    "gemma3-12b",
    "granite-3-8b",
    "starcoder2-7b",
    "xlstm-125m",
    # the paper's own case-study "architecture": STREAM over the bridge
    "paper-stream",
)

_ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi3_5-moe-42b-a6_6b",
}


def canonical(arch_id: str) -> str:
    return _ALIASES.get(arch_id, arch_id)


def get_config(arch_id: str) -> ModelConfig:
    arch_id = canonical(arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(
        f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return reduced(get_config(arch_id))


def lm_archs() -> list[str]:
    return [a for a in ARCH_IDS if a != "paper-stream"]
