"""Train-step builder: loss + grads + AdamW under pjit/GSPMD.

Distribution is declarative: parameters carry logical axes (repro.parallel),
batch shards over the DP axes, and XLA inserts the gradient all-reduce.  Two
opt-in distributed-optimization features restructure the step:

* ``microbatch > 1``     — gradient accumulation via lax.scan (same HLO size);
* ``compress_grads``     — the DP gradient reduction is taken away from GSPMD
  and done manually as an int8 ring all-reduce with error feedback
  (repro.optim.compress) inside a partial-manual shard_map over the DP axes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import RunConfig
from repro.core import bridge
from repro.models import transformer
from repro.optim import adamw
from repro.optim.adamw import AdamWState
from repro.parallel.sharding import ShardingRules, logical_to_physical


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array
    ef_residual: Any = None      # error-feedback state (compression only)


def make_train_state(run: RunConfig, key: jax.Array,
                     compress: bool = False, dp_size: int = 1) -> TrainState:
    params = transformer.init_params(run.model, key)
    state = TrainState(params=params, opt=adamw.adamw_init(params),
                       step=jnp.zeros((), jnp.int32),
                       ef_residual=(jax.tree.map(
                           lambda p: jnp.zeros((dp_size,) + p.shape,
                                               jnp.float32), params)
                           if compress else None))
    return state


def abstract_train_state(run: RunConfig, compress: bool = False,
                         dp_size: int = 1) -> TrainState:
    params = transformer.abstract_params(run.model)
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
    ef = lambda p: jax.ShapeDtypeStruct((dp_size,) + p.shape,  # noqa: E731
                                        jnp.float32)
    return TrainState(
        params=params,
        opt=AdamWState(m=jax.tree.map(f32, params),
                       v=jax.tree.map(f32, params),
                       count=jax.ShapeDtypeStruct((), jnp.int32)),
        step=jax.ShapeDtypeStruct((), jnp.int32),
        ef_residual=jax.tree.map(ef, params) if compress else None)


def train_state_shardings(run: RunConfig, mesh: Mesh,
                          rules: ShardingRules,
                          compress: bool = False) -> TrainState:
    axes = transformer.params_logical_axes(run.model)
    to_shard = lambda a: logical_to_physical(rules, mesh, *a)  # noqa: E731
    p_shard = jax.tree.map(to_shard, axes,
                           is_leaf=lambda x: isinstance(x, tuple))
    scalar = NamedSharding(mesh, P())

    # ZeRO: optimizer moments additionally shard over the zero axis (data)
    # on the first unsharded, divisible dim of each leaf.  fp32 m+v for a
    # 42B model drop from 21 GiB/chip (TP-only) to ~1.3 GiB/chip.
    zero_axes = rules.get("zero")
    if run.sharding.enable_zero and zero_axes:
        zsize = 1
        for a in zero_axes:
            zsize *= mesh.shape[a]
        shapes = transformer.abstract_params(run.model)
        is_axes = lambda x: isinstance(x, tuple) and all(  # noqa: E731
            isinstance(i, str) or i is None for i in x)

        def zero_shard(axes_leaf, shape_leaf):
            spec = list(rules.spec(*axes_leaf))
            spec += [None] * (len(shape_leaf.shape) - len(spec))
            for i, (ax, dim) in enumerate(zip(spec, shape_leaf.shape)):
                if ax is None and dim % zsize == 0:
                    spec[i] = zero_axes
                    break
            return NamedSharding(mesh, P(*spec))

        m_shard = jax.tree.map(zero_shard, axes, shapes, is_leaf=is_axes)
    else:
        m_shard = p_shard
    dp_axis = [a for a in run.sharding.batch_axes
               if a in mesh.axis_names][-1:] or [None]
    ef_shard = jax.tree.map(
        lambda a: NamedSharding(mesh, P(dp_axis[0])), axes,
        is_leaf=lambda x: isinstance(x, tuple))
    return TrainState(
        params=p_shard,
        opt=AdamWState(m=m_shard, v=m_shard, count=scalar),
        step=scalar,
        ef_residual=ef_shard if compress else None)


def batch_shardings(run: RunConfig, mesh: Mesh, rules: ShardingRules) -> dict:
    bspec = rules.spec("batch", None)
    out = {"labels": NamedSharding(mesh, bspec)}
    if run.model.embed_inputs:
        out["embeds"] = NamedSharding(mesh, rules.spec("batch", "seq", None))
    else:
        out["tokens"] = NamedSharding(mesh, bspec)
    if run.model.num_encoder_layers > 0:
        out["enc_embeds"] = NamedSharding(mesh, rules.spec("batch", None,
                                                           None))
    return out


def build_train_step(run: RunConfig, mesh: Optional[Mesh] = None,
                     rules: Optional[ShardingRules] = None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    cfg = run.model

    def loss_for(params, batch):
        return transformer.loss_fn(cfg, params, batch, run.remat)

    def grads_of(params, batch):
        if run.microbatch <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, batch)
            return loss, metrics, grads
        # gradient accumulation over microbatches
        def split(x):
            b = x.shape[0]
            mb = run.microbatch
            return x.reshape(mb, b // mb, *x.shape[1:])
        mb_batch = jax.tree.map(split, batch)

        def body(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_for, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return acc, (loss, metrics)

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, (losses, metrics) = jax.lax.scan(body, zero, mb_batch)
        grads = jax.tree.map(lambda g: g / run.microbatch, gsum)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return losses.mean(), metrics, grads

    def plain_step(state: TrainState, batch: dict):
        loss, metrics, grads = grads_of(state.params, batch)
        new_params, new_opt, opt_metrics = adamw.adamw_update(
            run.optim, grads, state.opt, state.params)
        metrics = dict(metrics, **opt_metrics)
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1,
                          ef_residual=state.ef_residual), metrics

    if not run.optim.compress_grads or mesh is None:
        return plain_step

    # ---- compressed-DP variant -------------------------------------------
    from repro.optim import compress as C
    dp_axes = [a for a in run.sharding.batch_axes if a in mesh.axis_names
               and mesh.shape[a] > 1]
    if not dp_axes:
        return plain_step
    dp_axis = dp_axes[-1]          # ring over the innermost DP axis
    n = mesh.shape[dp_axis]

    def compressed_step(state: TrainState, batch: dict):
        plain = TrainState(params=state.params, opt=state.opt,
                           step=state.step, ef_residual=None)

        def body(state_l, batch_l, res_l):
            loss, metrics, grads = grads_of(state_l.params, batch_l)
            flat, tdef = jax.tree.flatten(grads)
            sizes = [x.size for x in flat]
            vec = jnp.concatenate([x.reshape(-1).astype(jnp.float32)
                                   for x in flat])
            res_vec = jnp.concatenate(
                [x[0].reshape(-1) for x in jax.tree.leaves(res_l)])
            boosted = vec + res_vec
            # The ring ends in an int8 psum, so ``reduced`` is VMA-invariant
            # over the DP axis (bitwise identical on every shard).
            reduced = C.compressed_ring_allreduce(boosted, dp_axis, n)
            new_res = boosted - reduced
            outs, offs = [], 0
            for x, sz in zip(flat, sizes):
                outs.append(reduced[offs: offs + sz].reshape(x.shape)
                            .astype(x.dtype))
                offs += sz
            grads = jax.tree.unflatten(tdef, outs)
            ress, offs = [], 0
            for x, sz in zip(flat, sizes):
                ress.append(new_res[offs: offs + sz].reshape((1,) + x.shape))
                offs += sz
            residual = jax.tree.unflatten(tdef, ress)
            new_params, new_opt, opt_metrics = adamw.adamw_update(
                run.optim, grads, state_l.opt, state_l.params)
            metrics = dict(metrics, **opt_metrics)
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, dp_axis), metrics)
            new_plain = TrainState(params=new_params, opt=new_opt,
                                   step=state_l.step + 1, ef_residual=None)
            return new_plain, metrics, residual

        # partial-manual shard_map over the DP ring axis only; params and
        # optimizer state stay under GSPMD (model-axis sharding intact);
        # the error-feedback residual is per-DP-shard state.
        bspec = P(dp_axis)
        rep = P()
        mapped = bridge.shard_map(
            body, mesh,
            in_specs=(rep, bspec, P(dp_axis)),
            out_specs=(rep, rep, P(dp_axis)),
            mem_axis=dp_axis)
        new_plain, metrics, residual = mapped(plain, batch,
                                              state.ef_residual)
        return TrainState(params=new_plain.params, opt=new_plain.opt,
                          step=new_plain.step,
                          ef_residual=residual), metrics

    return compressed_step
