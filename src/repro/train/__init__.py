from repro.train.step import (  # noqa: F401
    TrainState,
    build_train_step,
    make_train_state,
    train_state_shardings,
)
