"""Configuration system for the repro framework.

Everything a run needs is described by three frozen dataclasses:

* :class:`ModelConfig`   — architecture (one per assigned arch in ``repro.configs``)
* :class:`ShapeConfig`   — input-shape cell (train_4k / prefill_32k / decode_32k / long_500k)
* :class:`RunConfig`     — mesh, sharding, bridge, optimizer and step options

Configs are plain data: no jax imports happen at module scope so that importing
a config never touches device state.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

# ---------------------------------------------------------------------------
# Block kinds (per-layer behaviour inside a transformer stack)
# ---------------------------------------------------------------------------
FULL_ATTN = "full"        # full causal attention
SWA_ATTN = "swa"          # sliding-window causal attention
GLOBAL_ATTN = "global"    # full attention layer inside a local:global pattern
RGLRU = "rglru"           # RG-LRU recurrent block (recurrentgemma / griffin)
MLSTM = "mlstm"           # xLSTM matrix-memory block
SLSTM = "slstm"           # xLSTM scalar-memory block

ATTENTION_KINDS = (FULL_ATTN, SWA_ATTN, GLOBAL_ATTN)
RECURRENT_KINDS = (RGLRU, MLSTM, SLSTM)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description, sufficient to build params + fwd/decode fns."""

    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # Per-period layer pattern, tiled to num_layers (remainder allowed).
    # e.g. gemma3: 5×swa + 1×global; recurrentgemma: (rglru, rglru, swa).
    layer_pattern: Sequence[str] = (FULL_ATTN,)
    window_size: int = 0             # sliding window for swa layers

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # Encoder-decoder (seamless): encoder layers are bidirectional FULL_ATTN.
    num_encoder_layers: int = 0
    cross_attention: bool = False

    # Frontend stubs for [vlm] / [audio]: inputs are precomputed embeddings.
    embed_inputs: bool = False       # True -> input is (B, S, d_model) floats
    num_prefix_embeds: int = 0       # e.g. image patch tokens prepended

    # Misc architectural knobs
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu | gelu
    glu: bool = True                 # gated FFN (SwiGLU/GeGLU)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"

    # xLSTM internals
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 1.3333333
    conv_width: int = 4
    lru_width: int = 0               # 0 -> d_model

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # -- derived ------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to 256 so vocab shards over TP=16 (Megatron
        convention); logits are sliced back to ``vocab_size``."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def layers(self) -> tuple[str, ...]:
        """Full per-layer kind list (pattern tiled, truncated to num_layers)."""
        pat = tuple(self.layer_pattern)
        reps = -(-self.num_layers // len(pat))
        return (pat * reps)[: self.num_layers]

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return any(k in ATTENTION_KINDS for k in self.layers)

    @property
    def is_recurrent_only(self) -> bool:
        return all(k in RECURRENT_KINDS for k in self.layers)

    @property
    def supports_long_context(self) -> bool:
        """True when per-token decode state is bounded (sub-quadratic family)."""
        return all(k != FULL_ATTN for k in self.layers) or self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        hd, h, kv = self.head_dim, self.num_heads, self.num_kv_heads
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for kind in self.layers:
            total += d  # pre-norm
            if kind in ATTENTION_KINDS:
                total += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            elif kind == RGLRU:
                w = self.lru_width
                total += 2 * d * w + w * d          # in/out proj (x,y branches)
                total += self.conv_width * w        # temporal conv
                total += 2 * w                      # input & recurrent gates (diag)
            elif kind == MLSTM:
                pf = self.mlstm_proj_factor
                inner = int(d * pf)
                total += 2 * d * inner + inner * d  # up(x2) + down
                total += 3 * inner * inner // max(self.num_heads, 1)  # qkv per head (block-diag approx)
                total += 3 * inner                  # i,f,o gates
            elif kind == SLSTM:
                pf = self.slstm_proj_factor
                inner = int(d * pf)
                total += 4 * d * d                  # recurrent cell weights (i,f,z,o)
                total += d * inner + inner * d      # ffn up/down
            # FFN
            if kind in ATTENTION_KINDS or kind == RGLRU:
                total += d  # post-norm
                if self.is_moe:
                    total += d * self.num_experts                       # router
                    ff = self.d_ff
                    total += self.num_experts * (3 if self.glu else 2) * d * ff
                elif self.d_ff > 0:
                    total += (3 if self.glu else 2) * d * self.d_ff
        if self.cross_attention:
            for _ in range(self.num_layers):
                total += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d + d
        for _ in range(self.num_encoder_layers):
            total += d + d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            total += d + (3 if self.glu else 2) * d * self.d_ff
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        per_layer_all = self.num_experts * (3 if self.glu else 2) * d * ff
        per_layer_act = self.experts_per_token * (3 if self.glu else 2) * d * ff
        n_moe_layers = sum(1 for k in self.layers if k in ATTENTION_KINDS)
        return self.param_count() - n_moe_layers * (per_layer_all - per_layer_act)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.mode == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class BridgeConfig:
    """Software-defined memory-bus bridge parameters (paper §2)."""

    page_elems: int = 16_384          # elements per page (the 'flit batch')
    epoch_budget: int = 8             # rate limiter: max pages pulled per epoch
    num_epochs: int = 0               # 0 -> one full ring rotation (N-1 epochs)
    mode: str = "pull"                # pull (paper) | push (beyond-paper)
    edge_buffer: bool = True          # double-buffer transfers across epochs
    channels: int = 1                 # pipelined round-engine depth (1=serial;
                                      # >1 overlaps request/data flits across
                                      # round chunks, bit-exact results)
    fused: bool = True                # fused Pallas datapath: one kernel pair
                                      # + one collective pair per round
                                      # (bit-exact; False = unfused ppermute
                                      # chain escape hatch)
    mem_axis: str = "data"            # mesh axis hosting the memory pool
    # modelled hardware (perfmodel): paper values and TPU projection
    link_gbps: float = 10.0           # paper prototype: 10G Aurora
    rtt_cycles: int = 134             # paper: 134-cycle data-flit round trip
    clock_mhz: float = 167.5          # 134 cycles == 800ns  -> 167.5 MHz


@dataclass(frozen=True)
class ShardingConfig:
    """Logical→mesh-axis rules. Axis names refer to mesh axes."""

    batch_axes: tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"
    seq_axis: str = "data"            # sequence parallelism for long prefill
    # SP disabled by default: the data axis already carries batch DP, and
    # binding both to one axis is invalid.  Enable per-run for batch-1 work.
    shard_seq_threshold: int = 1 << 40
    expert_axis: str = "model"
    zero_axis: str = "data"           # optimizer-state sharding (ZeRO) axis
    enable_zero: bool = True
    kv_pages_axis: str = "data"       # disaggregated KV pool axis


@dataclass(frozen=True)
class OptimConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    compress_grads: bool = False      # int8 ring all-reduce w/ error feedback


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    bridge: BridgeConfig = field(default_factory=BridgeConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    optim: OptimConfig = field(default_factory=OptimConfig)
    remat: str = "block"              # none | block | full
    scan_layers: bool = True
    attn_impl: str = "xla"            # xla | pallas
    kv_placement: str = "local"       # local | bridge_pull | bridge_push
    microbatch: int = 1               # gradient accumulation steps
    seed: int = 0

    def cache_key(self) -> str:
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]


def reduced(model: ModelConfig, **overrides: Any) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pat = len(tuple(model.layer_pattern))
    # Keep the full config's pattern remainder so smoke tests exercise the
    # unscanned tail path (e.g. recurrentgemma's 38 = 12*3 + 2).
    n_layers = min(model.num_layers, 2 * pat + model.num_layers % pat)
    shrink: dict[str, Any] = dict(
        num_layers=n_layers,
        d_model=128,
        num_heads=4,
        num_kv_heads=min(model.num_kv_heads, 2) if model.num_kv_heads > 1 else 1,
        d_ff=256 if model.d_ff > 0 else 0,
        vocab_size=512,
        head_dim=32,
        window_size=min(model.window_size, 64) if model.window_size else 0,
        num_experts=min(model.num_experts, 4) if model.num_experts else 0,
        experts_per_token=min(model.experts_per_token, 2) if model.experts_per_token else 0,
        num_encoder_layers=min(model.num_encoder_layers, 2),
        lru_width=128 if model.lru_width else 0,
        num_prefix_embeds=min(model.num_prefix_embeds, 8),
    )
    shrink.update(overrides)
    return dataclasses.replace(model, **shrink)


def config_to_dict(cfg: Any) -> Mapping[str, Any]:
    return dataclasses.asdict(cfg)
