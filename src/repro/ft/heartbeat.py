"""Heartbeat monitoring for node liveness (simulated clock for tests).

In a real deployment each worker's agent POSTs a heartbeat to the control
plane; here the monitor is a pure data structure driven by the training loop
(or a simulated clock in tests), so failure-detection logic is testable
without real processes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class HeartbeatMonitor:
    num_nodes: int
    timeout: float = 30.0
    last_seen: dict = field(default_factory=dict)
    now: float = 0.0

    def beat(self, node: int, t: Optional[float] = None) -> None:
        self.now = t if t is not None else self.now
        self.last_seen[node] = self.now

    def tick(self, t: float) -> list[int]:
        """Advance the clock; return nodes newly considered dead."""
        self.now = t
        dead = []
        for node in range(self.num_nodes):
            seen = self.last_seen.get(node)
            if seen is not None and (t - seen) > self.timeout:
                dead.append(node)
                self.last_seen.pop(node)
        return dead
