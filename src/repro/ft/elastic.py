"""Elastic fault-tolerant training driver.

Composes the substrate into the recovery loop a 1000-node deployment needs:

* periodic **checkpointing** (atomic, retention-managed);
* **failure handling**: on a node-failure event the control plane re-homes
  the dead node's pool pages (memport reprogram — *no recompile*), pooled
  state is restored from the last checkpoint through the bridge, and
  training resumes at the checkpointed step;
* **straggler mitigation**: step-time telemetry feeds per-node bridge rate
  limits (paper §2's software-controlled rate limiter);
* **traffic feedback**: in-band bridge counters recorded via
  :meth:`ElasticTrainer.record_telemetry` close the loop — rate limits
  adapt to observed spills and :meth:`ElasticTrainer.route_program`
  compiles load-balanced, measured-pruned circuit schedules;
* **elastic scaling**: the same remap path admits *new* nodes (revive) and
  re-stripes pages onto them.

The driver is deliberately synchronous and single-process here (the
container has one host); every decision point (detect -> plan -> remap ->
restore -> resume) is a pure function of explicit state so the logic is unit
tested in tests/test_ft.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.checkpoint import CheckpointManager
from repro.core.control_plane import ControlPlane, MigrationStep
from repro.ft.heartbeat import HeartbeatMonitor
from repro.obs.clock import MonotonicClock
from repro.telemetry import TelemetryAggregator


@dataclass
class FailureEvent:
    node: int                  # -1 for non-node events (e.g. link failures)
    at_step: int
    kind: str = "node_lost"
    direction: Optional[int] = None   # ring direction for link_lost events


@dataclass
class ElasticTrainer:
    """Wraps a step function with checkpoint/restart + elastic remap."""

    step_fn: Callable[[Any, Any], tuple[Any, dict]]
    ckpt: CheckpointManager
    cp: Optional[ControlPlane] = None
    ckpt_every: int = 50
    monitor: Optional[HeartbeatMonitor] = None
    telemetry: Optional[TelemetryAggregator] = None
    events: list = field(default_factory=list)
    _wall: MonotonicClock = field(default_factory=MonotonicClock, repr=False)

    def run(self, state: Any, batches, *, start_step: int = 0,
            num_steps: int = 100,
            failure_schedule: Optional[dict[int, int]] = None,
            on_remap: Optional[Callable[[list[MigrationStep]], None]] = None):
        """Run ``num_steps`` steps with injected failures (tests).

        failure_schedule: {step: node_to_kill}.
        Returns (state, history).
        """
        history = []
        step = start_step
        it = iter(batches)
        while step < num_steps:
            if failure_schedule and step in failure_schedule:
                node = failure_schedule.pop(step)
                state, step = self.handle_failure(node, step, state)
                continue
            batch = next(it)
            t0 = self._wall.now_us()
            state, metrics = self.step_fn(state, batch)
            dt = (self._wall.now_us() - t0) / 1e6
            if self.cp is not None:
                # single-host simulation: node 0 reports real time, others
                # are synthetic equal reports unless a test overrides
                for node in self.cp.alive_nodes:
                    self.cp.record_step_time(node, dt)
            step += 1
            history.append({"step": step, **{k: float(v)
                                             for k, v in metrics.items()}})
            if step % self.ckpt_every == 0:
                self.ckpt.save(step, state, extra={"step": step})
        return state, history

    def handle_failure(self, node: int, step: int, state: Any):
        """Failure path: remap pool pages, restore from last checkpoint."""
        self.events.append(FailureEvent(node, step))
        plan: list[MigrationStep] = []
        if self.cp is not None:
            plan = self.cp.fail_node(node)
        restore_step = self.ckpt.latest_step()
        if restore_step is None:
            raise RuntimeError(
                f"node {node} lost at step {step} with no checkpoint")
        restored, extra = self.ckpt.restore(state, step=restore_step)
        self.events.append(
            FailureEvent(node, restore_step, kind="restored"))
        # caller-provided executor refills re-homed pool pages (zero_bridge)
        self._last_plan = plan
        return restored, int(extra.get("step", restore_step))

    def record_telemetry(self, telem) -> None:
        """Fold one step's bridge counters into the trainer's aggregator.

        Lazily creates the :class:`~repro.telemetry.TelemetryAggregator`
        (sized from the control plane) so existing callers pay nothing.
        """
        if self.telemetry is None:
            n = (self.cp.num_nodes if self.cp is not None
                 else int(telem.traffic.shape[-1]))
            # Tenant width follows the measurement: a store created with a
            # wider max_tenants must not trip the aggregator's width check.
            self.telemetry = TelemetryAggregator(
                n, max_tenants=telem.max_tenants)
        self.telemetry.update(telem)

    def rate_limits(self, static_budget: int):
        """Per-node bridge budgets: straggler throttling + measured spill
        feedback (one measure -> recompile iteration zeroes the spills)."""
        if self.cp is None:
            return None
        return self.cp.rate_limits(static_budget, telemetry=self.telemetry)

    def route_program(self):
        """The circuit schedule for the next step: load-balanced and pruned
        from measured traffic once telemetry has been recorded, placement-
        derived before that."""
        if self.cp is None:
            return None
        return self.cp.route_program(telemetry=self.telemetry)

    def handle_link_failure(self, step: int, direction: int):
        """Ring-link failure path: no data is lost (pages stay homed), the
        circuit schedule just reroutes around the dead direction.  Returns
        the re-compiled RouteProgram to feed the next bridge step."""
        if self.cp is None:
            return None
        self.events.append(FailureEvent(-1, step, kind="link_lost",
                                        direction=direction))
        self.cp.report_link_failure(direction)
        return self.cp.route_program(telemetry=self.telemetry)
