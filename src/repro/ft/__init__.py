from repro.ft.elastic import ElasticTrainer, FailureEvent  # noqa: F401
from repro.ft.heartbeat import HeartbeatMonitor  # noqa: F401
