"""Transaction tracing for the bridge datapath.

A :class:`TraceRecorder` wraps *host-side* calls into jitted datapath
functions in wall-clock spans.  Spans nest — the recorder keeps an open
stack, so a transaction span contains its round spans, which contain
channel-chunk and phase spans — and each span can be decorated with the
``BridgeTelemetry`` counters of the work it fenced, making the trace a
join of *when* (wall clock) and *what* (bit-exact page counts).

Fencing matters under jax's async dispatch: a jitted call returns a
future, so the recorder only closes a span after
``jax.block_until_ready`` on the results (``fence=``).  The clock is
injectable (:mod:`repro.obs.clock`); with a ``ManualClock`` the whole
trace is deterministic and reproducible byte-for-byte.

Export is Chrome-trace JSON (``{"traceEvents": [...]}`` with ``ph="X"``
complete events) — load it at https://ui.perfetto.dev or
``chrome://tracing``.

For attributing time *inside* one jitted call (where no host clock can
see), the datapath phases are annotated with ``jax.named_scope("obs:…")``
so compiled-HLO metadata carries the phase name;
:func:`phase_op_counts` tallies instructions per phase from HLO text.
"""

from __future__ import annotations

import json
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from repro.obs.clock import Clock, MonotonicClock

#: Span categories used by the shipped instrumentation.  Free-form —
#: these are conventions, not an enum the recorder enforces.
CAT_TRANSFER = "transfer"   # one pull/push transaction (all rounds)
CAT_ROUND = "round"         # one bridge round
CAT_CHUNK = "chunk"         # one channel chunk within a round
CAT_PHASE = "phase"         # wire_req / gather / wire_data / commit
CAT_COMPILE = "compile"     # trace/lower/compile of a jitted cell
CAT_CONTROL = "control"     # orchestrator control period / refit
CAT_REQUEST = "request"     # one serving request (queue -> retire)


@dataclass
class Span:
    """One closed-interval trace span (microsecond timestamps)."""

    span_id: int
    parent_id: Optional[int]
    name: str
    cat: str
    start_us: float
    end_us: Optional[float] = None
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration_us(self) -> float:
        return 0.0 if self.end_us is None else self.end_us - self.start_us


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    return v


class TraceRecorder:
    """Collects a span tree and exports Chrome-trace/Perfetto JSON."""

    def __init__(self, clock: Optional[Clock] = None, *, pid: int = 0,
                 process_name: str = "repro-bridge"):
        self.clock = clock if clock is not None else MonotonicClock()
        self.pid = pid
        self.process_name = process_name
        self.spans: List[Span] = []
        self._stack: List[Span] = []
        self._next_id = 0

    # ---------------------------------------------------------------- spans
    @contextmanager
    def span(self, name: str, cat: str = CAT_TRANSFER, *, fence=None,
             **attrs) -> Iterator[Span]:
        """Open a span around a block; ``fence=`` pytrees are blocked on
        before the span closes so async-dispatched device work is inside."""
        s = Span(span_id=self._next_id,
                 parent_id=self._stack[-1].span_id if self._stack else None,
                 name=name, cat=cat, start_us=self.clock.now_us(),
                 args={k: _jsonable(v) for k, v in attrs.items()})
        self._next_id += 1
        self.spans.append(s)
        self._stack.append(s)
        try:
            yield s
        finally:
            if fence is not None:
                self.fence(fence)
            self._stack.pop()
            s.end_us = self.clock.now_us()

    def record_span(self, name: str, cat: str = CAT_REQUEST, *,
                    start_us: float, end_us: float, **attrs) -> Span:
        """Append a closed span with explicit timestamps.

        For lifecycle spans whose start predates the call — e.g. a serving
        request recorded at retirement, whose arrival timestamp was taken
        steps ago — where the context-manager protocol cannot apply.  The
        span is top-level (no parent inferred from the open stack).
        """
        s = Span(span_id=self._next_id, parent_id=None, name=name, cat=cat,
                 start_us=float(start_us), end_us=float(end_us),
                 args={k: _jsonable(v) for k, v in attrs.items()})
        self._next_id += 1
        self.spans.append(s)
        return s

    @staticmethod
    def fence(tree) -> None:
        """Block until every array in ``tree`` is ready (async barrier)."""
        import jax

        jax.block_until_ready(tree)

    def annotate(self, span: Span, **attrs) -> None:
        span.args.update({k: _jsonable(v) for k, v in attrs.items()})

    def annotate_telemetry(self, span: Span, telem, *, page_bytes: int = 0,
                           tenant_names: Optional[Dict[int, str]] = None
                           ) -> None:
        """Decorate ``span`` with the BridgeTelemetry counters it fenced.

        ``telem`` leaves may carry a leading requester axis (the N-device
        path returns [N, ...]); counts are summed over it so the span
        describes the whole transaction.  All values are exact integers —
        tests reconcile them bit-exactly against the oracle.
        """
        a = lambda x: np.asarray(x)  # noqa: E731
        served = int(a(telem.served_total()).sum())
        loop = int(a(telem.loopback_served).sum())
        cw, ccw = telem.wire_pages()
        cw, ccw = int(a(cw).sum()), int(a(ccw).sum())
        intra, inter = telem.tier_pages()
        tier_hops = a(telem.tier_hops).reshape(-1, 2).sum(0)
        args: Dict[str, Any] = {
            "pages_served": served,
            "pages_loopback": loop,
            "pages_spilled": int(a(telem.spilled).sum()),
            "pages_pruned": int(a(telem.pruned).sum()),
            "wire_pages_cw": cw,
            "wire_pages_ccw": ccw,
            "pages_intra_board": int(a(intra).sum()),
            "pages_inter_board": int(a(inter).sum()),
            "board_hop_pages": int(tier_hops[0]),
            "rack_hop_pages": int(tier_hops[1]),
        }
        if page_bytes:
            args["bytes_served"] = served * page_bytes
            args["wire_bytes"] = (cw + ccw) * page_bytes
        tser = a(telem.tenant_served).reshape(-1, telem.max_tenants).sum(0)
        tspill = a(telem.tenant_spilled).reshape(-1, telem.max_tenants).sum(0)
        names = tenant_names or {}
        args["tenant_pages"] = {
            str(names.get(t, t)): int(tser[t])
            for t in range(telem.max_tenants) if tser[t] or tspill[t]}
        span.args.update(args)

    # -------------------------------------------------------------- queries
    def find(self, name: str) -> Optional[Span]:
        """Most recent span with this name (None if absent)."""
        for s in reversed(self.spans):
            if s.name == name:
                return s
        return None

    def find_all(self, name: Optional[str] = None,
                 cat: Optional[str] = None) -> List[Span]:
        return [s for s in self.spans
                if (name is None or s.name == name)
                and (cat is None or s.cat == cat)]

    def children(self, span: Span) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span.span_id]

    def clear(self) -> None:
        self.spans = []
        self._stack = []
        self._next_id = 0

    # --------------------------------------------------------------- export
    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace dict: ``M`` metadata + one ``X`` event per span."""
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": self.process_name},
        }]
        for s in self.spans:
            args = dict(s.args, span_id=s.span_id, parent_id=s.parent_id)
            if s.end_us is None:
                # Auto-close still-open spans at export time so they show
                # up in the trace (flagged, not silently dropped).  The
                # span itself stays open — export must not mutate it.
                dur = max(self.clock.now_us() - s.start_us, 0.0)
                args["unclosed"] = True
            else:
                dur = s.duration_us
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": round(s.start_us, 3),
                "dur": round(dur, 3),
                "pid": self.pid, "tid": 0,
                "args": args,
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"recorder": self.process_name}}

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_chrome_trace(), sort_keys=True,
                          indent=indent)

    def write(self, path: str, indent: Optional[int] = 1) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=indent))
            f.write("\n")


def phase_op_counts(hlo_text: str) -> Dict[str, int]:
    """Count HLO instructions per ``obs:<phase>`` named scope.

    The datapath wraps its phases in ``jax.named_scope("obs:wire_req")``
    etc.; after lowering, each HLO instruction's metadata ``op_name``
    carries the scope path.  Counting instructions per phase shows where
    a program variant or pipeline depth pays its dispatch cost — the
    in-jit complement of host-side spans (XLA may rewrite ``:`` to ``_``
    in scope names, so both spellings are matched).

    Thin wrapper over the shared HLO parser's
    :func:`repro.analysis.hlo.scope_op_counts` — the jaxpr auditor's
    collective budgets count the same ops this reports.
    """
    from repro.analysis.hlo import scope_op_counts

    return scope_op_counts(hlo_text, prefix="obs")
