"""Injectable clocks for the tracing plane.

Everything in ``repro.obs`` reads time through a ``Clock`` so tests can
substitute a deterministic source and prove traces reproduce
byte-for-byte.  Timestamps are microseconds, matching the perfmodel's
unit and the Chrome-trace ``ts``/``dur`` convention.
"""

from __future__ import annotations

import time


class Clock:
    """Minimal clock protocol: ``now_us()`` returns microseconds."""

    def now_us(self) -> float:
        raise NotImplementedError


class MonotonicClock(Clock):
    """Wall clock backed by ``time.perf_counter`` (monotonic, sub-us)."""

    def __init__(self):
        self._origin = time.perf_counter()

    def now_us(self) -> float:
        return (time.perf_counter() - self._origin) * 1e6


class ManualClock(Clock):
    """Deterministic clock: advances ``tick_us`` on every read.

    Two runs that make the same sequence of ``now_us()`` calls observe
    identical timestamps, which makes trace output byte-for-byte
    reproducible regardless of host speed.
    """

    def __init__(self, start_us: float = 0.0, tick_us: float = 1.0):
        self._now = float(start_us)
        self.tick_us = float(tick_us)

    def now_us(self) -> float:
        t = self._now
        self._now += self.tick_us
        return t

    def advance(self, us: float) -> None:
        self._now += float(us)
