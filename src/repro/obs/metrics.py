"""Metrics registry: counters, gauges, log-bucketed histograms, SLOs.

Host-side, dependency-free (numpy only).  Families follow a
Prometheus-like naming scheme — ``bridge_*`` for datapath counters fed
from :class:`~repro.telemetry.counters.BridgeTelemetry`, ``obs_*`` for
span latencies, labels for per-tenant / per-QoS-class / per-tier /
per-link breakdowns:

    bridge_pages_served_total                    counter
    bridge_wire_pages_total{direction="cw"}      counter
    bridge_tier_hop_pages_total{tier="rack"}     counter
    bridge_tenant_pages_total{tenant="1",qos="interactive"}
    bridge_link_utilization{link="3"}            gauge (EWMA view)
    obs_span_latency_us{cat="round",name="pull"} histogram -> p50/p99

Histograms are log-bucketed (powers of ``growth`` from ``lo``), so one
static 32-bucket array spans 0.1 us .. ~3 min with bounded relative
error; quantiles interpolate geometrically inside the landing bucket.

:class:`SLOMonitor` tracks per-tenant round latencies against
``TenantSpec.slo_round_us`` and reports error-budget **burn rates**:
observed violation fraction over the window divided by the budgeted
violation fraction (burn > 1 means the tenant is eating budget faster
than sustainable).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Mapping[str, Any]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label_value(v: str) -> str:
    """Prometheus exposition escaping: backslash, double-quote, newline."""
    return (v.replace("\\", "\\\\")
             .replace('"', '\\"')
             .replace("\n", "\\n"))


def _render(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f'{k}="{_escape_label_value(v)}"' for k, v in key)
    return f"{name}{{{inner}}}"


@dataclass
class Counter:
    """Monotonically increasing count (pages, bytes, events)."""

    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        self.value += v


@dataclass
class Gauge:
    """Point-in-time value (utilizations, EWMA views, picks)."""

    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


@dataclass
class Histogram:
    """Log-bucketed histogram with geometric quantile interpolation.

    Bucket ``i`` holds values in ``[lo*growth**(i-1), lo*growth**i)``;
    bucket 0 is the underflow bin ``[0, lo)``.  Values above the last
    bound land in the overflow bin and quantiles clamp to the top bound.
    """

    lo: float = 0.1
    growth: float = 2.0
    num_buckets: int = 32
    counts: np.ndarray = field(default=None)  # type: ignore[assignment]
    total: float = 0.0
    count: int = 0

    def __post_init__(self):
        if self.counts is None:
            self.counts = np.zeros(self.num_buckets + 1, np.int64)
        self.bounds = self.lo * self.growth ** np.arange(self.num_buckets)

    def record(self, v: float) -> None:
        v = float(max(v, 0.0))
        idx = int(np.searchsorted(self.bounds, v, side="right"))
        self.counts[idx] += 1
        self.total += v
        self.count += 1

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return float("nan")
        if q >= 1.0:
            # clamp to the upper edge of the highest occupied bucket
            # instead of interpolating past the recorded range
            top = int(np.flatnonzero(self.counts)[-1])
            return float(self.bounds[min(top, self.num_buckets - 1)])
        target = q * self.count
        cum = np.cumsum(self.counts)
        idx = int(np.searchsorted(cum, target, side="left"))
        idx = min(idx, self.num_buckets)
        below = cum[idx - 1] if idx > 0 else 0
        frac = (target - below) / max(self.counts[idx], 1)
        frac = min(max(frac, 0.0), 1.0)
        upper = self.bounds[min(idx, self.num_buckets - 1)]
        lower = upper / self.growth if idx > 0 else 0.0
        if lower <= 0.0:
            return frac * upper
        return lower * (upper / lower) ** frac

    def p50(self) -> float:
        return self.quantile(0.50)

    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0


class MetricsRegistry:
    """Keyed store of metric families; the snapshot side of the plane."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelKey], Any] = {}

    def _get(self, kind, name: str, labels: Mapping[str, Any], **kw):
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = kind(**kw)
            self._metrics[key] = m
        elif not isinstance(m, kind):
            raise TypeError(f"{_render(*key)} already registered as "
                            f"{type(m).__name__}")
        return m

    # The family name is positional-only so labels may legally be called
    # "name" (obs_span_latency_us{name="..."} is the shipped convention).
    def counter(self, name: str, /, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, /, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, /, lo: float = 0.1, growth: float = 2.0,
                  num_buckets: int = 32, **labels) -> Histogram:
        return self._get(Histogram, name, labels, lo=lo, growth=growth,
                         num_buckets=num_buckets)

    # ------------------------------------------------------------ ingestion
    def observe_telemetry(self, telem, *, page_bytes: int = 0,
                          specs: Optional[Mapping[int, Any]] = None) -> None:
        """Fold one transfer's BridgeTelemetry into the counter families.

        ``specs`` maps tenant index -> TenantSpec so per-tenant counters
        carry the QoS class label; unknown tenants get qos="unknown".
        Counters stay integer-exact: each call adds that transfer's counts.
        """
        a = lambda x: np.asarray(x)  # noqa: E731
        served = int(a(telem.served_total()).sum())
        self.counter("bridge_pages_served_total").inc(served)
        self.counter("bridge_pages_loopback_total").inc(
            int(a(telem.loopback_served).sum()))
        self.counter("bridge_pages_spilled_total").inc(
            int(a(telem.spilled).sum()))
        self.counter("bridge_pages_pruned_total").inc(
            int(a(telem.pruned).sum()))
        cw, ccw = telem.wire_pages()
        cw, ccw = int(a(cw).sum()), int(a(ccw).sum())
        self.counter("bridge_wire_pages_total", direction="cw").inc(cw)
        self.counter("bridge_wire_pages_total", direction="ccw").inc(ccw)
        if page_bytes:
            self.counter("bridge_bytes_served_total").inc(
                served * page_bytes)
            self.counter("bridge_wire_bytes_total").inc(
                (cw + ccw) * page_bytes)
        hops = a(telem.tier_hops).reshape(-1, 2).sum(0)
        self.counter("bridge_tier_hop_pages_total", tier="board").inc(
            int(hops[0]))
        self.counter("bridge_tier_hop_pages_total", tier="rack").inc(
            int(hops[1]))
        mt = telem.max_tenants
        tser = a(telem.tenant_served).reshape(-1, mt).sum(0)
        tspill = a(telem.tenant_spilled).reshape(-1, mt).sum(0)
        tprune = a(telem.tenant_pruned).reshape(-1, mt).sum(0)
        specs = specs or {}
        for t in range(mt):
            if not (tser[t] or tspill[t] or tprune[t]):
                continue
            spec = specs.get(t)
            qos = getattr(spec, "qos", "unknown")
            lbl = dict(tenant=str(t), qos=qos)
            self.counter("bridge_tenant_pages_total", **lbl).inc(
                int(tser[t]))
            self.counter("bridge_tenant_spilled_total", **lbl).inc(
                int(tspill[t]))
            self.counter("bridge_tenant_pruned_total", **lbl).inc(
                int(tprune[t]))

    def observe_aggregator(self, agg) -> None:
        """Snapshot the EWMA aggregator views into gauge families."""
        # spill/drop rates are per-node; the gauge carries the fleet mean.
        self.gauge("bridge_spill_rate").set(float(np.mean(agg.spill_rate())))
        self.gauge("bridge_drop_rate").set(float(np.mean(agg.drop_rate())))
        for direction, u in agg.link_utilization().items():
            self.gauge("bridge_link_utilization",
                       direction=direction).set(float(u))
        for tier, u in agg.tier_utilization().items():
            self.gauge("bridge_tier_utilization", tier=tier).set(float(u))
        demand = np.asarray(agg.tenant_demand())
        for t, d in enumerate(demand.tolist()):
            if d:
                self.gauge("bridge_tenant_demand_pages",
                           tenant=str(t)).set(float(d))

    def observe_span(self, span) -> None:
        """Record a closed span's latency into the span histogram family."""
        self.histogram("obs_span_latency_us", cat=span.cat,
                       name=span.name).record(span.duration_us)

    # -------------------------------------------------------------- export
    def family_quantiles(self, name: str, /, label: str = "qos"
                         ) -> Dict[str, Dict[str, float]]:
        """Quantile summary of one histogram family, keyed by a label.

        Returns ``{label_value: {count, mean, p50, p99}}`` — e.g. the
        per-QoS-class p50/p99 round latencies the serve bench reports
        (``family_quantiles("serve_request_latency_us")``).  Series
        missing the label key under an empty string.
        """
        out: Dict[str, Dict[str, float]] = {}
        for (n, key), m in sorted(self._metrics.items()):
            if n != name or not isinstance(m, Histogram):
                continue
            out[dict(key).get(label, "")] = {
                "count": m.count, "mean": m.mean,
                "p50": m.p50(), "p99": m.p99()}
        return out

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
        for (name, key), m in sorted(self._metrics.items()):
            label = _render(name, key)
            if isinstance(m, Counter):
                out["counters"][label] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][label] = m.value
            else:
                out["histograms"][label] = {
                    "count": m.count, "sum": round(m.total, 3),
                    "mean": round(m.mean, 3),
                    "p50": round(m.p50(), 3), "p99": round(m.p99(), 3)}
        return out

    def to_text(self) -> str:
        """Prometheus-flavoured text exposition (deterministic order)."""
        lines: List[str] = []
        for (name, key), m in sorted(self._metrics.items()):
            label = _render(name, key)
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"{label} {m.value:g}")
            else:
                base, br = (name, label[len(name):])
                lines.append(f"{base}_count{br} {m.count}")
                lines.append(f"{base}_sum{br} {m.total:g}")
                lines.append(f"{base}_p50{br} {m.p50():g}")
                lines.append(f"{base}_p99{br} {m.p99():g}")
        return "\n".join(lines)


@dataclass
class _TenantSLO:
    slo_us: float
    window: deque


class SLOMonitor:
    """Per-tenant SLO violation tracking and error-budget burn rates.

    ``record(tenant, latency_us, slo_us)`` appends one observation (a
    measured or predicted round/window latency vs the tenant's
    ``TenantSpec.slo_round_us``).  ``burn_rate`` is the windowed
    violation fraction over the budgeted fraction — 1.0 means burning
    exactly the allowed budget, >1 unsustainable, 0 no violations.
    """

    def __init__(self, *, window: int = 256,
                 budget_fraction: float = 0.01,
                 registry: Optional[MetricsRegistry] = None):
        self.window = int(window)
        self.budget_fraction = float(budget_fraction)
        self.registry = registry
        self._tenants: Dict[int, _TenantSLO] = {}

    def record(self, tenant_id: int, latency_us: float,
               slo_us: float) -> None:
        st = self._tenants.get(tenant_id)
        if st is None:
            st = _TenantSLO(slo_us=float(slo_us),
                            window=deque(maxlen=self.window))
            self._tenants[tenant_id] = st
        st.slo_us = float(slo_us)
        st.window.append(bool(slo_us > 0 and latency_us > slo_us))
        if self.registry is not None:
            self.registry.gauge("slo_burn_rate",
                                tenant=str(tenant_id)).set(
                self.burn_rate(tenant_id))

    def violation_fraction(self, tenant_id: int) -> float:
        st = self._tenants.get(tenant_id)
        if st is None or not st.window:
            return 0.0
        return sum(st.window) / len(st.window)

    def burn_rate(self, tenant_id: int) -> float:
        st = self._tenants.get(tenant_id)
        if st is None or not st.window or self.budget_fraction <= 0:
            return 0.0
        return self.violation_fraction(tenant_id) / self.budget_fraction

    def describe(self) -> Dict[str, Any]:
        return {
            str(t): {
                "slo_us": st.slo_us,
                "samples": len(st.window),
                "violations": int(sum(st.window)),
                "violation_fraction": round(
                    self.violation_fraction(t), 4),
                "burn_rate": round(self.burn_rate(t), 3),
            }
            for t, st in sorted(self._tenants.items())
        }
