"""Online anomaly / drift sentinel over the metrics plane.

Watches the live measure->fit->steer loop for the failure modes a
calibrated control plane is blind to on its own:

* **latency shift** — the windowed median of measured-over-predicted
  round latency (prediction from the fitted
  :class:`~repro.core.perfmodel.Calibrator`) drifting past a factor
  threshold: the fabric got slower than the model steering it believes;
* **calibration-residual drift** — the windowed mean RLS residual
  climbing well above its healthy baseline: the fitted constants no
  longer describe the fabric.  The sentinel then *re-opens* the RLS
  covariance (:meth:`Calibrator.reset_covariance`) so the fit re-converges
  quickly, and journals the refit;
* **SLO burn** — a tenant's error-budget burn rate crossing an
  enter/clear hysteresis band (alert on the transition, not per sample);
* **telemetry conservation** — invariants the aggregator's linear EWMA
  folds preserve exactly by construction (``served = loopback +
  distance_pages`` in total, ``served >= loopback`` per node,
  non-negative finite counters).  A violation means an accounting bug,
  never load.

Every :class:`Alert` is appended to :attr:`Sentinel.alerts`, counted in
the ``obs_alerts_total{kind=...}`` counter family, and journaled as an
``alert`` :class:`~repro.obs.flight.DecisionRecord` when a flight
recorder is attached.  All detectors carry hysteresis so a sustained
anomaly raises one alert, not one per sample.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class Alert:
    """One sentinel finding (also journaled + counted when attached)."""

    kind: str          # "latency_shift" / "calibration_drift" / ...
    severity: str      # "warn" | "critical"
    message: str
    value: float       # the observed statistic
    threshold: float   # the threshold it crossed


class Sentinel:
    """Windowed detectors over latency ratios, residuals, SLOs, telemetry.

    ``window`` is the detection window: a sustained anomaly is flagged
    within at most ``window`` observations of its onset (the bench's
    injected 2x regression trips the median-ratio detector after about
    ``window/2 + 1`` samples).
    """

    def __init__(self, *, registry=None, flight=None, calibrator=None,
                 slo=None, window: int = 16,
                 shift_factor: float = 1.5, shift_clear: float = 1.2,
                 drift_factor: float = 4.0, drift_floor_us: float = 50.0,
                 burn_on: float = 2.0, burn_off: float = 1.0,
                 min_slo_samples: int = 8):
        self.registry = registry
        self.flight = flight
        self.calibrator = calibrator
        self.slo = slo
        self.window = int(window)
        self.shift_factor = float(shift_factor)
        self.shift_clear = float(shift_clear)
        self.drift_factor = float(drift_factor)
        self.drift_floor_us = float(drift_floor_us)
        self.burn_on = float(burn_on)
        self.burn_off = float(burn_off)
        self.min_slo_samples = int(min_slo_samples)
        self.alerts: List[Alert] = []
        self._ratios: deque = deque(maxlen=self.window)
        self._residuals: deque = deque(maxlen=self.window)
        self._resid_baseline: Optional[float] = None
        self._shift_alarm = False
        self._drift_alarm = False
        self._burn_alarm: Dict[int, bool] = {}

    # ----------------------------------------------------------------- emit
    def _emit(self, kind: str, severity: str, message: str, value: float,
              threshold: float) -> Alert:
        a = Alert(kind=kind, severity=severity, message=message,
                  value=float(value), threshold=float(threshold))
        self.alerts.append(a)
        if self.registry is not None:
            self.registry.counter("obs_alerts_total", kind=kind).inc()
        if self.flight is not None:
            self.flight.record("alert", alert_kind=kind, severity=severity,
                               message=message, value=float(value),
                               threshold=float(threshold))
        return a

    # ------------------------------------------------------------- latency
    def observe_latency(self, measured_us: float,
                        predicted_us: Optional[float] = None,
                        residual_us: Optional[float] = None) -> List[Alert]:
        """Feed one per-round measured latency (+ the calibrator's pre-fit
        prediction for it, when fitted).  Returns alerts raised now."""
        new: List[Alert] = []
        if predicted_us is not None and predicted_us > 0:
            self._ratios.append(float(measured_us) / float(predicted_us))
            if len(self._ratios) == self.window:
                med = float(np.median(self._ratios))
                if not self._shift_alarm and med > self.shift_factor:
                    self._shift_alarm = True
                    new.append(self._emit(
                        "latency_shift", "critical",
                        f"windowed median measured/predicted latency "
                        f"{med:.2f}x exceeds {self.shift_factor:g}x",
                        med, self.shift_factor))
                elif self._shift_alarm and med < self.shift_clear:
                    self._shift_alarm = False
        if residual_us is not None:
            new.extend(self._observe_residual(abs(float(residual_us))))
        return new

    def _observe_residual(self, resid_us: float) -> List[Alert]:
        self._residuals.append(resid_us)
        if len(self._residuals) < self.window:
            return []
        mean = float(np.mean(self._residuals))
        if self._resid_baseline is None:
            self._resid_baseline = mean
            return []
        threshold = max(self.drift_factor * self._resid_baseline,
                        self.drift_floor_us)
        if not self._drift_alarm and mean > threshold:
            self._drift_alarm = True
            a = self._emit(
                "calibration_drift", "warn",
                f"windowed mean RLS residual {mean:.1f}us exceeds "
                f"{threshold:.1f}us (baseline {self._resid_baseline:.1f}us)",
                mean, threshold)
            # The fitted constants no longer describe the fabric: re-open
            # the RLS gain so the next window re-converges, and journal
            # the triggered refit so replay/postmortems see it.
            if (self.calibrator is not None
                    and hasattr(self.calibrator, "reset_covariance")):
                self.calibrator.reset_covariance()
                if self.flight is not None:
                    self.flight.record("calibrator_refit",
                                       residual_us=mean,
                                       baseline_us=self._resid_baseline)
            self._residuals.clear()
            return [a]
        if self._drift_alarm and mean <= threshold:
            self._drift_alarm = False
        if not self._drift_alarm:
            # healthy: track the baseline slowly (EWMA over window means)
            self._resid_baseline = (0.9 * self._resid_baseline + 0.1 * mean)
        return []

    # ----------------------------------------------------------------- SLOs
    def check_slo(self) -> List[Alert]:
        """Burn-rate hysteresis over the attached SLOMonitor's tenants."""
        if self.slo is None:
            return []
        new: List[Alert] = []
        for tid_s, st in self.slo.describe().items():
            tid = int(tid_s)
            if st["samples"] < self.min_slo_samples:
                continue
            burn = float(st["burn_rate"])
            alarm = self._burn_alarm.get(tid, False)
            if not alarm and burn >= self.burn_on:
                self._burn_alarm[tid] = True
                new.append(self._emit(
                    "slo_burn", "critical",
                    f"tenant {tid} burn rate {burn:.2f} >= "
                    f"{self.burn_on:g} ({st['violations']}/{st['samples']} "
                    f"over {st['slo_us']:g}us)", burn, self.burn_on))
            elif alarm and burn <= self.burn_off:
                self._burn_alarm[tid] = False
        return new

    # ------------------------------------------------------------ telemetry
    def check_telemetry(self, agg) -> List[Alert]:
        """Conservation invariants of the aggregator's EWMA folds."""
        new: List[Alert] = []
        served = np.asarray(agg.served, float)
        loop = np.asarray(agg.loopback, float)
        dist = np.asarray(agg.distance_pages(), float)
        fields = {"served": served, "loopback": loop, "distance_pages": dist,
                  "spilled": np.asarray(agg.spilled, float),
                  "tenant_served": np.asarray(agg.tenant_served, float)}
        for name, arr in fields.items():
            if not np.all(np.isfinite(arr)) or np.any(arr < -1e-6):
                new.append(self._emit(
                    "conservation", "critical",
                    f"telemetry counter {name} is negative or non-finite",
                    float(np.min(arr)) if arr.size else 0.0, 0.0))
                return new
        # served folds loopback + per-distance slot pages of the same
        # steps with the same linear EWMA, so the totals agree exactly
        # (up to float rounding) — and served >= loopback per node.
        tot_served, tot_parts = float(served.sum()), float(
            loop.sum() + dist.sum())
        tol = 1e-6 * max(tot_served, 1.0)
        if abs(tot_served - tot_parts) > tol:
            new.append(self._emit(
                "conservation", "critical",
                f"served total {tot_served:.6f} != loopback + distance "
                f"pages {tot_parts:.6f}", tot_served - tot_parts, tol))
        if np.any(served + 1e-6 < loop):
            node = int(np.argmax(loop - served))
            new.append(self._emit(
                "conservation", "critical",
                f"node {node} loopback exceeds served",
                float((loop - served)[node]), 0.0))
        return new

    # ---------------------------------------------------------- introspect
    def describe(self) -> Dict[str, Any]:
        return {
            "alerts": len(self.alerts),
            "window": self.window,
            "shift_alarm": self._shift_alarm,
            "drift_alarm": self._drift_alarm,
            "burn_alarms": sorted(t for t, on in self._burn_alarm.items()
                                  if on),
            "resid_baseline_us": self._resid_baseline,
        }


__all__ = ["Alert", "Sentinel"]
