"""repro.obs — tracing and metrics plane over the in-band telemetry.

Three layers, host-side only (nothing here runs under jit):

- ``clock``: injectable monotonic clocks (wall for production, manual for
  deterministic tests).
- ``trace``: ``TraceRecorder`` wraps jitted datapath calls in fenced
  wall-clock spans (transaction -> round -> chunk -> phase), decorates
  them with the matching ``BridgeTelemetry`` counters, and exports
  Chrome-trace/Perfetto JSON.
- ``metrics``: counter/gauge/log-bucketed-histogram registry with
  per-tenant / per-QoS / per-tier families fed by ``TelemetryAggregator``
  and spans, plus an SLO burn-rate monitor.

The measured span latencies feed ``repro.core.perfmodel.Calibrator`` so
control-plane decisions run on fitted, not guessed, constants.
"""

from repro.obs.clock import Clock, ManualClock, MonotonicClock
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    SLOMonitor,
)
from repro.obs.trace import Span, TraceRecorder, phase_op_counts

__all__ = [
    "Clock",
    "ManualClock",
    "MonotonicClock",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SLOMonitor",
    "Span",
    "TraceRecorder",
    "phase_op_counts",
]
