"""repro.obs — tracing and metrics plane over the in-band telemetry.

Three layers, host-side only (nothing here runs under jit):

- ``clock``: injectable monotonic clocks (wall for production, manual for
  deterministic tests).
- ``trace``: ``TraceRecorder`` wraps jitted datapath calls in fenced
  wall-clock spans (transaction -> round -> chunk -> phase), decorates
  them with the matching ``BridgeTelemetry`` counters, and exports
  Chrome-trace/Perfetto JSON.
- ``metrics``: counter/gauge/log-bucketed-histogram registry with
  per-tenant / per-QoS / per-tier families fed by ``TelemetryAggregator``
  and spans, plus an SLO burn-rate monitor.
- ``flight``: the decision plane — ``FlightRecorder`` journals every
  control-plane action as a typed ``DecisionRecord`` (JSONL in/out) and
  ``replay()`` re-executes a journal bit-identically against a fresh
  control plane; ``why(request_id)`` walks the causal chain behind one
  serving request.
- ``detect``: the ``Sentinel`` — online latency-shift / calibration-drift
  / SLO-burn / telemetry-conservation detectors emitting ``Alert``
  records into the journal and ``obs_alerts_total`` counters.

The measured span latencies feed ``repro.core.perfmodel.Calibrator`` so
control-plane decisions run on fitted, not guessed, constants.
"""

from repro.obs.clock import Clock, ManualClock, MonotonicClock
from repro.obs.detect import Alert, Sentinel
from repro.obs.flight import (
    DecisionRecord,
    FlightRecorder,
    JournalError,
    JournalTruncatedError,
    ReplayDivergenceError,
    ReplayResult,
    program_digest,
    replay,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    SLOMonitor,
)
from repro.obs.trace import Span, TraceRecorder, phase_op_counts

__all__ = [
    "Alert",
    "Clock",
    "DecisionRecord",
    "FlightRecorder",
    "JournalError",
    "JournalTruncatedError",
    "ManualClock",
    "MonotonicClock",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ReplayDivergenceError",
    "ReplayResult",
    "SLOMonitor",
    "Sentinel",
    "Span",
    "TraceRecorder",
    "phase_op_counts",
    "program_digest",
    "replay",
]
