"""Control-plane flight recorder: journal every decision, replay it later.

A :class:`FlightRecorder` is an append-only bounded journal of typed
:class:`DecisionRecord`\\ s — one per control-plane action: route-program
installs (with variant + verifier digest), ``select_channels`` picks (with
the calibrator inputs that priced them), allocate/release/migration plans,
admission admit/queue/reject/evict verdicts, scheduler window refits,
lease grant/renew/expiry, node fail/revive, and sentinel alerts
(:mod:`repro.obs.detect`).  Each record is stamped with a monotonic
sequence number, an :class:`~repro.obs.clock.Clock` timestamp, and causal
refs: the trace span open when the decision was taken and the telemetry
epoch (aggregator fold count) that motivated it.

Two things fall out of journaling *inputs*, not just outputs:

* :func:`replay` re-executes a journal against a fresh
  :class:`~repro.core.control_plane.ControlPlane` / scheduler and asserts
  the resulting :class:`~repro.core.steering.RouteProgram` digests,
  placements and window schedules are **bit-identical** — a postmortem
  journal is a reproducible test.  Divergence raises
  :class:`ReplayDivergenceError`; a cut-off or corrupted journal raises
  :class:`JournalTruncatedError` at load time instead of silently
  replaying a prefix.
* :meth:`FlightRecorder.why` walks the causal refs backwards from a
  serving request id to the admission verdict, lease grant, page
  placement and the route program governing its traffic.

The JSONL export ends in a ``journal_seal`` line (record count + seq
range) so truncation is detectable; decision payloads are plain JSON
(numpy arrays listed, route programs via :func:`program_to_dict`).
"""

from __future__ import annotations

import hashlib
import json
from collections import deque
from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from repro.obs.clock import Clock, MonotonicClock


class JournalError(RuntimeError):
    """Base class for flight-journal failures."""


class JournalTruncatedError(JournalError):
    """The journal is cut off, corrupted, or missing its seal/genesis."""


class ReplayDivergenceError(JournalError):
    """Re-execution produced a different program/placement/schedule."""


# --------------------------------------------------------------------- records
@dataclass
class DecisionRecord:
    """One journaled control-plane decision."""

    seq: int                      # monotonic per-recorder sequence number
    t_us: float                   # obs.Clock timestamp
    kind: str                     # "allocate" / "route_program" / ...
    detail: Dict[str, Any] = field(default_factory=dict)
    span_id: Optional[int] = None    # trace span open when decided
    epoch: int = 0                   # telemetry epoch (aggregator folds)
    request_id: Optional[int] = None  # serving request this decision served

    def to_json(self) -> Dict[str, Any]:
        return {"seq": self.seq, "t_us": self.t_us, "kind": self.kind,
                "span_id": self.span_id, "epoch": self.epoch,
                "request_id": self.request_id, "detail": self.detail}

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "DecisionRecord":
        return DecisionRecord(
            seq=int(d["seq"]), t_us=float(d["t_us"]), kind=str(d["kind"]),
            detail=dict(d.get("detail") or {}), span_id=d.get("span_id"),
            epoch=int(d.get("epoch", 0)), request_id=d.get("request_id"))


def _jsonable(v):
    """Deep-convert numpy scalars/arrays so the journal is plain JSON."""
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


# ----------------------------------------------------------- program serde
#: (field, numpy dtype) normalization used by both the digest and the
#: JSON round-trip — matches steering._program's construction dtypes.
_PROGRAM_FIELDS = (("offsets", np.int32), ("epoch", np.int32),
                   ("live", np.bool_), ("rank_epoch", np.int32))


def program_to_dict(program) -> Dict[str, Any]:
    """Serialize a RouteProgram's arrays to plain JSON lists."""
    return {name: np.asarray(getattr(program, name), dtype).tolist()
            for name, dtype in _PROGRAM_FIELDS}


def program_from_dict(d: Dict[str, Any]):
    """Rebuild a RouteProgram with the canonical jnp dtypes."""
    import jax.numpy as jnp

    from repro.core.steering import RouteProgram

    return RouteProgram(
        offsets=jnp.asarray(d["offsets"], jnp.int32),
        epoch=jnp.asarray(d["epoch"], jnp.int32),
        live=jnp.asarray(d["live"], bool),
        rank_epoch=jnp.asarray(d["rank_epoch"], jnp.int32))


def program_digest(program) -> str:
    """sha256 over the program's dtype-normalized array bytes.

    Bit-identical programs — and only those — share a digest; this is the
    verifier-install fingerprint the journal records and replay asserts.
    """
    h = hashlib.sha256()
    for name, dtype in _PROGRAM_FIELDS:
        a = np.ascontiguousarray(np.asarray(getattr(program, name), dtype))
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


def route_variant(*, compiled: bool, hierarchical: bool, failed_link: bool,
                  bidirectional: bool, measured: bool) -> str:
    """Human label for which compile branch produced a route program."""
    if not compiled:
        return "installed"
    if hierarchical and bidirectional and not failed_link:
        return "hierarchical"
    if failed_link:
        return "link_avoiding"
    if measured and bidirectional:
        return "load_balanced"
    return "bidirectional" if bidirectional else "unidirectional"


# --------------------------------------------- telemetry/calibrator snapshots
# The control plane journals the *exact read-set* of each decision — the
# few aggregator views it consumed — so replay can rebuild an equivalent
# shim without re-running the datapath.

def route_telemetry_snapshot(telemetry) -> Optional[Dict[str, Any]]:
    """The read-set of ``ControlPlane._compile_route_program``."""
    if telemetry is None:
        return None
    dist = np.asarray(telemetry.distance_pages()
                      if hasattr(telemetry, "distance_pages")
                      else telemetry, float).reshape(-1)
    drops = 0.0
    for names in (("last_spilled", "last_pruned"), ("spilled", "pruned")):
        if any(hasattr(telemetry, f) for f in names):
            drops = sum(float(np.asarray(getattr(telemetry, f)).sum())
                        for f in names if hasattr(telemetry, f))
            break
    intra = (np.asarray(telemetry.distance_intra_pages(),
                        float).reshape(-1).tolist()
             if hasattr(telemetry, "distance_intra_pages") else None)
    return {"dist": dist.tolist(), "drops": drops, "dist_intra": intra}


def route_telemetry_shim(snap: Optional[Dict[str, Any]]):
    """An aggregator stand-in reproducing a journaled compile read-set."""
    if snap is None:
        return None
    dist = np.asarray(snap["dist"], float)
    shim = SimpleNamespace(
        distance_pages=lambda: dist,
        last_spilled=np.asarray([float(snap.get("drops", 0.0))]),
        last_pruned=np.zeros((1,)))
    if snap.get("dist_intra") is not None:
        intra = np.asarray(snap["dist_intra"], float)
        shim.distance_intra_pages = lambda: intra
    return shim


def wire_telemetry_snapshot(telemetry) -> Optional[Dict[str, Any]]:
    """The read-set of ``ControlPlane.select_channels``."""
    if telemetry is None:
        return None
    if hasattr(telemetry, "link_pages"):          # TelemetryAggregator
        lp = telemetry.link_pages()
        cw, ccw = float(lp["cw"]), float(lp["ccw"])
        dist = np.asarray(telemetry.distance_pages(), float)
        served = np.asarray(telemetry.served, float)
    else:                                         # raw BridgeTelemetry
        cw = float(np.asarray(telemetry.epoch_cw).sum())
        ccw = float(np.asarray(telemetry.epoch_ccw).sum())
        s = np.asarray(telemetry.slot_served)
        dist = s.reshape((-1, s.shape[-1])).sum(0).astype(float)
        served = np.asarray(telemetry.served_total(), float).reshape(-1)
    return {"cw": cw, "ccw": ccw, "dist": dist.tolist(),
            "served": served.tolist()}


def wire_telemetry_shim(snap: Optional[Dict[str, Any]]):
    if snap is None:
        return None
    dist = np.asarray(snap["dist"], float)
    return SimpleNamespace(
        link_pages=lambda: {"cw": float(snap["cw"]),
                            "ccw": float(snap["ccw"])},
        distance_pages=lambda: dist,
        served=np.asarray(snap["served"], float))


def calibrator_snapshot(calibrator) -> Optional[Dict[str, Any]]:
    """The read-set of ``select_channels``'s calibrator pricing."""
    if calibrator is None:
        return None
    if not calibrator.fitted:
        return {"fitted": False}
    hw = calibrator.hw()
    return {"fitted": True,
            "hop_us": float(hw.ici_hop_latency_us),
            "link_gbps": float(hw.ici_link_gbps),
            "chunk_us": float(calibrator.chunk_overhead_us)}


def calibrator_shim(snap: Optional[Dict[str, Any]]):
    if snap is None:
        return None
    if not snap.get("fitted"):
        return SimpleNamespace(fitted=False)
    return SimpleNamespace(
        fitted=True,
        hw=lambda: SimpleNamespace(
            ici_hop_latency_us=float(snap["hop_us"]),
            ici_link_gbps=float(snap["link_gbps"])),
        chunk_overhead_us=float(snap["chunk_us"]))


# ------------------------------------------------------------------ recorder
class FlightRecorder:
    """Append-only bounded journal of control-plane decisions.

    ``capacity`` bounds memory: the oldest records fall off (counted in
    :attr:`dropped_total`) — a journal whose genesis ``cp_init`` record was
    dropped refuses to replay.  ``trace=`` links each record to the trace
    span open at decision time; :attr:`epoch` is stamped by the owner
    (the orchestrator sets it to the aggregator's fold count).
    """

    def __init__(self, clock: Optional[Clock] = None, *,
                 capacity: int = 65536, trace=None):
        self.clock = clock if clock is not None else MonotonicClock()
        self.capacity = int(capacity)
        self.trace = trace
        self.epoch = 0
        self.dropped_total = 0
        self._records: deque = deque()
        self._next_seq = 0

    def __len__(self) -> int:
        return len(self._records)

    # ---------------------------------------------------------------- append
    def record(self, kind: str, *, request_id: Optional[int] = None,
               **detail) -> DecisionRecord:
        span_id = None
        if self.trace is not None and getattr(self.trace, "_stack", None):
            span_id = self.trace._stack[-1].span_id
        rec = DecisionRecord(
            seq=self._next_seq, t_us=float(self.clock.now_us()), kind=kind,
            detail={k: _jsonable(v) for k, v in detail.items()},
            span_id=span_id, epoch=self.epoch, request_id=request_id)
        self._next_seq += 1
        self._records.append(rec)
        if len(self._records) > self.capacity:
            self._records.popleft()
            self.dropped_total += 1
        return rec

    # --------------------------------------------------------------- queries
    def records(self, kind: Optional[str] = None) -> List[DecisionRecord]:
        if kind is None:
            return list(self._records)
        return [r for r in self._records if r.kind == kind]

    def for_request(self, request_id: int) -> List[DecisionRecord]:
        return [r for r in self._records if r.request_id == request_id]

    def why(self, request_id: int) -> List[DecisionRecord]:
        """The causal chain behind one serving request, in seq order.

        Directly-stamped records (admission verdict, lease grant/release)
        plus the decisions they reference: the allocate/release of the
        lease's region and the route-program install governing the bridge
        when the request was admitted.
        """
        own = [r for r in self._records if r.request_id == request_id]
        if not own:
            return []
        out = {r.seq: r for r in own}
        region_ids = {r.detail["region_id"] for r in own
                      if "region_id" in r.detail}
        first_seq = min(out)
        governing = None
        for r in self._records:
            if (r.kind in ("allocate", "release")
                    and r.detail.get("region_id") in region_ids):
                out[r.seq] = r
            if r.kind == "route_program" and r.seq < first_seq:
                governing = r
        if governing is not None:
            out[governing.seq] = governing
        return [out[s] for s in sorted(out)]

    # ----------------------------------------------------------------- JSONL
    def to_jsonl(self) -> str:
        lines = [json.dumps(r.to_json(), sort_keys=True)
                 for r in self._records]
        first = self._records[0].seq if self._records else 0
        last = self._records[-1].seq if self._records else -1
        lines.append(json.dumps(
            {"kind": "journal_seal", "count": len(self._records),
             "first_seq": first, "last_seq": last,
             "dropped": self.dropped_total}, sort_keys=True))
        return "\n".join(lines) + "\n"

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_jsonl())

    @classmethod
    def from_jsonl(cls, text: str, *, clock: Optional[Clock] = None
                   ) -> "FlightRecorder":
        """Parse a JSONL journal; raises :class:`JournalTruncatedError`
        on a missing/wrong seal, a seq gap, or undecodable lines."""
        recs: List[DecisionRecord] = []
        seal = None
        for i, line in enumerate(text.splitlines()):
            if not line.strip():
                continue
            if seal is not None:
                raise JournalTruncatedError(
                    f"line {i}: records after the journal seal")
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise JournalTruncatedError(
                    f"line {i}: undecodable journal line ({e})") from None
            if d.get("kind") == "journal_seal":
                seal = d
                continue
            try:
                recs.append(DecisionRecord.from_json(d))
            except (KeyError, TypeError, ValueError) as e:
                raise JournalTruncatedError(
                    f"line {i}: malformed record ({e})") from None
        if seal is None:
            raise JournalTruncatedError("journal has no seal (truncated?)")
        if seal.get("count") != len(recs):
            raise JournalTruncatedError(
                f"seal says {seal.get('count')} records, found {len(recs)}")
        if recs:
            if (seal.get("first_seq") != recs[0].seq
                    or seal.get("last_seq") != recs[-1].seq):
                raise JournalTruncatedError("seal seq range mismatch")
            for a, b in zip(recs, recs[1:]):
                if b.seq != a.seq + 1:
                    raise JournalTruncatedError(
                        f"seq gap: {a.seq} -> {b.seq}")
        out = cls(clock=clock, capacity=max(len(recs), 1))
        out._records.extend(recs)
        out._next_seq = (recs[-1].seq + 1) if recs else 0
        out.dropped_total = int(seal.get("dropped", 0))
        return out

    @classmethod
    def load(cls, path: str, *, clock: Optional[Clock] = None
             ) -> "FlightRecorder":
        with open(path) as f:
            return cls.from_jsonl(f.read(), clock=clock)


# -------------------------------------------------------------------- replay
@dataclass
class ReplayResult:
    """What :func:`replay` re-executed and verified."""

    ops: int = 0
    programs: int = 0
    placements: int = 0
    releases: int = 0
    channel_picks: int = 0
    migrations: int = 0
    refits: int = 0
    failures: int = 0
    placement_digest: str = ""
    plane: Any = None


def _serialize_plan(plan) -> List[List[int]]:
    return [[int(s.page_id), int(s.old_home), int(s.old_slot),
             int(s.new_home), int(s.new_slot)] for s in plan]


def _diverge(rec: DecisionRecord, what: str, want, got):
    raise ReplayDivergenceError(
        f"replay diverged at seq {rec.seq} ({rec.kind}): {what} "
        f"recorded {want!r}, replayed {got!r}")


def _build_plane(detail: Dict[str, Any]):
    from repro.core.control_plane import ControlPlane
    from repro.core.topology import Topology

    hw = detail.get("topo_hw") or []
    kw = dict(zip(("board_hop_us", "rack_hop_us",
                   "board_link_gbps", "rack_link_gbps"), hw))
    topo = Topology.from_sizes(detail["group_sizes"], **kw)
    return ControlPlane(int(detail["num_nodes"]),
                        int(detail["pages_per_node"]),
                        int(detail["num_logical"]),
                        seed=int(detail.get("seed", 0)), topology=topo)


def _restore_state(cp, state: Dict[str, Any]) -> Dict[int, Any]:
    """Restore a cp_init placement snapshot; returns live region handles."""
    from repro.core.control_plane import Region

    cp._home = np.asarray(state["home"], np.int64)
    cp._slot = np.asarray(state["slot"], np.int64)
    cp._free = [list(map(int, f)) for f in state["free"]]
    cp._free_logical = list(map(int, state["free_logical"]))
    cp._next_logical = int(state["next_logical"])
    cp._next_region = int(state["next_region"])
    for node, alive in zip(cp.nodes, state["alive"]):
        node.alive = bool(alive)
    cp._failed_link_direction = state.get("failed_link")
    if state.get("rng_state") is not None:
        cp._rng.bit_generator.state = state["rng_state"]
    cp._regions = {}
    regions: Dict[int, Any] = {}
    for rid_s, r in (state.get("regions") or {}).items():
        reg = Region(int(rid_s), r["name"],
                     np.asarray(r["page_ids"], np.int64), r["policy"])
        cp._regions[reg.region_id] = reg
        regions[reg.region_id] = reg
    return regions


def placement_digest(cp) -> str:
    """sha256 over the placement table (logical -> home/slot)."""
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(cp._home, np.int64).tobytes())
    h.update(np.ascontiguousarray(cp._slot, np.int64).tobytes())
    return h.hexdigest()[:16]


def replay(journal, pool=None, topology=None) -> ReplayResult:
    """Re-execute a journal against a fresh control plane; assert equality.

    ``journal`` is a :class:`FlightRecorder`, an iterable of
    :class:`DecisionRecord`, or a path to a JSONL file.  The journal must
    begin with the ``cp_init`` genesis record (a bounded journal that
    dropped it cannot replay).  ``pool``/``topology`` override the
    re-executed plane (for what-if replays); by default the genesis
    snapshot rebuilds it exactly.

    Every effectful record is re-executed and compared bit-for-bit:
    allocations (page ids, homes, slots), releases, failure remap plans,
    route-program digests, channel picks, migration plans, and scheduler
    window refits.  Verdict-only records (admission, lease lifecycle,
    alerts) are causal metadata — their placement effects replay through
    the allocate/release records they reference.
    """
    from repro.orchestrator.scheduler import WeightedFairScheduler
    from repro.orchestrator.tenants import TenantSpec

    if isinstance(journal, str):
        journal = FlightRecorder.load(journal)
    records = (journal.records() if isinstance(journal, FlightRecorder)
               else list(journal))
    if not records:
        raise JournalTruncatedError("empty journal")
    if records[0].kind != "cp_init":
        raise JournalTruncatedError(
            f"journal does not begin with cp_init (first record is "
            f"{records[0].kind!r} at seq {records[0].seq}; genesis dropped?)")

    res = ReplayResult()
    cp = pool
    regions: Dict[int, Any] = {}
    specs: List[TenantSpec] = []
    for rec in records:
        d = rec.detail
        res.ops += 1
        if rec.kind == "cp_init":
            if cp is None:
                cp = _build_plane(d) if topology is None else None
                if cp is None:
                    from repro.core.control_plane import ControlPlane
                    cp = ControlPlane(
                        int(d["num_nodes"]), int(d["pages_per_node"]),
                        int(d["num_logical"]), seed=int(d.get("seed", 0)),
                        topology=topology)
            regions = _restore_state(cp, d["state"])
        elif cp is None:
            raise JournalTruncatedError(
                f"record {rec.kind!r} at seq {rec.seq} before cp_init")
        elif rec.kind == "allocate":
            reg = cp.allocate(int(d["num_pages"]), name=d.get("name", ""),
                              policy=d["policy"],
                              affinity=int(d.get("affinity", 0)))
            got = {"region_id": reg.region_id,
                   "page_ids": np.asarray(reg.page_ids).tolist(),
                   "homes": [int(cp._home[i]) for i in reg.page_ids],
                   "slots": [int(cp._slot[i]) for i in reg.page_ids]}
            for k, v in got.items():
                if v != d[k]:
                    _diverge(rec, k, d[k], v)
            regions[reg.region_id] = reg
            res.placements += 1
        elif rec.kind == "release":
            reg = regions.pop(int(d["region_id"]), None)
            if reg is None:
                _diverge(rec, "region", d["region_id"], None)
            cp.release(reg)
            res.releases += 1
        elif rec.kind == "fail_node":
            plan = _serialize_plan(cp.fail_node(int(d["node"])))
            if plan != d["plan"]:
                _diverge(rec, "remap plan", d["plan"], plan)
            res.failures += 1
        elif rec.kind == "revive_node":
            cp.revive_node(int(d["node"]))
        elif rec.kind == "link_failure":
            cp.report_link_failure(int(d["direction"]))
        elif rec.kind == "link_clear":
            cp.clear_link_failure()
        elif rec.kind == "route_program":
            if d["compiled"]:
                prog = cp.route_program(
                    requesters=d.get("requesters"),
                    bidirectional=d["bidirectional"], prune=d["prune"],
                    telemetry=route_telemetry_shim(d.get("telemetry")),
                    verify=d.get("verified", True))
            else:
                prog = cp.route_program(
                    program=program_from_dict(d["program"]),
                    verify=d.get("verified", True))
            got = program_digest(prog)
            if got != d["digest"]:
                _diverge(rec, "program digest", d["digest"], got)
            res.programs += 1
        elif rec.kind == "select_channels":
            prog = (program_from_dict(d["program"])
                    if d.get("program") is not None else None)
            pick = cp.select_channels(
                int(d["budget"]), int(d["page_bytes"]),
                telemetry=wire_telemetry_shim(d.get("telemetry")),
                max_channels=int(d["max_channels"]), program=prog,
                calibrator=calibrator_shim(d.get("calibrator")))
            if pick != d["pick"]:
                _diverge(rec, "channel pick", d["pick"], pick)
            res.channel_picks += 1
        elif rec.kind == "migration":
            plan = _serialize_plan(cp.affinity_migration(
                np.asarray(d["traffic"], float),
                min_share=float(d["min_share"]),
                limit=None if d.get("limit") is None else int(d["limit"])))
            if plan != d["plan"]:
                _diverge(rec, "migration plan", d["plan"], plan)
            res.migrations += 1
        elif rec.kind == "register":
            specs.append(TenantSpec(
                tenant_id=int(d["tenant_id"]), name=d["name"], qos=d["qos"],
                page_quota=int(d.get("page_quota", 0)),
                share=float(d.get("share", 1.0)),
                priority=int(d.get("priority", 0)),
                slo_round_us=float(d.get("slo_round_us", 0.0))))
        elif rec.kind == "refit":
            sched = WeightedFairScheduler(int(d["budget"]))
            mode = d.get("mode", "compile")
            if mode == "telemetry":
                shim = SimpleNamespace(
                    tenant_demand=lambda: np.asarray(d["demand"], float),
                    last_tenant_spilled=np.asarray(d["spilled"], float))
                got = sched.refit(specs, shim, int(d["num_nodes"]),
                                  saturated=list(d.get("saturated", [])))
            elif mode == "windows":
                got = sched.compile(specs, {int(k): float(v) for k, v
                                            in d["demand"].items()})
            else:
                got = sched.compile(specs)
            want = {int(k): int(v) for k, v in d["windows"].items()}
            if dict(got.windows) != want:
                _diverge(rec, "windows", want, dict(got.windows))
            res.refits += 1
        # admission / lease_* / alert / step_report / calibrator_refit:
        # causal metadata — effects replay via the records they reference.
    res.placement_digest = placement_digest(cp)
    res.plane = cp
    return res


__all__ = [
    "DecisionRecord",
    "FlightRecorder",
    "JournalError",
    "JournalTruncatedError",
    "ReplayDivergenceError",
    "ReplayResult",
    "calibrator_shim",
    "calibrator_snapshot",
    "placement_digest",
    "program_digest",
    "program_from_dict",
    "program_to_dict",
    "replay",
    "route_telemetry_shim",
    "route_telemetry_snapshot",
    "route_variant",
    "wire_telemetry_shim",
    "wire_telemetry_snapshot",
]
