"""Jaxpr / HLO auditor for datapath purity and retrace hazards.

The bridge's correctness story depends on the jitted datapath being a
*pure, statically-shaped* function of its step inputs: no host callbacks
(`pure_callback` / `io_callback` / `debug_callback`), no infeed/outfeed,
no dynamic output shapes — and a bounded number of wire collectives per
channel depth (the PR 4 dispatch regression was exactly an unbounded
per-depth collective blow-up).  This module proves those properties on
traced jaxprs and lowered HLO text, and turns the recorded
``phase_breakdown`` of BENCH_bridge.json into a machine-checked budget.

jax is imported lazily inside the functions that trace/lower, so the
budget checks (:func:`wire_op_budget`, :func:`check_collective_budget`)
stay importable from jax-free contexts (``benchmarks/validate_bench.py``).

Rule catalog (details in ``src/repro/analysis/RULES.md``):

  JA301  host-callback      a callback primitive inside the datapath
  JA302  dynamic-shape      an equation output with a non-static dimension
  JA303  infeed-outfeed     host transfer primitives inside the datapath
  JA304  retrace            a jitted function compiled more than once over
                            a set of calls that should share one trace
  JA305  collective-budget  per-phase wire op count above the channel-depth
                            budget (or a fused count that scales with depth)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.analysis.findings import Finding

__all__ = ["audit_jaxpr", "audit_fn", "audit_hlo_text", "count_primitives",
           "collective_counts", "audit_retrace", "wire_op_budget",
           "check_collective_budget", "WIRE_COLLECTIVES"]

#: Primitive names that round-trip through the host.
HOST_CALLBACK_PRIMITIVES = {
    "pure_callback", "io_callback", "debug_callback", "callback",
    "debug_print", "host_callback_call", "outside_call",
}
HOST_TRANSFER_PRIMITIVES = {"infeed", "outfeed"}

#: Collective primitives that put flits on the wire (jaxpr names).
WIRE_COLLECTIVES = ("ppermute", "all_gather", "all_to_all", "psum",
                    "pmax", "pmin", "reduce_scatter")

#: HLO custom-call targets that implement host callbacks after lowering.
_HLO_CALLBACK_MARKERS = ("callback", "py_func")


# --------------------------------------------------------------------- jaxpr
def _subjaxprs(jaxpr):
    """Immediate child jaxprs of every equation (scan/while/cond bodies)."""
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            objs = v if isinstance(v, (tuple, list)) else (v,)
            for o in objs:
                inner = getattr(o, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield inner
                elif hasattr(o, "eqns"):
                    yield o


def _walk_eqns(jaxpr, depth=0):
    """(equation, depth) over the whole jaxpr tree, bodies included."""
    if depth > 32:
        return
    for eqn in jaxpr.eqns:
        yield eqn, depth
    for sub in _subjaxprs(jaxpr):
        yield from _walk_eqns(sub, depth + 1)


def _closed(jaxpr):
    return getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr


def audit_jaxpr(jaxpr, *, where: str = "jaxpr") -> List[Finding]:
    """Purity audit of one (closed) jaxpr: JA301 / JA302 / JA303."""
    out: List[Finding] = []
    for eqn, _ in _walk_eqns(_closed(jaxpr)):
        name = eqn.primitive.name
        if name in HOST_CALLBACK_PRIMITIVES:
            out.append(Finding(
                "JA301", f"host callback primitive '{name}' inside the "
                "datapath — every call syncs the device stream", path=where))
        elif name in HOST_TRANSFER_PRIMITIVES:
            out.append(Finding(
                "JA303", f"host transfer primitive '{name}' inside the "
                "datapath", path=where))
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", ())
            if any(not isinstance(d, int) for d in shape):
                out.append(Finding(
                    "JA302", f"'{name}' produces a dynamic output shape "
                    f"{shape} — downstream consumers retrace per size",
                    path=where))
    return out


def audit_fn(fn, *args, where: str = "", **kwargs) -> List[Finding]:
    """Trace ``fn`` with jax.make_jaxpr and audit the result."""
    import jax

    jaxpr = jax.make_jaxpr(fn)(*args, **kwargs)
    return audit_jaxpr(jaxpr, where=where or getattr(fn, "__name__", "fn"))


def count_primitives(jaxpr) -> Dict[str, int]:
    """Primitive occurrence counts over the whole jaxpr tree.

    Loop bodies (scan/while) count ONCE — this is trace-size accounting,
    the static complement of runtime op counts.
    """
    counts: Dict[str, int] = {}
    for eqn, _ in _walk_eqns(_closed(jaxpr)):
        name = eqn.primitive.name
        counts[name] = counts.get(name, 0) + 1
    return counts


def collective_counts(jaxpr) -> Dict[str, int]:
    """The wire-collective subset of :func:`count_primitives`."""
    return {k: v for k, v in count_primitives(jaxpr).items()
            if k in WIRE_COLLECTIVES}


def audit_retrace(jitted, argsets: Sequence[tuple], *,
                  where: str = "jit") -> List[Finding]:
    """Call ``jitted`` over ``argsets``; >1 compile is a JA304 finding.

    Use for step inputs that are *supposed* to be runtime values (route
    programs, budgets, tables): if swapping them retraces, the zero-retrace
    contract is broken.
    """
    before = int(jitted._cache_size())
    for args in argsets:
        jitted(*args)
    misses = int(jitted._cache_size()) - before
    if misses > 1:
        return [Finding(
            "JA304", f"{misses} compilations over {len(argsets)} calls — "
            "a step input is being treated as static (expected at most 1)",
            path=where)]
    return []


# ----------------------------------------------------------------------- HLO
def audit_hlo_text(text: str, *, where: str = "hlo") -> List[Finding]:
    """Purity audit of lowered HLO text: callbacks and infeed/outfeed
    survive lowering as custom-calls / infeed ops."""
    from repro.analysis import hlo

    out: List[Finding] = []
    for comp in hlo.parse_hlo(text).values():
        for ins in comp.instructions:
            if ins.opcode in ("infeed", "outfeed"):
                out.append(Finding(
                    "JA303", f"{ins.opcode} instruction '{ins.name}' in "
                    f"computation {comp.name}", path=where))
            elif ins.opcode == "custom-call" and any(
                    m in ins.raw.lower() for m in _HLO_CALLBACK_MARKERS):
                out.append(Finding(
                    "JA301", f"host-callback custom-call '{ins.name}' in "
                    f"computation {comp.name}", path=where))
    return out


# ------------------------------------------------------------------- budgets
def wire_op_budget(num_nodes: int, channels: int, *,
                   fused: bool) -> Dict[str, int]:
    """Upper bound on scoped wire ops per transfer round, per phase.

    Derived from the engines' structure (``repro.core.bridge``):

    * unfused serial (channels == 1): one request ppermute and one data
      ppermute per live slot — exactly ``N-1`` each.
    * unfused pipelined (channels == c >= 2): each of the c chunks issues
      its own per-slot wire ops, plus one extra per-slot drain for the
      double-buffered carry — ``(N-1) * (c+1)``.
    * fused: one request all_gather (``wire_req = 1``) and one payload
      exchange whose op count is depth-INDEPENDENT — ``N-1`` ladder
      rotations off-TPU, 1 all_to_all on TPU; budgeted at ``N-1``.
    """
    s = max(num_nodes - 1, 1)
    if fused:
        return {"wire_req": 1, "wire_data": s}
    if channels <= 1:
        return {"wire_req": s, "wire_data": s}
    return {"wire_req": s * (channels + 1), "wire_data": s * (channels + 1)}


def check_collective_budget(phase_breakdown: dict, num_nodes: int
                            ) -> List[Finding]:
    """JA305: the recorded per-depth phase op counts against the budget.

    ``phase_breakdown`` is the BENCH_bridge.json section
    (``{"unfused"|"fused": {"<channels>": {"phase_ops": {...}}}}``).
    Asserts every wire phase stays within :func:`wire_op_budget` and that
    the fused engine's wire counts do not scale with depth (the structural
    property that killed the PR 4 dispatch regression).
    """
    out: List[Finding] = []
    for engine in ("unfused", "fused"):
        entries = phase_breakdown.get(engine, {})
        baseline: Dict[str, int] = {}
        for c_str in sorted(entries, key=lambda x: int(x)):
            ops = entries[c_str].get("phase_ops", {})
            budget = wire_op_budget(num_nodes, int(c_str),
                                    fused=(engine == "fused"))
            for phase, cap in budget.items():
                got = ops.get(phase)
                if got is None:
                    out.append(Finding(
                        "JA305", f"{engine} depth {c_str}: phase '{phase}' "
                        "missing from phase_ops", path="phase_breakdown"))
                    continue
                if got > cap:
                    out.append(Finding(
                        "JA305", f"{engine} depth {c_str}: {got} '{phase}' "
                        f"ops above the budget {cap} for a {num_nodes}-node "
                        "ring", path="phase_breakdown"))
                if engine == "fused":
                    if phase in baseline and got != baseline[phase]:
                        out.append(Finding(
                            "JA305", f"fused depth {c_str}: '{phase}' op "
                            f"count {got} != depth-1 count "
                            f"{baseline[phase]} — the fused engine's wire "
                            "ops must not scale with channels",
                            path="phase_breakdown"))
                    baseline.setdefault(phase, got)
    return out


def audit_transfer(fn, *args, where: str = "",
                   budget: Optional[Dict[str, int]] = None,
                   **kwargs) -> List[Finding]:
    """One-stop audit of a datapath callable: trace -> purity audit, and
    optionally lower -> scoped wire ops vs ``budget`` (JA305)."""
    import jax

    from repro.analysis import hlo

    name = where or getattr(fn, "__name__", "fn")
    out = audit_fn(fn, *args, where=name, **kwargs)
    if budget:
        lowered = jax.jit(fn).lower(*args, **kwargs)
        text = lowered.compile().as_text()
        counts = hlo.scope_op_counts(text)
        for phase, cap in budget.items():
            got = counts.get(phase, 0)
            if got > cap:
                out.append(Finding(
                    "JA305", f"{name}: {got} scoped '{phase}' ops above "
                    f"budget {cap}", path=name))
    return out
