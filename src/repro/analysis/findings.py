"""Structured findings shared by every bridgelint analysis pass.

A :class:`Finding` is one violated contract: a stable rule id (the
catalog lives in ``src/repro/analysis/RULES.md``), a human message, and
the locus it anchors to — a ``path:line`` for source lint, a logical
locus ("slot 3", "epoch 2") for program verification.  Passes *return*
findings instead of raising so callers can collect, filter, report or
suppress; :class:`ProgramVerificationError` is the raising wrapper the
control plane uses to refuse installing an unsound route program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

#: Severities.  ``error`` findings fail the CLI / raise in the control
#: plane; ``warning`` findings are reported but never gate.
ERROR = "error"
WARNING = "warning"


@dataclass(frozen=True)
class Finding:
    """One violated contract, anchored to a source or logical locus."""

    rule: str                 # stable id, e.g. "BL201" / "PC108" / "JA301"
    message: str
    path: str = ""            # file path, or logical locus ("program")
    line: int = 0             # 1-based source line; 0 = not a source locus
    severity: str = ERROR

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else (self.path or "-")
        return f"{loc}: {self.rule} [{self.severity}] {self.message}"

    def as_dict(self) -> dict:
        return {"rule": self.rule, "message": self.message,
                "path": self.path, "line": self.line,
                "severity": self.severity}


def errors(findings: Sequence[Finding]) -> List[Finding]:
    """The gating subset: findings with ``error`` severity."""
    return [f for f in findings if f.severity == ERROR]


class ProgramVerificationError(ValueError):
    """A RouteProgram failed static verification; carries the findings.

    Raised by ``ControlPlane.route_program(verify=True)`` instead of
    silently installing a program whose schedule would drop, duplicate or
    collide traffic.  ``.findings`` holds the full structured list.
    """

    def __init__(self, findings: Sequence[Finding]):
        self.findings: List[Finding] = list(findings)
        lines = "\n  ".join(str(f) for f in self.findings)
        super().__init__(
            f"route program failed static verification "
            f"({len(self.findings)} finding(s)):\n  {lines}")
