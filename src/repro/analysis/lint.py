"""AST lint for the retrace / host-sync hazards that bite jax datapaths.

Catches, at parse time, the patterns that historically forced recompiles
or silent host round-trips in this repo: host conversions of traced
values, Python control flow on tracers, per-call Python lists baked into
fresh constants, ``jax.jit`` of loop-shaped functions without
``static_argnames``, mutation of frozen pytree fields outside
construction, and host-side batcher-state mutation from outside the
owning object.

Rule catalog (details in ``src/repro/analysis/RULES.md``):

  BL201  host-round-trip       int()/float()/bool()/.item() on a
                               jax-rooted expression
  BL202  traced-branch         Python if/while/ternary on a jax-rooted test
  BL203  fresh-constant        jnp.asarray/jnp.array of a per-call Python
                               list/tuple/comprehension
  BL204  missing-static        jax.jit of a function that range()-loops
                               over one of its own parameters, without
                               static_argnames/static_argnums
  BL205  frozen-mutation       object.__setattr__ outside
                               __init__/__post_init__/__setstate__
  BL206  batcher-tick          slot-map / queue / lease state mutated on an
                               object other than self (outside the owning
                               batcher's tick methods)
  BL207  raw-clock             direct time.time()/time.monotonic()/
                               time.perf_counter() (and _ns variants)
                               outside ``repro/obs/clock.py`` — bypasses
                               the injectable Clock, breaking ManualClock
                               determinism and flight-journal replay

Suppression: append ``# bridgelint: ignore[BL203]`` (or a bare
``# bridgelint: ignore`` for all rules) to the offending line or the line
directly above it.

The detectors are deliberately conservative — tuned so the shipped tree
lints clean without suppressions; anything ambiguous (a bare Name that
*might* be a tracer) is not flagged.  False negatives are acceptable,
false positives are not: the lint gates CI.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.findings import Finding

__all__ = ["lint_source", "lint_file", "lint_paths", "iter_py_files"]

#: Module roots whose calls produce traced arrays.
_JAX_ROOTS = {"jnp", "jax", "lax"}

#: jax.* / jnp.* calls that return *host* values — never flagged.
_HOST_OK_FUNCS = {
    "default_backend", "devices", "device_count", "local_device_count",
    "process_index", "process_count", "issubdtype", "isdtype", "dtype",
    "result_type", "tree_structure", "tree_all", "make_jaxpr",
    "named_scope", "eval_shape",
}

#: Attribute reads that turn a traced expression into static host data.
_HOST_OK_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize", "sharding"}

#: Raw wall-clock reads (BL207) — everything outside ``repro/obs/clock.py``
#: must go through the injectable ``Clock`` so tests and replay can pin time.
_RAW_CLOCK_CALLS = {
    "time.time", "time.monotonic", "time.perf_counter",
    "time.time_ns", "time.monotonic_ns", "time.perf_counter_ns",
}

#: The one module allowed to read the host clock directly.
_CLOCK_MODULE_SUFFIX = "obs/clock.py"

#: Host-side batcher / lease state (BL206): mutating these on anything
#: other than ``self`` bypasses the owning object's tick discipline.
_BATCHER_STATE = {"slots", "queues", "leases", "slot_map", "_pending_reset"}
_MUTATING_METHODS = {"append", "appendleft", "extend", "insert", "pop",
                     "popleft", "remove", "clear", "update", "setdefault"}

_SUPPRESS_RE = re.compile(
    r"#\s*bridgelint:\s*ignore(?:\[([A-Za-z0-9_,\s]+)\])?")


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` as a string for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_chain(call: ast.Call) -> Optional[str]:
    return _dotted(call.func)


def _is_jax_call(call: ast.Call) -> bool:
    chain = _call_chain(call)
    if not chain:
        return False
    parts = chain.split(".")
    if parts[0] not in _JAX_ROOTS:
        return False
    return parts[-1] not in _HOST_OK_FUNCS


def _is_traced_expr(node: ast.AST) -> bool:
    """Heuristic: does this expression hold a traced jax value?

    True iff it *contains* a call rooted at jnp/jax/lax (minus the known
    host-returning helpers) and is not unwrapped back to host data via a
    static attribute (``.shape`` etc.).  Bare Names are never traced —
    too ambiguous for a gating lint.
    """
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _HOST_OK_ATTRS:
            return False
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and _is_jax_call(sub):
            return True
    return False


def _is_constant_elt(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.operand,
                                                    ast.Constant):
        return True
    return False


def _static_kwargs(call: ast.Call) -> bool:
    return any(kw.arg in ("static_argnames", "static_argnums")
               for kw in call.keywords)


class _FnIndex(ast.NodeVisitor):
    """Module-level function defs, for the BL204 jit-site resolution."""

    def __init__(self):
        self.fns: Dict[str, ast.FunctionDef] = {}

    def visit_FunctionDef(self, node: ast.FunctionDef):
        self.fns.setdefault(node.name, node)
        # no generic_visit: only module/class level defs are resolvable

    visit_AsyncFunctionDef = visit_FunctionDef


def _params_looped_over(fn: ast.FunctionDef) -> Set[str]:
    """Parameters of ``fn`` used as a ``range()`` bound inside it —
    trace-time loop lengths that must be static."""
    params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
    hit: Set[str] = set()
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id == "range":
            for arg in sub.args:
                for name in ast.walk(arg):
                    if isinstance(name, ast.Name) and name.id in params:
                        hit.add(name.id)
    return hit


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, fn_index: Dict[str, ast.FunctionDef]):
        self.path = path
        self.fns = fn_index
        self.findings: List[Finding] = []
        self._func_stack: List[str] = []
        self._clock_module = path.replace("\\", "/").endswith(
            _CLOCK_MODULE_SUFFIX)

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        self.findings.append(Finding(rule, message, path=self.path,
                                     line=getattr(node, "lineno", 0)))

    # ------------------------------------------------------------ scopes
    def visit_FunctionDef(self, node: ast.FunctionDef):
        self._func_stack.append(node.name)
        self._check_jit_decorators(node)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    # ----------------------------------------------------------- BL202
    def _check_test(self, node: ast.AST, test: ast.AST, kind: str) -> None:
        if _is_traced_expr(test):
            self._emit("BL202", node,
                       f"Python {kind} on a traced expression — the value "
                       "forces a host sync at trace time (use jnp.where / "
                       "lax.cond / lax.select)")

    def visit_If(self, node: ast.If):
        self._check_test(node, node.test, "if")
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        self._check_test(node, node.test, "while")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp):
        self._check_test(node, node.test, "conditional expression")
        self.generic_visit(node)

    # ----------------------------------------------------------- BL205/206
    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            self._check_state_store(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check_state_store(node.target)
        self.generic_visit(node)

    def _batcher_attr(self, node: ast.AST) -> Optional[str]:
        """``obj.slots``-style access where obj is not ``self``."""
        if isinstance(node, ast.Subscript):
            node = node.value
        if not isinstance(node, ast.Attribute) or \
                node.attr not in _BATCHER_STATE:
            return None
        root = node.value
        while isinstance(root, (ast.Attribute, ast.Subscript)):
            root = root.value
        if isinstance(root, ast.Name) and root.id in ("self", "cls"):
            return None
        return node.attr

    def _check_state_store(self, target: ast.AST) -> None:
        attr = self._batcher_attr(target)
        if attr is not None:
            self._emit("BL206", target,
                       f"mutation of batcher state '.{attr}' from outside "
                       "the owning object — slot-map/lease changes must go "
                       "through the batcher's tick methods")

    # ----------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call):
        chain = _call_chain(node)

        # BL201: int()/float()/bool() over a traced expression
        if isinstance(node.func, ast.Name) and \
                node.func.id in ("int", "float", "bool") and \
                len(node.args) == 1 and _is_traced_expr(node.args[0]):
            self._emit("BL201", node,
                       f"{node.func.id}() on a traced expression blocks on "
                       "device transfer (np.asarray the fenced result "
                       "instead, outside the hot path)")
        # BL201: .item() on a traced expression
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and \
                _is_traced_expr(node.func.value):
            self._emit("BL201", node,
                       ".item() on a traced expression blocks on device "
                       "transfer")

        # BL203: jnp.asarray/jnp.array of a per-call Python list
        if chain in ("jnp.asarray", "jnp.array", "jax.numpy.asarray",
                     "jax.numpy.array") and node.args:
            arg = node.args[0]
            if isinstance(arg, (ast.List, ast.Tuple)):
                elts = arg.elts
                if elts and not all(_is_constant_elt(e) for e in elts) \
                        and not any(_is_traced_expr(e) for e in elts):
                    self._emit(
                        "BL203", node,
                        f"{chain} of a per-call Python sequence bakes a "
                        "fresh constant into every trace (hoist it, or pass "
                        "an ndarray)")
            elif isinstance(arg, (ast.ListComp, ast.GeneratorExp)) and \
                    not _is_traced_expr(arg.elt):
                self._emit(
                    "BL203", node,
                    f"{chain} of a comprehension builds a fresh constant "
                    "per call (hoist it, or vectorize with jnp.arange)")

        # BL204: jax.jit(fn) call-site without static argnames
        if chain in ("jax.jit", "jit") and node.args and \
                isinstance(node.args[0], ast.Name) and \
                not _static_kwargs(node):
            fn = self.fns.get(node.args[0].id)
            if fn is not None:
                looped = _params_looped_over(fn)
                if looped:
                    self._emit(
                        "BL204", node,
                        f"jax.jit({fn.name}) without static_argnames, but "
                        f"{fn.name}() loops over parameter(s) "
                        f"{sorted(looped)} with range() — they must be "
                        "static or every new value retraces")

        # BL205: object.__setattr__ outside construction
        if chain == "object.__setattr__" and \
                (not self._func_stack or self._func_stack[-1] not in
                 ("__init__", "__post_init__", "__setstate__")):
            self._emit("BL205", node,
                       "object.__setattr__ outside __init__/__post_init__ "
                       "mutates a frozen pytree after construction — jitted "
                       "consumers hold the stale leaves")

        # BL207: raw wall-clock read outside the clock module
        if chain in _RAW_CLOCK_CALLS and not self._clock_module:
            self._emit("BL207", node,
                       f"{chain}() bypasses the injectable obs.Clock — use "
                       "MonotonicClock().now_us() (or a passed-in clock) so "
                       "ManualClock tests and journal replay stay "
                       "deterministic")

        # BL206: mutating-method call on foreign batcher state
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _MUTATING_METHODS:
            attr = self._batcher_attr(node.func.value)
            if attr is not None:
                self._emit("BL206", node,
                           f".{node.func.attr}() on batcher state "
                           f"'.{attr}' from outside the owning object")
        self.generic_visit(node)

    def _check_jit_decorators(self, node: ast.FunctionDef) -> None:
        """BL204 for the decorator form: @jax.jit / @partial(jax.jit)."""
        for dec in node.decorator_list:
            chain = _dotted(dec) if not isinstance(dec, ast.Call) else None
            if isinstance(dec, ast.Call):
                dchain = _call_chain(dec)
                if dchain in ("functools.partial", "partial") and dec.args \
                        and _dotted(dec.args[0]) in ("jax.jit", "jit"):
                    if not _static_kwargs(dec):
                        chain = "jax.jit"
                elif dchain in ("jax.jit", "jit") and not _static_kwargs(dec):
                    chain = "jax.jit"
            if chain in ("jax.jit", "jit"):
                looped = _params_looped_over(node)
                if looped:
                    self._emit(
                        "BL204", dec,
                        f"@jax.jit on {node.name}() without static_argnames "
                        f"but it range()-loops over {sorted(looped)}")


def _suppressed_lines(source: str) -> Dict[int, Optional[Set[str]]]:
    """line -> suppressed rule set (None = all rules)."""
    out: Dict[int, Optional[Set[str]]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(line)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = {r.strip().upper() for r in m.group(1).split(",")
                      if r.strip()}
    return out


def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one module's source text; returns unsuppressed findings."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("BL200", f"syntax error: {e.msg}", path=path,
                        line=e.lineno or 0)]
    index = _FnIndex()
    index.visit(tree)
    linter = _Linter(path, index.fns)
    linter.visit(tree)
    supp = _suppressed_lines(source)
    out = []
    for f in linter.findings:
        ok = False
        for line in (f.line, f.line - 1):
            rules = supp.get(line, "missing")
            if rules is None or (rules != "missing" and f.rule in rules):
                ok = True
        if not ok:
            out.append(f)
    return out


def lint_file(path) -> List[Finding]:
    p = Path(path)
    return lint_source(p.read_text(), path=str(p))


def iter_py_files(paths: Iterable) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def lint_paths(paths: Iterable) -> List[Finding]:
    """Lint every ``.py`` under ``paths`` (dirs recurse)."""
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(lint_file(f))
    return findings
